// Fixture: an unsafe block with no `// SAFETY:` comment anywhere near
// it must produce a `safety` finding.
pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}
