// Fixture: renders a metric name absent from the catalogue fixture,
// and fails to render one the catalogue lists — `metrics` findings in
// both directions.
pub fn render() -> String {
    let mut o = String::new();
    o.push_str("singlequant_bogus_total 1\n");
    o
}
