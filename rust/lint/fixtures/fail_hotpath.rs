// Fixture: hot-path aborts must produce `hotpath` findings — both the
// `.unwrap()` and the indexing-by-literal `pages[0]`.
pub fn first_page(pages: &[u32]) -> u32 {
    let head = pages.first().copied();
    head.unwrap() + pages[0]
}
