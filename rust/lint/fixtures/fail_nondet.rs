// Fixture: reading the wall clock outside the clock/metrics/server/
// bench modules must produce a `nondet` finding (exact-replay
// contract) — serving logic goes through `util::clock::now()`.
pub fn stamp_now() -> std::time::Instant {
    std::time::Instant::now()
}
