// Fixture: thread creation anywhere but tensor/pool.rs must produce a
// `thread` finding — it bypasses the worker pool's nesting guard.
pub fn sneak_a_thread() {
    std::thread::spawn(|| {}).join().ok();
}
