//! `cargo run -p sqlint` — lint the whole tree and exit non-zero on
//! any finding. An optional argument overrides the repo root (the
//! fixture self-tests exercise the library API instead).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use sqlint::{gather, lint_all};

fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        // lint/ lives at rust/lint — the repo root is two levels up
        None => Path::new(env!("CARGO_MANIFEST_DIR")).join("../.."),
    };
    let fs = match gather(&root) {
        Ok(fs) => fs,
        Err(e) => {
            eprintln!("sqlint: walking {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let n_files = fs.rust_files.len();
    let findings = lint_all(&fs);
    for f in &findings {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    if findings.is_empty() {
        println!("sqlint: {n_files} files clean");
        ExitCode::SUCCESS
    } else {
        println!("sqlint: {} finding(s) across {n_files} files", findings.len());
        ExitCode::FAILURE
    }
}
