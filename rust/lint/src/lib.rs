//! `sqlint` — the project's static pass over the rust_pallas tree.
//!
//! Six rules, each encoding a contract the serving stack otherwise
//! enforces only by convention (see DESIGN.md "Static analysis & audit"
//! for the catalogue and the rationale behind each):
//!
//! * `safety`  — every `unsafe` site carries a `// SAFETY:` comment.
//! * `thread`  — no thread creation outside `tensor/pool.rs`.
//! * `nondet`  — no wall-clock / entropy sources outside the metrics,
//!   server, bench, and clock modules (the exact-replay contract).
//! * `hotpath` — no `unwrap`/`expect`/`panic!`-family macros or
//!   indexing-by-literal in the hot serving modules; those paths must
//!   return typed errors instead of aborting the engine.
//! * `metrics` — Prometheus names rendered by `coordinator/metrics.rs`
//!   match the catalogue in DESIGN.md exactly, both directions.
//! * `envvar`  — every `SQ_*` env var referenced by CI exists in code.
//!
//! The lexer is hand-rolled (comments, strings, raw strings, char
//! literals, `#[cfg(test)]` regions) so the crate has zero dependencies
//! and the fully offline vendored build keeps working.
//!
//! A finding is suppressed by a comment on the same line or the line
//! above: `// sqlint: allow(<rule>) — reason`. The reason is part of
//! the convention; the marker alone is what the matcher keys on.

pub const RULE_SAFETY: &str = "safety";
pub const RULE_THREAD: &str = "thread";
pub const RULE_NONDET: &str = "nondet";
pub const RULE_HOTPATH: &str = "hotpath";
pub const RULE_METRICS: &str = "metrics";
pub const RULE_ENVVAR: &str = "envvar";

/// One rule violation, formatted by the binary as `path:line: [rule] msg`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub msg: String,
}

/// One physical source line after lexing: `code` has comments and
/// string/char-literal contents blanked (quotes kept), `comment` holds
/// the text of any comment on the line, `in_test` marks lines inside a
/// `#[cfg(test)] mod` body.
#[derive(Debug, Default, Clone)]
pub struct Line {
    pub code: String,
    pub comment: String,
    pub in_test: bool,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex Rust source into per-line code/comment views. Handles nested
/// block comments, escapes in string and char literals, raw strings
/// with any hash count, and the char-literal/lifetime ambiguity.
pub fn lex(src: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        CharLit,
    }
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    // raw string? scan back over hashes to an `r`
                    let mut h = 0usize;
                    while i >= h + 1 && chars[i - h - 1] == '#' {
                        h += 1;
                    }
                    let is_raw = i >= h + 1 && chars[i - h - 1] == 'r';
                    cur.code.push('"');
                    st = if is_raw { St::RawStr(h as u32) } else { St::Str };
                    i += 1;
                } else if c == '\'' {
                    // char literal iff an escape or a single char then a
                    // closing quote follows; otherwise it is a lifetime
                    if chars.get(i + 1) == Some(&'\\') || chars.get(i + 2) == Some(&'\'') {
                        cur.code.push('\'');
                        st = St::CharLit;
                    } else {
                        cur.code.push('\'');
                    }
                    i += 1;
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(d) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Code } else { St::BlockComment(d - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(d + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    cur.code.push(' ');
                    if chars.get(i + 1).is_some() {
                        cur.code.push(' ');
                    }
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(h) => {
                let closes = c == '"'
                    && (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'));
                if closes {
                    cur.code.push('"');
                    for _ in 0..h {
                        cur.code.push('#');
                    }
                    st = St::Code;
                    i += 1 + h as usize;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\\' {
                    cur.code.push(' ');
                    if chars.get(i + 1).is_some() {
                        cur.code.push(' ');
                    }
                    i += 2;
                } else if c == '\'' {
                    cur.code.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    mark_tests(&mut lines);
    lines
}

/// Mark lines inside `#[cfg(test)] mod … { … }` bodies (including
/// `#[cfg(all(test, …))]`). Rules that guard runtime behaviour skip
/// them; tests may spawn threads, read clocks, and unwrap freely.
fn mark_tests(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut pending_mod = false;
    let mut test_base: Option<i64> = None;
    for line in lines.iter_mut() {
        if test_base.is_some() {
            line.in_test = true;
        }
        let t = line.code.trim().to_string();
        if test_base.is_none() && (t.contains("#[cfg(test)]") || t.contains("#[cfg(all(test")) {
            armed = true;
        }
        let is_mod = t.starts_with("mod ") || t.starts_with("pub mod ") || t.contains(" mod ");
        if armed && is_mod {
            pending_mod = true;
        } else if armed && !t.is_empty() && !t.starts_with("#[") {
            // the cfg(test) attribute gated something other than a mod
            // (a fn, a use) — no region to open
            armed = false;
        }
        for ch in t.chars() {
            match ch {
                '{' => {
                    if pending_mod && test_base.is_none() {
                        test_base = Some(depth);
                        line.in_test = true;
                        armed = false;
                        pending_mod = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_base == Some(depth) {
                        test_base = None;
                    }
                }
                _ => {}
            }
        }
    }
}

/// True when `lines[i]` (or the line above) carries the inline
/// suppression marker for `rule`.
fn suppressed(lines: &[Line], i: usize, rule: &str) -> bool {
    let marker = format!("sqlint: allow({rule})");
    if lines[i].comment.contains(&marker) {
        return true;
    }
    i > 0 && lines[i - 1].comment.contains(&marker)
}

/// Find `pat` in `code` at a word boundary (the char before the match,
/// if any, is not an identifier char). Returns match offsets.
fn boundary_matches(code: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(off) = code[from..].find(pat) {
        let at = from + off;
        let pre_ok = at == 0 || !is_ident(code[..at].chars().next_back().unwrap_or(' '));
        if pre_ok {
            out.push(at);
        }
        from = at + pat.len();
    }
    out
}

fn next_non_ws(s: &str) -> Option<char> {
    s.chars().find(|c| !c.is_whitespace())
}

// ---------------------------------------------------------------- safety

/// `unsafe` blocks, fns, impls, and traits must be annotated with a
/// `// SAFETY:` comment (a `# Safety` doc section also counts).
/// Function-pointer *types* (`unsafe fn(...)`) are not unsafe sites.
fn check_safety(path: &str, lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    for i in 0..lines.len() {
        let code = &lines[i].code;
        for at in boundary_matches(code, "unsafe") {
            let after = &code[at + "unsafe".len()..];
            if after.chars().next().is_some_and(is_ident) {
                continue;
            }
            // `unsafe fn(` in type position is a signature, not a site
            let mut rest = after.trim_start().to_string();
            if rest.is_empty() {
                if let Some(next) = lines.get(i + 1) {
                    rest = next.code.trim_start().to_string();
                }
            }
            if let Some(tail) = rest.strip_prefix("fn") {
                if next_non_ws(tail) == Some('(') {
                    continue;
                }
            }
            if !safety_covered(lines, i) && !suppressed(lines, i, RULE_SAFETY) {
                out.push(Finding {
                    rule: RULE_SAFETY,
                    path: path.to_string(),
                    line: i + 1,
                    msg: "unsafe site without a `// SAFETY:` comment".to_string(),
                });
            }
        }
    }
    out
}

fn has_safety_tag(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

fn safety_covered(lines: &[Line], i: usize) -> bool {
    if has_safety_tag(&lines[i].comment) {
        return true;
    }
    let lo = i.saturating_sub(12);
    for j in (lo..i).rev() {
        if has_safety_tag(&lines[j].comment) {
            return true;
        }
        let t = lines[j].code.trim();
        let part_of_group = t.contains("unsafe impl")
            || t.starts_with("unsafe fn")
            || t.starts_with("pub unsafe")
            || t.starts_with("pub(crate) unsafe")
            || t.starts_with("pub(super) unsafe");
        // A line ending in `=` is a wrapped assignment head (rustfmt
        // splits `let x = unsafe { … }` when it overflows); the unsafe
        // expression below belongs to it, so keep scanning for the
        // comment above the head.
        if t.is_empty() || t.starts_with("#[") || part_of_group || t.ends_with('=') {
            continue;
        }
        return false;
    }
    false
}

// ---------------------------------------------------------------- thread

const THREAD_PATTERNS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];

/// All *compute* thread creation funnels through the `tensor/pool.rs`
/// worker pool; anything else bypasses its nesting guard and queue
/// accounting. `src/server/` is exempt: the HTTP accept loop and
/// per-connection handlers are I/O threads, not compute, and never
/// touch the pool's invariants.
fn check_thread(path: &str, lines: &[Line]) -> Vec<Finding> {
    if !path.starts_with("src/")
        || path == "src/tensor/pool.rs"
        || path.starts_with("src/server/")
    {
        return Vec::new();
    }
    let what = "thread creation outside tensor/pool.rs bypasses the worker pool";
    scan_patterns(path, lines, RULE_THREAD, THREAD_PATTERNS, what)
}

// ---------------------------------------------------------------- nondet

const NONDET_PATTERNS: &[&str] = &[
    "Instant::now",
    "SystemTime::now",
    "thread_rng",
    "from_entropy",
    "getrandom",
    "RandomState",
];

const NONDET_ALLOWED: &[&str] = &[
    "src/coordinator/metrics.rs",
    "src/util/bench.rs",
    "src/util/clock.rs",
];

/// Exact-replay contract: serving logic must not read wall clocks or
/// entropy directly. Time flows through `util::clock::now()` (one
/// audited chokepoint); sampling through the positional RNG.
fn check_nondet(path: &str, lines: &[Line]) -> Vec<Finding> {
    if !path.starts_with("src/")
        || path.starts_with("src/server/")
        || NONDET_ALLOWED.contains(&path)
    {
        return Vec::new();
    }
    let what = "nondeterminism outside the clock/metrics/server/bench modules";
    scan_patterns(path, lines, RULE_NONDET, NONDET_PATTERNS, what)
}

// --------------------------------------------------------------- hotpath

const HOT_MODULES_EXACT: &[&str] =
    &["src/coordinator/batcher.rs", "src/runtime/native_backend.rs"];

fn in_hot_scope(path: &str) -> bool {
    HOT_MODULES_EXACT.contains(&path)
        || path.starts_with("src/kv/")
        || path.starts_with("src/spec/")
        || path.starts_with("src/pipeline/")
        || path.starts_with("src/calib/")
}

/// The serving hot path must degrade through typed errors
/// (`AdmissionError`, `FinishReason`, `KvError`) — never abort on
/// request-shaped input. Bans `.unwrap()`, `.expect(…)`, the panicking
/// macros, and indexing by integer literal.
fn check_hotpath(path: &str, lines: &[Line]) -> Vec<Finding> {
    if !in_hot_scope(path) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut hits: Vec<String> = Vec::new();
        for call in ["unwrap", "expect"] {
            for at in boundary_matches(code, call) {
                let dotted = code[..at].trim_end().ends_with('.');
                let called = next_non_ws(&code[at + call.len()..]) == Some('(');
                if dotted && called {
                    hits.push(format!(".{call}() aborts the engine"));
                }
            }
        }
        for mac in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
            if !boundary_matches(code, mac).is_empty() {
                hits.push(format!("{mac} aborts the engine"));
            }
        }
        for h in literal_index_hits(code) {
            hits.push(format!("indexing by literal `[{h}]` can panic"));
        }
        for msg in hits {
            if !suppressed(lines, i, RULE_HOTPATH) {
                out.push(Finding {
                    rule: RULE_HOTPATH,
                    path: path.to_string(),
                    line: i + 1,
                    msg,
                });
            }
        }
    }
    out
}

/// `expr[<integer literal>]` — an index expression (the char before `[`
/// ends an expression) whose bracket body is digits/underscores only.
fn literal_index_hits(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let chars: Vec<char> = code.chars().collect();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' || i == 0 {
            continue;
        }
        let prev = chars[..i].iter().rev().find(|c| !c.is_whitespace());
        let is_index = matches!(prev, Some(&p) if is_ident(p) || p == ')' || p == ']');
        if !is_index {
            continue;
        }
        let body: String = chars[i + 1..].iter().take_while(|&&c| c != ']').collect();
        if !body.is_empty()
            && chars[i + 1..].iter().any(|&c| c == ']')
            && body.chars().all(|c| c.is_ascii_digit() || c == '_')
        {
            out.push(body);
        }
    }
    out
}

// --------------------------------------------------------------- metrics

/// Extract `singlequant_*` metric names from non-test lines.
fn metric_names(src: &str, skip_tests: bool) -> Vec<(String, usize)> {
    let lines = lex(src);
    let raw: Vec<&str> = src.lines().collect();
    let mut out: Vec<(String, usize)> = Vec::new();
    for (i, text) in raw.iter().enumerate() {
        if skip_tests && lines.get(i).is_some_and(|l| l.in_test) {
            continue;
        }
        for at in boundary_matches(text, "singlequant_") {
            let name: String = text[at..]
                .chars()
                .take_while(|&c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
                .collect();
            let name = name.trim_end_matches('_').to_string();
            if !out.iter().any(|(n, _)| *n == name) {
                out.push((name, i + 1));
            }
        }
    }
    out
}

pub const CATALOGUE_BEGIN: &str = "sqlint:metric-catalogue:begin";
pub const CATALOGUE_END: &str = "sqlint:metric-catalogue:end";

/// Cross-check the names rendered by `coordinator/metrics.rs` against
/// the catalogue block in DESIGN.md (between the `sqlint` markers),
/// both directions. Quantile metrics also render a derived `_count`
/// series at runtime; the catalogue lists base names only.
pub fn lint_metric_names(metrics_src: &str, design_path: &str, design_md: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let begin = design_md.lines().position(|l| l.contains(CATALOGUE_BEGIN));
    let end = design_md.lines().position(|l| l.contains(CATALOGUE_END));
    let (Some(b), Some(e)) = (begin, end) else {
        out.push(Finding {
            rule: RULE_METRICS,
            path: design_path.to_string(),
            line: 1,
            msg: format!("catalogue markers `{CATALOGUE_BEGIN}`/`{CATALOGUE_END}` not found"),
        });
        return out;
    };
    let catalogue_txt: String = design_md
        .lines()
        .take(e)
        .skip(b + 1)
        .collect::<Vec<_>>()
        .join("\n");
    let rendered = metric_names(metrics_src, true);
    let listed = metric_names(&catalogue_txt, false);
    for (name, line) in &rendered {
        if !listed.iter().any(|(n, _)| n == name) {
            out.push(Finding {
                rule: RULE_METRICS,
                path: "src/coordinator/metrics.rs".to_string(),
                line: *line,
                msg: format!("metric `{name}` is rendered but not in the DESIGN.md catalogue"),
            });
        }
    }
    for (name, line) in &listed {
        if !rendered.iter().any(|(n, _)| n == name) {
            out.push(Finding {
                rule: RULE_METRICS,
                path: design_path.to_string(),
                line: b + 1 + *line,
                msg: format!("catalogue lists `{name}` but metrics.rs no longer renders it"),
            });
        }
    }
    out
}

// ---------------------------------------------------------------- envvar

fn env_vars_in(text: &str) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        for at in boundary_matches(line, "SQ_") {
            let name: String = line[at..]
                .chars()
                .take_while(|&c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
                .collect();
            let name = name.trim_end_matches('_').to_string();
            if name.len() > 3 && !out.iter().any(|(n, _)| *n == name) {
                out.push((name, i + 1));
            }
        }
    }
    out
}

/// Every `SQ_*` env var referenced by the CI workflow must appear in
/// rust code (src/tests/benches/examples) — a renamed or removed knob
/// must not leave CI silently exercising nothing.
pub fn lint_env_vars(ci_path: &str, ci_src: &str, sources: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (name, line) in env_vars_in(ci_src) {
        let exists = sources.iter().any(|(_, text)| text.contains(&name));
        if !exists {
            out.push(Finding {
                rule: RULE_ENVVAR,
                path: ci_path.to_string(),
                line,
                msg: format!("env var `{name}` referenced by CI is read nowhere in the rust tree"),
            });
        }
    }
    out
}

// ------------------------------------------------------------- top level

fn scan_patterns(
    path: &str,
    lines: &[Line],
    rule: &'static str,
    patterns: &[&str],
    what: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in patterns {
            if !boundary_matches(&line.code, pat).is_empty() && !suppressed(lines, i, rule) {
                out.push(Finding {
                    rule,
                    path: path.to_string(),
                    line: i + 1,
                    msg: format!("`{pat}`: {what}"),
                });
            }
        }
    }
    out
}

/// Run the per-file rules (`safety`, `thread`, `nondet`, `hotpath`) on
/// one source file. `path` is relative to `rust/` with forward slashes
/// (e.g. `src/kv/pool.rs`) — it selects each rule's scope.
pub fn lint_rust_source(path: &str, src: &str) -> Vec<Finding> {
    let lines = lex(src);
    let mut out = check_safety(path, &lines);
    out.extend(check_thread(path, &lines));
    out.extend(check_nondet(path, &lines));
    out.extend(check_hotpath(path, &lines));
    out
}

/// Everything the whole-tree run needs, gathered by the caller (the
/// binary walks the repo; the self-tests feed fixtures).
#[derive(Default)]
pub struct FileSet {
    /// `(path relative to rust/, contents)` for every `.rs` file.
    pub rust_files: Vec<(String, String)>,
    /// `(display path, contents)` of the CI workflow.
    pub ci_yml: Option<(String, String)>,
    /// `(display path, contents)` of DESIGN.md.
    pub design_md: Option<(String, String)>,
}

/// Gather the [`FileSet`] for a repo checkout: every `.rs` under
/// `rust/{src,tests,benches,examples}` (the lint crate itself and build
/// output are siblings, never walked), the CI workflow, and DESIGN.md.
pub fn gather(root: &std::path::Path) -> std::io::Result<FileSet> {
    let rust_root = root.join("rust");
    let mut fs = FileSet::default();
    for dir in ["src", "tests", "benches", "examples"] {
        collect_rs(&rust_root, &rust_root.join(dir), &mut fs.rust_files)?;
    }
    fs.ci_yml = read_opt(root, ".github/workflows/ci.yml");
    fs.design_md = read_opt(root, "DESIGN.md");
    Ok(fs)
}

fn read_opt(root: &std::path::Path, rel: &str) -> Option<(String, String)> {
    std::fs::read_to_string(root.join(rel)).ok().map(|text| (rel.to_string(), text))
}

fn collect_rs(
    rust_root: &std::path::Path,
    dir: &std::path::Path,
    out: &mut Vec<(String, String)>,
) -> std::io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    let mut entries: Vec<std::path::PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(rust_root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(rust_root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

/// Run every rule over a [`FileSet`].
pub fn lint_all(fs: &FileSet) -> Vec<Finding> {
    let mut out = Vec::new();
    for (path, src) in &fs.rust_files {
        out.extend(lint_rust_source(path, src));
    }
    let metrics_src = fs
        .rust_files
        .iter()
        .find(|(p, _)| p == "src/coordinator/metrics.rs")
        .map(|(_, s)| s.as_str());
    if let (Some(metrics), Some((dp, design))) = (metrics_src, &fs.design_md) {
        out.extend(lint_metric_names(metrics, dp, design));
    }
    if let Some((cp, ci)) = &fs.ci_yml {
        out.extend(lint_env_vars(cp, ci, &fs.rust_files));
    }
    out.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    out
}
