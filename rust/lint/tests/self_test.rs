//! sqlint self-tests: every rule trips on its deliberately-failing
//! fixture, the suppression and scoping machinery behaves, and the
//! real tree is clean (the test-suite twin of `cargo run -p sqlint`).

use std::path::Path;

use sqlint::{gather, lint_all, lint_env_vars, lint_metric_names, lint_rust_source, Finding};

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn safety_fixture_fails() {
    let src = include_str!("../fixtures/fail_safety.rs");
    let f = lint_rust_source("src/tensor/fixture.rs", src);
    assert_eq!(rules(&f), vec!["safety"], "{f:?}");
    assert_eq!(f[0].line, 4);
}

#[test]
fn thread_fixture_fails_outside_pool_only() {
    let src = include_str!("../fixtures/fail_thread.rs");
    let f = lint_rust_source("src/coordinator/worker.rs", src);
    assert_eq!(rules(&f), vec!["thread"], "{f:?}");
    // the same source is legal inside the worker pool itself and in the
    // HTTP layer's I/O threads
    assert!(lint_rust_source("src/tensor/pool.rs", src).is_empty());
    assert!(lint_rust_source("src/server/mod.rs", src).is_empty());
}

#[test]
fn nondet_fixture_fails_outside_allowlist_only() {
    let src = include_str!("../fixtures/fail_nondet.rs");
    let f = lint_rust_source("src/pipeline/mod.rs", src);
    assert_eq!(rules(&f), vec!["nondet"], "{f:?}");
    for allowed in ["src/util/clock.rs", "src/util/bench.rs", "src/server/api.rs"] {
        assert!(lint_rust_source(allowed, src).is_empty(), "{allowed}");
    }
}

#[test]
fn hotpath_fixture_fails_with_both_findings() {
    let src = include_str!("../fixtures/fail_hotpath.rs");
    let f = lint_rust_source("src/kv/fixture.rs", src);
    assert_eq!(rules(&f), vec!["hotpath", "hotpath"], "{f:?}");
    assert!(f[0].msg.contains("unwrap"), "{f:?}");
    assert!(f[1].msg.contains("[0]"), "{f:?}");
    // the same panics are fine outside the hot modules
    assert!(lint_rust_source("src/rotation/art.rs", src).is_empty());
    // the quantization pipeline and calibration joined the panic-free
    // set alongside kv/ and spec/
    for hot in ["src/pipeline/mod.rs", "src/pipeline/fold.rs", "src/calib/mod.rs"] {
        assert_eq!(rules(&lint_rust_source(hot, src)), vec!["hotpath", "hotpath"], "{hot}");
    }
}

#[test]
fn metrics_fixture_fails_both_directions() {
    let code = include_str!("../fixtures/fail_metrics.rs");
    let design = include_str!("../fixtures/fail_metrics_design.md");
    let f = lint_metric_names(code, "DESIGN.md", design);
    assert_eq!(rules(&f), vec!["metrics", "metrics"], "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("singlequant_bogus_total")), "{f:?}");
    assert!(
        f.iter().any(|x| x.msg.contains("singlequant_requests_completed_total")),
        "{f:?}"
    );
}

#[test]
fn metrics_missing_catalogue_markers_is_a_finding() {
    let code = include_str!("../fixtures/fail_metrics.rs");
    let f = lint_metric_names(code, "DESIGN.md", "# no catalogue here\n");
    assert_eq!(rules(&f), vec!["metrics"], "{f:?}");
    assert!(f[0].msg.contains("markers"), "{f:?}");
}

#[test]
fn env_fixture_fails_on_the_unread_var_only() {
    let ci = include_str!("../fixtures/fail_env.yml");
    let sources =
        vec![("src/tensor/simd.rs".to_string(), "std::env::var(\"SQ_KERNEL\")".to_string())];
    let f = lint_env_vars(".github/workflows/ci.yml", ci, &sources);
    assert_eq!(rules(&f), vec!["envvar"], "{f:?}");
    assert!(f[0].msg.contains("SQ_BOGUS_KNOB"), "{f:?}");
}

#[test]
fn inline_suppression_silences_each_rule() {
    let safety = "pub fn f(p: *const u8) -> u8 {\n    \
                  // sqlint: allow(safety) — fixture exercises the marker\n    \
                  unsafe { *p }\n}\n";
    assert!(lint_rust_source("src/tensor/x.rs", safety).is_empty());
    let hot = "pub fn g(v: &[u32]) -> u32 {\n    \
               v.first().copied().unwrap() // sqlint: allow(hotpath) — fixture\n}\n";
    assert!(lint_rust_source("src/kv/x.rs", hot).is_empty());
    let nondet = "pub fn now() -> std::time::Instant {\n    \
                  // sqlint: allow(nondet) — fixture\n    \
                  std::time::Instant::now()\n}\n";
    assert!(lint_rust_source("src/pipeline/x.rs", nondet).is_empty());
}

#[test]
fn safety_accepts_comments_over_attributes_and_impl_groups() {
    let src = "// SAFETY: caller upholds the avx2 contract\n\
               #[target_feature(enable = \"avx2\")]\n\
               pub unsafe fn tile() {}\n\
               \n\
               // SAFETY: both markers only ever hold Send data\n\
               unsafe impl Send for X {}\n\
               unsafe impl Sync for X {}\n";
    assert!(lint_rust_source("src/tensor/x.rs", src).is_empty());
}

#[test]
fn safety_accepts_comment_above_wrapped_assignment() {
    let src = "pub fn f(p: *const u8) -> u8 {\n    \
               // SAFETY: caller guarantees p is valid for reads\n    \
               let v =\n        \
               unsafe { *p };\n    v\n}\n";
    assert!(lint_rust_source("src/tensor/x.rs", src).is_empty());
}

#[test]
fn fn_pointer_types_are_not_unsafe_sites() {
    let src = "pub struct Job {\n    run: unsafe fn(*const (), usize),\n}\n";
    assert!(lint_rust_source("src/tensor/x.rs", src).is_empty());
}

#[test]
fn cfg_test_modules_are_exempt_from_runtime_rules() {
    let src = "pub fn ok() {}\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   #[test]\n\
                   fn t() {\n\
                       let v = vec![1u32];\n\
                       let _ = v.first().unwrap() + v[0];\n\
                       let _ = std::time::Instant::now();\n\
                       std::thread::spawn(|| {}).join().unwrap();\n\
                   }\n\
               }\n";
    assert!(lint_rust_source("src/kv/x.rs", src).is_empty());
}

#[test]
fn unwrap_family_lookalikes_are_not_flagged() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n    \
               v.first().copied().unwrap_or(0) + v.first().copied().unwrap_or_default()\n}\n";
    assert!(lint_rust_source("src/kv/x.rs", src).is_empty());
}

#[test]
fn strings_and_comments_do_not_produce_findings() {
    let src = "pub fn f() -> &'static str {\n    \
               // mentions unwrap() and Instant::now and thread::spawn\n    \
               \"unsafe { panic!(\\\"x[0]\\\") } Instant::now thread::spawn\"\n}\n";
    assert!(lint_rust_source("src/kv/x.rs", src).is_empty(), "{:?}", {
        lint_rust_source("src/kv/x.rs", src)
    });
}

#[test]
fn cleaned_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let fs = gather(&root).expect("walk repo");
    assert!(fs.rust_files.len() > 80, "walker found {} files", fs.rust_files.len());
    let findings = lint_all(&fs);
    let listing: Vec<String> = findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg))
        .collect();
    assert!(findings.is_empty(), "tree has findings:\n{}", listing.join("\n"));
}
