//! Pipeline integration on real trained checkpoints: quality ordering,
//! calibration structure, and the single-pass speed claim.
//! Requires `make artifacts`.

use singlequant::analysis::outliers::site_outlier_stats;
use singlequant::calib::{calib_sequences, run_calibration};
use singlequant::model::forward::forward_score;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::util::sqt::SqtFile;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists()
}

fn load(model: &str) -> (singlequant::model::ModelConfig, Weights, Vec<u16>) {
    let dir = artifacts_dir();
    let engine = singlequant::runtime::Engine::new(&dir).unwrap();
    let cfg = engine.config(model).unwrap();
    let w = Weights::load(&format!("{dir}/ckpt/{model}.sqt")).unwrap();
    let toks = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
        .unwrap()
        .get("tokens")
        .unwrap()
        .as_u16()
        .unwrap()
        .to_vec();
    (cfg, w, toks)
}

#[test]
fn calibration_detects_massive_outliers() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (cfg, w, toks) = load("sq-m");
    let seqs = calib_sequences(&toks, 6, 64, 1);
    let cal = run_calibration(&cfg, &w, &seqs, 1).unwrap();
    // the training fold injects 80-320x massive channels; calibration must
    // see them at the qkv/mlp sites
    let s = site_outlier_stats(&cal, "l00.qkv");
    assert!(s.mo_ratio > 8.0, "MO ratio only {}", s.mo_ratio);
    assert!(s.mo_channels >= 1);
    assert!(s.utilization < 0.5, "activations look too easy: {}", s.utilization);
}

#[test]
fn quality_ordering_singlequant_vs_naive() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Through the Rust quantized reference forward (fast, no PJRT):
    // fidelity to the fp logits must order SingleQuant/QuaRot above RTN.
    let (cfg, w, toks) = load("sq-m");
    let eval: Vec<u16> = toks[5000..5000 + 64].to_vec();
    let fp = forward_score(&cfg, &w, &eval, None, None).unwrap();
    let mut errs = std::collections::BTreeMap::new();
    for (name, method) in [
        ("rtn", Method::Rtn),
        ("quarot", Method::QuaRot),
        ("singlequant", Method::singlequant()),
    ] {
        let opts = PipelineOptions { method, calib_seqs: 6, calib_len: 64, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks, &opts).unwrap();
        let ctx = qm.quant_ctx().unwrap();
        let lg = forward_score(&cfg, &qm.weights, &eval, Some(&ctx), None).unwrap();
        errs.insert(name, lg.mse(&fp));
    }
    assert!(errs["singlequant"] < errs["rtn"],
            "singlequant {} !< rtn {}", errs["singlequant"], errs["rtn"]);
    assert!(errs["quarot"] < errs["rtn"]);
}

#[test]
fn single_pass_speed_claim() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Table 7's core claim at this scale: closed-form construction is
    // much faster than the 100-step learned baseline.
    let (cfg, w, toks) = load("sq-s");
    let t0 = std::time::Instant::now();
    let _ = quantize(&cfg, &w, &toks, &PipelineOptions::default()).unwrap();
    let t_single = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let _ = quantize(&cfg, &w, &toks, &PipelineOptions {
        method: Method::SpinQuant { steps: 100 },
        ..Default::default()
    })
    .unwrap();
    let t_spin = t0.elapsed().as_secs_f64();
    assert!(t_spin > 3.0 * t_single,
            "spin {t_spin:.2}s not much slower than single {t_single:.2}s");
}

#[test]
fn moe_pipeline_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (cfg, w, toks) = load("sq-moe");
    let qm = quantize(&cfg, &w, &toks, &PipelineOptions {
        calib_seqs: 4,
        calib_len: 48,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(qm.rots.len(), cfg.n_layers * 4);
    let eval: Vec<u16> = toks[100..148].to_vec();
    let ctx = qm.quant_ctx().unwrap();
    let lg = forward_score(&cfg, &qm.weights, &eval, Some(&ctx), None).unwrap();
    assert!(lg.data().iter().all(|v| v.is_finite()));
}
