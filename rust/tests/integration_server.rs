//! HTTP front-end integration: concurrent streaming and non-streaming
//! completions against a live `server::serve` instance over real sockets.
//! Runs on the deterministic SyntheticBackend — no artifacts, no PJRT —
//! so this suite exercises the full network path in plain `cargo test`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use singlequant::coordinator::{ServeConfig, ServeEngine, SyntheticBackend};
use singlequant::model::{ModelConfig, NativeModel, Weights};
use singlequant::pipeline::{quantize, PipelineOptions};
use singlequant::runtime::NativeBackend;
use singlequant::server::{serve, ServerConfig};
use singlequant::util::json::Json;
use singlequant::util::rng::Rng;

/// Minimal HTTP/1.1 client: one request, read to EOF (the server closes
/// every connection). Returns (status, head, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String, String) {
    let mut stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))
        .expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let body = body.unwrap_or("");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let (head, payload) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    (status, head.to_string(), payload.to_string())
}

fn completion_body(prompt: &str, max_tokens: usize, stream: bool) -> String {
    Json::obj(vec![
        ("prompt", Json::str(prompt)),
        ("max_tokens", Json::usize(max_tokens)),
        ("stream", Json::bool(stream)),
    ])
    .to_string()
}

/// CI hook: `SQ_SPECULATIVE=K` (optionally `SQ_DRAFT=ngram`) turns
/// speculative decoding on for every server this suite starts. The
/// speculative engine is bit-identical to the plain one by contract,
/// so all assertions must pass unchanged — the CI matrix runs the
/// whole suite under this knob to hold the engines to that.
fn maybe_speculate(engine: &mut ServeEngine) {
    let k: usize = std::env::var("SQ_SPECULATIVE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if k == 0 {
        return;
    }
    match std::env::var("SQ_DRAFT").as_deref() {
        Ok("ngram") | Err(_) => {}
        Ok(other) => panic!("SQ_DRAFT={other:?}: this suite only knows ngram"),
    }
    engine.enable_speculation(k, Box::new(singlequant::spec::NgramDraft::new(3)));
}

fn start_server(
    batch: usize,
    queue_cap: usize,
    delay: Duration,
) -> singlequant::server::ServerHandle {
    let mut engine = ServeEngine::new(
        Box::new(SyntheticBackend::new(batch).with_seq(64, 128).with_delay(delay)),
        ServeConfig { max_new_cap: 16, seed: 11, queue_cap },
    );
    maybe_speculate(&mut engine);
    serve(engine, ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        default_max_tokens: 8,
        default_deadline_ms: None,
        model: "sq-test".to_string(),
    })
    .expect("server starts")
}

#[test]
fn eight_plus_concurrent_mixed_clients() {
    let handle = start_server(4, 32, Duration::from_millis(1));
    let addr = handle.addr();

    let clients: Vec<_> = (0..10)
        .map(|i| {
            std::thread::spawn(move || {
                let streaming = i % 2 == 1;
                let body = completion_body(&format!("hello-{i}"), 6, streaming);
                let (status, head, payload) =
                    http(addr, "POST", "/v1/completions", Some(&body));
                assert_eq!(status, 200, "client {i}: {payload}");
                if streaming {
                    assert!(
                        head.contains("text/event-stream"),
                        "client {i}: not SSE: {head}"
                    );
                    let frames: Vec<&str> = payload
                        .split("\n\n")
                        .filter(|f| !f.is_empty())
                        .map(|f| f.strip_prefix("data: ").expect("data frame"))
                        .collect();
                    assert_eq!(*frames.last().unwrap(), "[DONE]", "client {i}");
                    // 6 token chunks + 1 finishing chunk + [DONE]
                    assert_eq!(frames.len(), 8, "client {i}: {frames:?}");
                    for f in &frames[..6] {
                        let j = Json::parse(f).expect("chunk json");
                        assert_eq!(j.str_at("object").unwrap(), "text_completion.chunk");
                    }
                    let last = Json::parse(frames[6]).unwrap();
                    let choice = &last.get("choices").unwrap().as_arr().unwrap()[0];
                    assert_eq!(choice.str_at("finish_reason").unwrap(), "length");
                } else {
                    let j = Json::parse(&payload).expect("completion json");
                    assert_eq!(j.str_at("object").unwrap(), "text_completion");
                    let choice = &j.get("choices").unwrap().as_arr().unwrap()[0];
                    assert_eq!(choice.str_at("finish_reason").unwrap(), "length");
                    let usage = j.get("usage").unwrap();
                    assert_eq!(usage.usize_at("completion_tokens").unwrap(), 6);
                    assert_eq!(
                        usage.usize_at("prompt_tokens").unwrap(),
                        format!("hello-{i}").len()
                    );
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }

    // health + metrics reflect the traffic (give the scheduler one idle
    // publish cycle so the final tick's snapshot is visible)
    std::thread::sleep(Duration::from_millis(80));
    let (status, _, health) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let h = Json::parse(&health).unwrap();
    assert_eq!(h.str_at("status").unwrap(), "ok");
    assert_eq!(h.str_at("model").unwrap(), "sq-test");

    let (status, _, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("singlequant_requests_completed_total 10"), "{metrics}");
    assert!(metrics.contains("singlequant_ttft_seconds{quantile=\"0.5\"}"));
    assert!(metrics.contains("singlequant_per_token_seconds{quantile=\"0.95\"}"));
    assert!(metrics.contains("singlequant_http_requests_total"));
    assert!(metrics.contains("singlequant_http_streams_opened_total 5"), "{metrics}");

    handle.shutdown();
}

#[test]
fn overload_returns_429_not_hangs() {
    // one slow slot, queue of one: a burst must bounce with 429s
    let handle = start_server(1, 1, Duration::from_millis(30));
    let addr = handle.addr();

    let clients: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let body = completion_body(&format!("burst-{i}"), 4, false);
                let (status, head, _) =
                    http(addr, "POST", "/v1/completions", Some(&body));
                if status == 429 {
                    assert!(head.contains("Retry-After"), "429 must advise retry");
                }
                status
            })
        })
        .collect();
    let statuses: Vec<u16> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    assert!(
        statuses.iter().any(|&s| s == 429),
        "burst of 8 into queue_cap=1 must shed load: {statuses:?}"
    );
    assert!(
        statuses.iter().any(|&s| s == 200),
        "some of the burst must be served: {statuses:?}"
    );
    assert!(
        statuses.iter().all(|&s| s == 200 || s == 429),
        "only 200/429 expected: {statuses:?}"
    );

    let (_, _, metrics) = http(addr, "GET", "/metrics", None);
    let rejected: f64 = metrics
        .lines()
        .find(|l| l.starts_with("singlequant_requests_rejected_total"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let http_429: f64 = metrics
        .lines()
        .find(|l| l.starts_with("singlequant_http_responses_429_total"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    assert!(rejected + http_429 >= 1.0, "rejections must be visible in metrics");

    handle.shutdown();
}

#[test]
fn deadline_cuts_off_with_partial_output() {
    let handle = start_server(1, 8, Duration::from_millis(20));
    let addr = handle.addr();
    let body = Json::obj(vec![
        ("prompt", Json::str("slow")),
        ("max_tokens", Json::usize(16)),
        ("deadline_ms", Json::usize(1)),
    ])
    .to_string();
    let (status, _, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200);
    let j = Json::parse(&payload).unwrap();
    let choice = &j.get("choices").unwrap().as_arr().unwrap()[0];
    assert_eq!(choice.str_at("finish_reason").unwrap(), "deadline");
    assert!(
        j.get("usage").unwrap().usize_at("completion_tokens").unwrap() < 16,
        "deadline must stop generation early"
    );
    handle.shutdown();
}

#[test]
fn malformed_requests_get_4xx() {
    let handle = start_server(2, 8, Duration::ZERO);
    let addr = handle.addr();

    let (status, _, _) = http(addr, "POST", "/v1/completions", Some("not json"));
    assert_eq!(status, 400);
    let (status, _, payload) = http(addr, "POST", "/v1/completions", Some("{}"));
    assert_eq!(status, 400);
    assert!(payload.contains("prompt"));
    let (status, _, _) = http(
        addr,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": "x", "stream": "yes"}"#),
    );
    assert_eq!(status, 400);
    // prompt longer than the lowered prefill width
    let long = "x".repeat(65);
    let (status, _, _) =
        http(addr, "POST", "/v1/completions", Some(&completion_body(&long, 2, false)));
    assert_eq!(status, 400);

    let (status, _, _) = http(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _, _) = http(addr, "DELETE", "/healthz", None);
    assert_eq!(status, 405);

    handle.shutdown();
}

#[test]
fn native_backend_serves_completions_end_to_end() {
    // Quantize a small model with the full SingleQuant pipeline and serve
    // it through the pure-CPU NativeBackend — no PJRT, no xla stub, no
    // artifacts on disk.
    let cfg = ModelConfig::demo();
    let w = Weights::random_init(&cfg, 3);
    let mut rng = Rng::new(13);
    let calib: Vec<u16> = (0..1024).map(|_| rng.below(256) as u16).collect();
    let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
    let qm = quantize(&cfg, &w, &calib, &opts).expect("quantize demo model");
    let model =
        NativeModel::from_quantized(&qm, opts.weight_bits, 2).expect("native model");
    let mut engine = ServeEngine::new(
        Box::new(NativeBackend::new(model, 2)),
        ServeConfig { max_new_cap: 8, seed: 5, queue_cap: 16 },
    );
    maybe_speculate(&mut engine);
    let handle = serve(engine, ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        default_max_tokens: 5,
        default_deadline_ms: None,
        model: "sq-demo-native".to_string(),
    })
    .expect("server starts");
    let addr = handle.addr();

    // non-streaming completion against the quantized model
    let (status, _, payload) = http(
        addr,
        "POST",
        "/v1/completions",
        Some(&completion_body("hello native", 5, false)),
    );
    assert_eq!(status, 200, "{payload}");
    let j = Json::parse(&payload).expect("completion json");
    assert_eq!(j.str_at("object").unwrap(), "text_completion");
    assert_eq!(j.str_at("model").unwrap(), "sq-demo-native");
    // greedy generation may hit EOS early on a random-init model, but the
    // request must complete with a bounded token count
    let done = j.get("usage").unwrap().usize_at("completion_tokens").unwrap();
    assert!(done <= 5, "completion_tokens {done}");

    // streaming completion through the same model
    let (status, head, payload) = http(
        addr,
        "POST",
        "/v1/completions",
        Some(&completion_body("stream me", 4, true)),
    );
    assert_eq!(status, 200);
    assert!(head.contains("text/event-stream"), "not SSE: {head}");
    assert!(payload.trim_end().ends_with("data: [DONE]"), "{payload:?}");

    // the prefill/decode time split surfaces in /metrics
    std::thread::sleep(Duration::from_millis(80));
    let (status, _, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("singlequant_prefill_seconds_total"), "{metrics}");
    assert!(metrics.contains("singlequant_decode_seconds_total"));
    assert!(metrics.contains("singlequant_decode_tokens_per_second"));

    handle.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_work() {
    let handle = start_server(2, 8, Duration::from_millis(15));
    let addr = handle.addr();

    // a request that takes ~8 ticks, launched just before shutdown
    let client = std::thread::spawn(move || {
        http(addr, "POST", "/v1/completions", Some(&completion_body("drain", 8, true)))
    });
    std::thread::sleep(Duration::from_millis(40)); // let it get admitted
    handle.shutdown();

    let (status, _, payload) = client.join().unwrap();
    assert_eq!(status, 200, "in-flight request must finish during drain");
    assert!(payload.trim_end().ends_with("data: [DONE]"), "{payload:?}");

    // the listener is gone: new connections fail
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(300)).is_err(),
        "server must stop accepting after shutdown"
    );
}
