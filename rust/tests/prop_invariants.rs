//! Property-based invariant suite (proptest-lite harness from
//! `util::prop`): the mathematical guarantees the paper's constructions
//! rest on, checked over randomized inputs.

use singlequant::calib::run_calibration_pool;
use singlequant::kv::{BlockPool, KvCache, PageTable, PagedSlot};
use singlequant::model::forward::{forward_score, QuantCtx};
use singlequant::model::{ModelConfig, NativeModel, Weights};
use singlequant::quant::pack::PackedWeight;
use singlequant::quant::repack::RepackedWeight;
use singlequant::quant::{fake_quant_per_channel, fake_quant_per_token, qlevels};
use singlequant::rotation::art::{art_rotation, art_rotation_pure};
use singlequant::rotation::baselines::{duquant_rotation, quarot_rotation};
use singlequant::rotation::givens::{lemma1_givens, map_to_e1};
use singlequant::rotation::hadamard::{fwht_row, hadamard_matrix};
use singlequant::rotation::kronecker::{
    kron_factor, kron_rotate_rows, kron_rotate_weight, kron_sandwich,
};
use singlequant::rotation::singlequant::{build_site_rotation, SingleQuantConfig, SiteProfile};
use singlequant::rotation::urt::{uniform_target, urt_rotation};
use singlequant::tensor::kernels::{
    givens_rotate_rows, givens_rotate_rows_inv, matmul_packed, matmul_packed_with,
    matmul_threaded, matmul_threaded_with,
};
use singlequant::tensor::pool::WorkerPool;
use singlequant::tensor::{decomp, simd, stats, Tensor};
use singlequant::util::prop::{close, ensure, forall};
use singlequant::util::rng::Rng;

fn rand_profile(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = rng.normal_vec(n, 1.0);
    // sprinkle massive outliers
    for _ in 0..1 + rng.below(3) {
        let i = rng.below(n);
        v[i] = (20.0 + 200.0 * rng.f32()) * if rng.f32() < 0.5 { -1.0 } else { 1.0 };
    }
    v
}

// ---------------------------------------------------------------------------
// Lemma 1 + Givens chains
// ---------------------------------------------------------------------------

#[test]
fn prop_lemma1_minimizes_infinity_norm() {
    forall("lemma1", 200, 11, |rng| (rng.normal_f32() * 50.0, rng.normal_f32() * 50.0),
           |&(a, b)| {
        let r = (a * a + b * b).sqrt();
        if r < 1e-3 {
            return Ok(());
        }
        let mut v = vec![a, b];
        lemma1_givens(&v.clone(), 0, 1).apply_row(&mut v);
        let target = r / 2f32.sqrt();
        ensure((v[0].abs() - target).abs() < 1e-2 * target.max(1.0),
               format!("pair not balanced: {v:?} target {target}"))?;
        ensure(v.iter().fold(0f32, |m, x| m.max(x.abs())) <= target * 1.001 + 1e-4,
               "infinity norm above the Lemma-1 optimum")
    });
}

#[test]
fn prop_map_to_e1_norm_and_zeroing() {
    forall("map_to_e1", 100, 13, |rng| { let n = 2 + rng.below(60); rng.normal_vec(n, 2.0) },
           |v| {
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let chain = map_to_e1(v);
        let mut w = v.clone();
        chain.apply_row(&mut w);
        ensure((w[0] - norm).abs() < 2e-3 * norm.max(1.0), "head not the norm")?;
        for &x in &w[1..] {
            ensure(x.abs() < 2e-3 * norm.max(1.0), "tail not zeroed")?;
        }
        ensure(chain.len() <= v.len() - 1, "more than n-1 rotations")
    });
}

// ---------------------------------------------------------------------------
// ART / URT / composed rotation orthogonality + semantics
// ---------------------------------------------------------------------------

#[test]
fn prop_art_orthogonal_and_reduces_max() {
    forall("art", 60, 17, |rng| {
        let n = 4 + rng.below(28);
        (rand_profile(rng, n), rng.next_u64())
    }, |(v, seed)| {
        let mut rng = Rng::new(*seed);
        let res = art_rotation(v, 1 + (seed % 4) as usize, &mut rng);
        ensure(res.rotation.orthogonality_defect() < 5e-3,
               format!("defect {}", res.rotation.orthogonality_defect()))?;
        let before = v.iter().fold(0f32, |m, x| m.max(x.abs()));
        let after = res.profile_after.iter().fold(0f32, |m, x| m.max(x.abs()));
        ensure(after <= before * 1.01, format!("max grew {before} -> {after}"))
    });
}

#[test]
fn prop_art_pure_never_increases_infinity_norm_stepwise() {
    forall("art_pure", 80, 19, |rng| { let n = 6 + rng.below(20); rand_profile(rng, n) }, |v| {
        let r1 = art_rotation_pure(v, 1);
        let r5 = art_rotation_pure(v, 5);
        let m = |p: &[f32]| p.iter().fold(0f32, |m, x| m.max(x.abs()));
        ensure(m(&r5.profile_after) <= m(&r1.profile_after) + 1e-3,
               "multi-step worse than single")
    });
}

#[test]
fn prop_urt_exact_mapping_and_rank_preservation() {
    forall("urt", 60, 23, |rng| { let n = 3 + rng.below(40); rand_profile(rng, n) }, |v| {
        let res = urt_rotation(v);
        ensure(res.rotation.orthogonality_defect() < 5e-3, "not orthogonal")?;
        let got = Tensor::from_raw(vec![1, v.len()], v.clone())
            .matmul(&res.rotation)
            .into_data();
        let scale = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1.0);
        for (g, t) in got.iter().zip(&res.target) {
            ensure((g - t).abs() < 5e-3 * scale, "V R^U != U")?;
        }
        // rank preservation
        ensure(stats::argsort(v) == stats::argsort(&res.target),
               "target does not preserve ranks")
    });
}

#[test]
fn prop_uniform_target_norm_preserving() {
    forall("uniform_target", 100, 29, |rng| { let n = 2 + rng.below(64); rng.normal_vec(n, 3.0) },
           |v| {
        let u = uniform_target(v);
        let nv = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nu = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        ensure((nv - nu).abs() < 1e-3 * nv.max(1.0), format!("{nv} vs {nu}"))
    });
}

#[test]
fn prop_composed_rotation_orthogonal_all_ablations() {
    forall("composer", 24, 31, |rng| {
        let n = [24usize, 48, 64, 96][rng.below(4)];
        let sa = rand_profile(rng, n);
        let med = rng.normal_vec(n, 0.4);
        (n, sa, med, rng.next_u64())
    }, |(n, sa, med, seed)| {
        for (art, urt) in [(false, false), (true, false), (false, true), (true, true)] {
            let cfg = SingleQuantConfig {
                use_art: art,
                use_urt: urt,
                seed: *seed,
                ..Default::default()
            };
            let profile = SiteProfile {
                n: *n,
                signed_absmax: sa.clone(),
                median: med.clone(),
            };
            let rot = build_site_rotation(&profile, &cfg);
            ensure(rot.defect() < 5e-3,
                   format!("art={art} urt={urt} defect {}", rot.defect()))?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Kronecker algebra
// ---------------------------------------------------------------------------

#[test]
fn prop_kron_factor_postconditions() {
    forall("kron_factor", 300, 37, |rng| 1 + rng.below(4096), |&n| {
        let (n1, n2) = kron_factor(n);
        ensure(n1 * n2 == n, "product mismatch")?;
        ensure(n2.is_power_of_two(), "n2 not a power of two")?;
        let root = (n as f64).sqrt();
        for k in 0..13 {
            let a = 1usize << k;
            if a <= n && n % a == 0 {
                ensure((n2 as f64 - root).abs() <= (a as f64 - root).abs() + 1e-9,
                       format!("n={n}: {a} closer to sqrt than {n2}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kron_rotation_preserves_product() {
    forall("kron_product", 30, 41, |rng| {
        let n1 = 2 + rng.below(6);
        let n2 = 2 + rng.below(6);
        let c = 1 + rng.below(8);
        let t = 1 + rng.below(10);
        let r1 = decomp::random_orthogonal(n1, rng);
        let r2 = decomp::random_orthogonal(n2, rng);
        let x = Tensor::randn(&[t, n1 * n2], 1.0, rng);
        let w = Tensor::randn(&[n1 * n2, c], 0.5, rng);
        (r1, r2, x, w)
    }, |(r1, r2, x, w)| {
        let y_ref = x.matmul(w);
        let xr = kron_rotate_rows(x, r1, r2);
        let wr = kron_rotate_weight(w, r1, r2);
        let y = xr.matmul(&wr);
        let scale = y_ref.max_abs().max(1.0);
        ensure(y.sub(&y_ref).max_abs() / scale < 5e-3,
               format!("Eq.1 violated by {}", y.sub(&y_ref).max_abs()))
    });
}

#[test]
fn prop_kron_sandwich_matches_dense_sandwich() {
    // (r1 ⊗ r2)ᵀ H (r1 ⊗ r2) via the reshaped two-sided small matmuls must
    // agree with the materialized kron — odd factors, non-square splits,
    // and the degenerate 1-sized axes included.
    forall("kron-sandwich", 30, 0x5179, |rng| {
        let n1 = 1 + rng.below(7);
        let n2 = 1 + rng.below(7);
        let n = n1 * n2;
        let r1 = decomp::random_orthogonal(n1, rng);
        let r2 = decomp::random_orthogonal(n2, rng);
        // SPD Hessian shape, like the calibration Gram it stands in for
        let x = Tensor::randn(&[n + 3, n], 1.0, rng);
        (r1, r2, x.matmul_tn(&x))
    }, |(r1, r2, h)| {
        let fast = kron_sandwich(h, r1, r2);
        let r = r1.kron(r2);
        let dense = r.transpose().matmul(&h.matmul(&r));
        let tol = 1e-5 * dense.max_abs().max(1.0);
        ensure(fast.sub(&dense).max_abs() <= tol,
               format!("sandwich off by {} (tol {tol})", fast.sub(&dense).max_abs()))
    });
}

// ---------------------------------------------------------------------------
// Hadamard
// ---------------------------------------------------------------------------

#[test]
fn prop_fwht_matches_matrix_and_is_involution() {
    forall("fwht", 50, 43, |rng| {
        let n = 1usize << (1 + rng.below(6));
        (rng.normal_vec(n, 1.5), n)
    }, |(v, n)| {
        let h = hadamard_matrix(*n);
        let expect = Tensor::from_raw(vec![1, *n], v.clone()).matmul(&h);
        let mut got = v.clone();
        fwht_row(&mut got);
        for i in 0..*n {
            ensure((got[i] - expect.data()[i]).abs() < 1e-3, "fwht != H")?;
        }
        fwht_row(&mut got);
        for i in 0..*n {
            ensure((got[i] - v[i]).abs() < 1e-3, "H(Hx) != x")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Quantizers / packing
// ---------------------------------------------------------------------------

#[test]
fn prop_fake_quant_on_grid_and_bounded() {
    forall("fq_token", 60, 47, |rng| {
        let t = 1 + rng.below(12);
        let n = 2 + rng.below(40);
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        (Tensor::randn(&[t, n], 2.0, rng), bits)
    }, |(x, bits)| {
        let q = fake_quant_per_token(x, *bits, 1.0);
        let (qmin, qmax) = qlevels(*bits);
        for i in 0..x.rows() {
            let absmax = x.row(i).iter().fold(0f32, |m, &v| m.max(v.abs()));
            let scale = (absmax / qmax).max(1e-8);
            for &v in q.row(i) {
                let k = v / scale;
                ensure((k - k.round()).abs() < 2e-2, "off grid")?;
                ensure(k.round() >= qmin && k.round() <= qmax, "out of range")?;
            }
            for (a, b) in x.row(i).iter().zip(q.row(i)) {
                ensure((a - b).abs() <= scale * 0.51 + 1e-6, "error above half-step")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pack_roundtrip_exact() {
    forall("pack", 40, 53, |rng| {
        let n = 1 + rng.below(40);
        let c = 1 + rng.below(24);
        let bits = [3u32, 4, 8][rng.below(3)];
        (Tensor::randn(&[n, c], 0.8, rng), bits)
    }, |(w, bits)| {
        let packed = PackedWeight::pack(w, *bits).map_err(|e| e.to_string())?;
        let deq = packed.unpack();
        let reference = fake_quant_per_channel(w, *bits, 1.0);
        ensure(deq.sub(&reference).max_abs() < 1e-5, "pack != fake-quant")?;
        if w.len() >= 64 && *bits <= 4 {
            // headers/scales amortize away on real layer sizes
            ensure(packed.nbytes() * 2 < w.len() * 4, "no compression")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Baseline rotations
// ---------------------------------------------------------------------------

#[test]
fn prop_baseline_rotations_orthogonal() {
    forall("baselines", 30, 59, |rng| {
        let n = [16usize, 24, 48, 96][rng.below(4)];
        (rand_profile(rng, n), n, rng.next_u64())
    }, |(prof, n, seed)| {
        ensure(quarot_rotation(*n, *seed).defect() < 5e-3, "quarot")?;
        ensure(duquant_rotation(prof, 8, *seed).defect() < 5e-3, "duquant")
    });
}

// ---------------------------------------------------------------------------
// Smoothing end-to-end
// ---------------------------------------------------------------------------

#[test]
fn prop_singlequant_rotation_improves_outlier_quantization() {
    forall("sq_improves", 12, 61, |rng| {
        let n = [48usize, 64, 96][rng.below(3)];
        let t = 48 + rng.below(64);
        let mut x = Tensor::randn(&[t, n], 1.0, rng);
        let c1 = rng.below(n);
        let mut c2 = rng.below(n);
        if c2 == c1 {
            c2 = (c2 + 1) % n;
        }
        let m1 = 15.0 + 45.0 * rng.f32();
        let m2 = 10.0 + 30.0 * rng.f32();
        for i in 0..t {
            x.row_mut(i)[c1] = m1 * (0.7 + 0.6 * rng.f32());
            x.row_mut(i)[c2] = -m2 * (0.7 + 0.6 * rng.f32());
        }
        x
    }, |x| {
        // Functional metric: quantized layer-output error against the
        // unquantized product (the norm-relative elementwise error is
        // dominated by the outlier coordinates themselves and misleads).
        let mut rng = Rng::new(97);
        let w = Tensor::randn(&[x.cols(), 32], 0.5, &mut rng);
        let y_ref = x.matmul(&w);
        let e0 = fake_quant_per_token(x, 4, 1.0).matmul(&w).sub(&y_ref).frob_norm()
            / y_ref.frob_norm().max(1e-9);
        let profile = SiteProfile {
            n: x.cols(),
            signed_absmax: stats::col_signed_absmax(x),
            median: stats::col_median(x),
        };
        let rot = build_site_rotation(&profile, &SingleQuantConfig::default());
        let xr = kron_rotate_rows(x, &rot.r1, &rot.r2);
        let wr = kron_rotate_weight(&w, &rot.r1, &rot.r2);
        let e1 = fake_quant_per_token(&xr, 4, 1.0).matmul(&wr).sub(&y_ref).frob_norm()
            / y_ref.frob_norm().max(1e-9);
        ensure(e1 < 0.85 * e0, format!("no improvement: {e1} vs {e0}"))
    });
}

// ---------------------------------------------------------------------------
// Native serving kernels (tensor::kernels + quant::repack + model::native)
// ---------------------------------------------------------------------------

#[test]
fn prop_matmul_packed_matches_dequantize_then_matmul() {
    // The ISSUE-2 kernel contract: fused dequant-in-inner-loop matmul agrees
    // with dequantize-then-f32-matmul within 1e-4 relative tolerance across
    // bits 2..=8, odd shapes, arbitrary scale groups, and thread counts.
    forall("matmul-packed", 40, 0x5171, |rng| {
        let bits = 2 + rng.below(7) as u32; // 2..=8
        let k = 3 + rng.below(40);
        let n = 1 + rng.below(24);
        let m = 1 + rng.below(6);
        let group = 1 + rng.below(k);
        let w = Tensor::randn(&[k, n], 0.7, rng);
        let x = Tensor::randn(&[m, k], 1.0, rng);
        (bits, group, w, x, 1 + rng.below(4))
    }, |(bits, group, w, x, threads)| {
        let rw = RepackedWeight::pack(w, *bits, *group).map_err(|e| e.to_string())?;
        let reference = x.matmul(&rw.dequantize());
        let got = matmul_packed(x, &rw, *threads);
        for (i, (a, b)) in got.data().iter().zip(reference.data()).enumerate() {
            ensure(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                format!("elem {i}: {a} vs {b} (bits {bits} group {group})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_threaded_matmul_is_bit_identical_to_reference() {
    forall("matmul-threaded", 30, 0x5172, |rng| {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(48);
        let n = 1 + rng.below(48);
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        (a, b, 1 + rng.below(6))
    }, |(a, b, threads)| {
        let reference = a.matmul(b);
        let got = matmul_threaded(a, b, *threads);
        ensure(got.data() == reference.data(),
               format!("threaded matmul diverged at {threads} threads"))
    });
}

#[test]
fn prop_givens_chain_rows_match_dense_rotation() {
    forall("givens-rows", 40, 0x5173, |rng| {
        let n = 2 + rng.below(30);
        let chain = map_to_e1(&rng.normal_vec(n, 1.0));
        let x = Tensor::randn(&[1 + rng.below(8), n], 1.0, rng);
        (chain, x, 1 + rng.below(4))
    }, |(chain, x, threads)| {
        let dense = x.matmul(&chain.to_matrix(x.cols()));
        let mut got = x.clone();
        givens_rotate_rows(&mut got, chain, *threads);
        close(got.data(), dense.data(), 1e-3)
    });
}

#[test]
fn prop_givens_inverse_rows_match_transpose_and_are_lane_invariant() {
    // The URT fast path's second half: applying a chain's inverse
    // (reversed transposed plane rotations) equals the dense Rᵀ matmul,
    // forward-then-inverse is the identity, and — the determinism
    // contract — the thread count never changes a single bit.
    forall("givens-inv-rows", 40, 0x5177, |rng| {
        let n = 2 + rng.below(30);
        let chain = map_to_e1(&rng.normal_vec(n, 1.0));
        let x = Tensor::randn(&[1 + rng.below(8), n], 1.0, rng);
        (chain, x, 1 + rng.below(6))
    }, |(chain, x, threads)| {
        let dense = x.matmul(&chain.to_matrix(x.cols()).transpose());
        let mut inv = x.clone();
        givens_rotate_rows_inv(&mut inv, chain, *threads);
        close(inv.data(), dense.data(), 1e-3)?;

        let mut rt = x.clone();
        givens_rotate_rows(&mut rt, chain, *threads);
        givens_rotate_rows_inv(&mut rt, chain, *threads);
        close(rt.data(), x.data(), 1e-3)?;

        let mut serial_inv = x.clone();
        givens_rotate_rows_inv(&mut serial_inv, chain, 1);
        ensure(serial_inv.data() == inv.data(),
               format!("inverse rows diverged at {threads} threads"))?;
        let mut serial_fwd = x.clone();
        givens_rotate_rows(&mut serial_fwd, chain, 1);
        let mut fwd = x.clone();
        givens_rotate_rows(&mut fwd, chain, *threads);
        ensure(serial_fwd.data() == fwd.data(),
               format!("forward rows diverged at {threads} threads"))
    });
}

#[test]
fn prop_simd_packed_matmul_matches_scalar_kernel() {
    // The ISSUE-7 microkernel contract: the best SIMD kernel agrees with
    // the scalar kernel within the 1e-4 dequant tolerance on every packed
    // shape, bit width, and scale-group layout. Trivially green on
    // machines where best() == Scalar.
    forall("simd-packed", 40, 0x5175, |rng| {
        let bits = 2 + rng.below(7) as u32; // 2..=8
        let k = 3 + rng.below(48);
        let n = 1 + rng.below(24);
        let m = 1 + rng.below(6);
        let group = 1 + rng.below(k);
        let w = Tensor::randn(&[k, n], 0.7, rng);
        let x = Tensor::randn(&[m, k], 1.0, rng);
        (bits, group, w, x, 1 + rng.below(4))
    }, |(bits, group, w, x, threads)| {
        let rw = RepackedWeight::pack(w, *bits, *group).map_err(|e| e.to_string())?;
        let scalar = matmul_packed_with(simd::Kernel::Scalar, x, &rw, *threads);
        let vector = matmul_packed_with(simd::best(), x, &rw, *threads);
        for (i, (a, b)) in vector.data().iter().zip(scalar.data()).enumerate() {
            ensure(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                format!("elem {i}: simd {a} vs scalar {b} (bits {bits} group {group})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_simd_dense_matmul_is_bit_identical_to_scalar() {
    // Dense tier of the determinism contract: kernel choice never changes
    // a single bit of an f32 matmul.
    forall("simd-dense", 30, 0x5176, |rng| {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(48);
        let n = 1 + rng.below(48);
        let mut a = Tensor::randn(&[m, k], 1.0, rng);
        // exercise the kernels' zero-skip on a sparse stripe
        for i in 0..a.len() / 7 {
            a.data_mut()[i * 7] = 0.0;
        }
        let b = Tensor::randn(&[k, n], 1.0, rng);
        (a, b, 1 + rng.below(6))
    }, |(a, b, threads)| {
        let scalar = matmul_threaded_with(simd::Kernel::Scalar, a, b, *threads);
        let vector = matmul_threaded_with(simd::best(), a, b, *threads);
        ensure(scalar.data() == vector.data(),
               "dense matmul bits differ between kernels")
    });
}

#[test]
fn prop_pool_calibration_is_bit_identical_across_lanes() {
    // The stage-1 determinism contract: per-sequence traces fan out over
    // any number of pool lanes, but the fixed-order reduction makes the
    // statistics (absmax, Hessian, reservoir, counters) bit-equal to the
    // single-lane run — including 1-sequence and remainder-chunk shapes.
    let cfg = ModelConfig::demo();
    let w = Weights::random_init(&cfg, 3);
    forall("calib-lanes", 6, 0x5178, |rng| {
        let n_seqs = 1 + rng.below(5);
        let seqs: Vec<Vec<u16>> = (0..n_seqs)
            .map(|_| (0..8 + rng.below(16)).map(|_| rng.below(260) as u16).collect())
            .collect();
        (seqs, 2 + rng.below(7), rng.next_u64())
    }, |(seqs, lanes, seed)| {
        let serial = run_calibration_pool(&cfg, &w, seqs, *seed, true, &WorkerPool::new(1))
            .map_err(|e| e.to_string())?;
        let par = run_calibration_pool(&cfg, &w, seqs, *seed, true, &WorkerPool::new(*lanes))
            .map_err(|e| e.to_string())?;
        ensure(serial.n_tokens == par.n_tokens && serial.n_sequences == par.n_sequences,
               "corpus counters diverge")?;
        for (key, a) in &serial.sites {
            let b = &par.sites[key];
            ensure(a.token_count == b.token_count, format!("{key}: token_count"))?;
            ensure(a.signed_absmax.len() == b.signed_absmax.len()
                       && a.signed_absmax.iter().zip(&b.signed_absmax)
                              .all(|(x, y)| x.to_bits() == y.to_bits()),
                   format!("{key}: absmax bits diverge at {lanes} lanes"))?;
            ensure(a.hessian.shape() == b.hessian.shape()
                       && a.hessian.data().iter().zip(b.hessian.data())
                              .all(|(x, y)| x.to_bits() == y.to_bits()),
                   format!("{key}: hessian bits diverge at {lanes} lanes"))?;
            ensure(a.sample.shape() == b.sample.shape()
                       && a.sample.data().iter().zip(b.sample.data())
                              .all(|(x, y)| x.to_bits() == y.to_bits()),
                   format!("{key}: reservoir bits diverge at {lanes} lanes"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_kv_cached_decode_matches_full_forward_exactly() {
    // The ISSUE-2 decode contract: prefill a prefix, decode the rest token
    // by token — every logits row equals the full-sequence reference
    // forward bit-for-bit, on both the fp and the fake-quant path.
    let cfg = ModelConfig::demo();
    let w = Weights::random_init(&cfg, 5);
    let ctx = QuantCtx::identity(&cfg, 4);
    let nm_fp = NativeModel::from_weights(&cfg, &w, None, 2).unwrap();
    let nm_q = NativeModel::from_weights(&cfg, &w, Some(ctx.clone()), 2).unwrap();
    forall("kv-decode-exact", 6, 0x5174, |rng| {
        let t = 2 + rng.below(10);
        let plen = 1 + rng.below(t - 1);
        let toks: Vec<u16> = (0..t).map(|_| rng.below(260) as u16).collect();
        (toks, plen)
    }, |(toks, plen)| {
        for (nm, quant) in [(&nm_fp, None), (&nm_q, Some(&ctx))] {
            let full = forward_score(&cfg, &w, toks, quant, None)
                .map_err(|e| e.to_string())?;
            let mut kv = nm.new_kv();
            let pre = nm.prefill(&mut kv, &toks[..*plen]).map_err(|e| e.to_string())?;
            for i in 0..*plen {
                ensure(pre.row(i) == full.row(i),
                       format!("prefill row {i} diverged"))?;
            }
            for i in *plen..toks.len() {
                let row = nm.decode(&mut kv, toks[i]).map_err(|e| e.to_string())?;
                ensure(row.as_slice() == full.row(i),
                       format!("decode row {i} diverged"))?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Paged KV block pool: exact page conservation under random churn
// ---------------------------------------------------------------------------

/// Random reserve/advance/truncate/release churn over several slots of
/// one shared pool: after every operation each page is either on the
/// free list or held by exactly one slot's table, a failed reserve is
/// all-or-nothing (no pages move, the table does not grow), and a full
/// drain returns the pool to pristine. With `--features audit` the
/// pool's internal conservation auditor re-checks the same law from its
/// own outstanding-page counter after every step.
#[test]
fn prop_block_pool_conserves_pages_under_churn() {
    forall("block_pool_churn", 60, 29, |rng| {
        (1 + rng.below(3), 1 + rng.below(5), 2 + rng.below(14), rng.next_u64())
    }, |&(pt, slots, pages, seed)| {
        let mut pool = BlockPool::new(1, 4, pt, pages);
        let mut tables: Vec<PageTable> = (0..slots).map(|_| PageTable::new()).collect();
        let mut rng = Rng::new(seed);
        for step in 0..120 {
            let s = rng.below(slots);
            match rng.below(4) {
                0 | 1 => {
                    // grow + commit; exhaustion must change nothing
                    let extra = 1 + rng.below(2 * pt);
                    let free_before = pool.pages_free();
                    let held_before = tables[s].n_pages();
                    let grew = {
                        let mut slot = PagedSlot { pool: &mut pool, table: &mut tables[s] };
                        let ok = slot.reserve(extra).is_ok();
                        if ok {
                            slot.advance(extra);
                        }
                        ok
                    };
                    if !grew {
                        ensure(pool.pages_free() == free_before,
                               format!("step {step}: failed reserve moved pages"))?;
                        ensure(tables[s].n_pages() == held_before,
                               format!("step {step}: failed reserve grew the table"))?;
                    }
                }
                2 => {
                    // speculative-rollback-style truncate to a random prefix
                    let keep = rng.below(tables[s].pos() + 1);
                    tables[s].truncate(&mut pool, keep);
                    ensure(tables[s].pos() == keep,
                           format!("step {step}: truncate missed the target"))?;
                }
                _ => {
                    // retire/preempt: every page back to the free list
                    tables[s].release(&mut pool);
                    ensure(tables[s].n_pages() == 0 && tables[s].pos() == 0,
                           format!("step {step}: release left slot state behind"))?;
                }
            }
            let held: usize = tables.iter().map(|t| t.n_pages()).sum();
            ensure(held + pool.pages_free() == pool.pages_total(),
                   format!("step {step}: {held} held + {} free != {} total",
                           pool.pages_free(), pool.pages_total()))?;
            ensure(pool.pages_used() == held,
                   format!("step {step}: pool used-count disagrees with tables"))?;
            for (i, t) in tables.iter().enumerate() {
                ensure(t.pos() <= t.capacity(&pool),
                       format!("step {step}: slot {i} pos beyond reserved capacity"))?;
            }
            #[cfg(feature = "audit")]
            pool.audit_conservation();
        }
        for t in tables.iter_mut() {
            t.release(&mut pool);
        }
        ensure(pool.pages_free() == pool.pages_total(), "drained pool must be pristine")
    });
}
