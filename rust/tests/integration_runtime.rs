//! Integration: AOT HLO artifacts executed through PJRT must agree with
//! the pure-Rust reference forward on the real trained checkpoints, for
//! both fp and quantized graphs — the wire that holds the three layers
//! together. Requires `make artifacts`.

use std::sync::Arc;

use singlequant::coordinator::tokenizer::PAD;
use singlequant::model::forward::forward_score;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::sqt::SqtFile;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists()
}

fn corpus_tokens() -> Vec<u16> {
    let f = SqtFile::load(&format!("{}/data/corpus_wiki_eval.sqt", artifacts_dir()))
        .expect("corpus");
    f.get("tokens").unwrap().as_u16().unwrap().to_vec()
}

#[test]
fn fp_graph_matches_rust_reference() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
    let cfg = engine.config("sq-s").unwrap();
    let weights = Weights::load(&format!("{}/ckpt/sq-s.sqt", artifacts_dir())).unwrap();
    weights.validate(&cfg).unwrap();

    let toks = corpus_tokens();
    let opts = PipelineOptions { method: Method::Fp16, ..Default::default() };
    let qm = quantize(&cfg, &weights, &toks, &opts).unwrap();
    let runner = ModelRunner::new(engine, &qm).unwrap();

    let seq: Vec<u16> = toks[100..100 + 40].to_vec();
    let via_pjrt = &runner.score_many(&[seq.clone()]).unwrap()[0];
    let via_rust = forward_score(&cfg, &weights, &seq, None, None).unwrap();
    let scale = via_rust.max_abs().max(1.0);
    let mut worst = 0.0f32;
    for p in 0..seq.len() {
        for v in 0..cfg.vocab_size {
            worst = worst.max((via_pjrt.at(p, v) - via_rust.at(p, v)).abs());
        }
    }
    assert!(worst / scale < 2e-3, "fp mismatch {worst} (scale {scale})");
}

#[test]
fn w4a4_graph_matches_rust_quant_forward() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
    let cfg = engine.config("sq-s").unwrap();
    let weights = Weights::load(&format!("{}/ckpt/sq-s.sqt", artifacts_dir())).unwrap();
    let toks = corpus_tokens();
    let opts = PipelineOptions {
        method: Method::singlequant(),
        calib_seqs: 4,
        calib_len: 48,
        ..Default::default()
    };
    let qm = quantize(&cfg, &weights, &toks, &opts).unwrap();
    let ctx = qm.quant_ctx().unwrap();

    let seq: Vec<u16> = toks[500..500 + 32].to_vec();
    let via_rust = forward_score(&cfg, &qm.weights, &seq, Some(&ctx), None).unwrap();

    let runner = ModelRunner::new(engine, &qm).unwrap();
    let via_pjrt = &runner.score_many(&[seq.clone()]).unwrap()[0];

    let scale = via_rust.max_abs().max(1.0);
    let mut worst = 0.0f32;
    for p in 0..seq.len() {
        for v in 0..cfg.vocab_size {
            worst = worst.max((via_pjrt.at(p, v) - via_rust.at(p, v)).abs());
        }
    }
    // fake-quant thresholds can flip under f32 reassociation; tolerate a
    // small relative gap.
    assert!(worst / scale < 5e-2, "w4a4 mismatch {worst} (scale {scale})");
}

#[test]
fn decode_path_matches_score_path() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
    let cfg = engine.config("sq-m").unwrap();
    let weights = Weights::load(&format!("{}/ckpt/sq-m.sqt", artifacts_dir())).unwrap();
    let toks = corpus_tokens();
    let opts = PipelineOptions { method: Method::Fp16, ..Default::default() };
    let qm = quantize(&cfg, &weights, &toks, &opts).unwrap();
    let runner = ModelRunner::new(engine, &qm).unwrap();

    let t = cfg.score_seq;
    let seq: Vec<u16> = toks[20..20 + 24].to_vec();
    let score = &runner.score_many(&[seq.clone()]).unwrap()[0];

    // prefill the first 16 tokens, decode the rest one by one (batch 4,
    // only slot 0 populated)
    let batch = 4;
    let mut ptoks = vec![PAD as i32; batch * t];
    for (j, &tok) in seq[..16].iter().enumerate() {
        ptoks[j] = tok as i32;
    }
    let (plogits, mut kv) = runner.prefill(batch, &ptoks).unwrap();
    // prefill logits at position 15 must match the score graph
    let v = cfg.vocab_size;
    for vi in 0..v {
        let a = plogits.data()[15 * v + vi]; // row 0, pos 15
        let b = score.at(15, vi);
        assert!((a - b).abs() < 2e-2 * score.max_abs().max(1.0),
                "prefill logit mismatch at {vi}: {a} vs {b}");
    }
    for pos in 16..24 {
        let mut toks_step = vec![PAD as i32; batch];
        toks_step[0] = seq[pos] as i32;
        let mut positions = vec![0i32; batch];
        positions[0] = pos as i32;
        let logits = runner.decode(&mut kv, &toks_step, &positions).unwrap();
        for vi in 0..v {
            let a = logits.at(0, vi);
            let b = score.at(pos, vi);
            assert!((a - b).abs() < 5e-2 * score.max_abs().max(1.0),
                    "decode logit mismatch at pos {pos}, vocab {vi}: {a} vs {b}");
        }
    }
}
