//! Serving-coordinator integration: continuous batching over the real
//! quantized W4A4 graphs. Exercises admission, mixed prompt lengths,
//! mid-flight joins, retirement, and the generation quality of the
//! end-to-end path. Requires `make artifacts`.

use std::sync::Arc;

use singlequant::coordinator::tokenizer::{decode, encode};
use singlequant::coordinator::{Request, ServeConfig, ServeEngine, TokenEvent};
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner, RunnerBackend};
use singlequant::util::sqt::SqtFile;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

/// Collect finished responses out of a tick's event stream.
fn responses_of(events: Vec<TokenEvent>) -> Vec<singlequant::coordinator::Response> {
    events
        .into_iter()
        .filter_map(|ev| match ev {
            TokenEvent::Done { response, .. } => Some(response),
            _ => None,
        })
        .collect()
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists()
}

fn make_engine(method: Method, batch: usize) -> (ServeEngine, Vec<u16>) {
    let dir = artifacts_dir();
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let cfg = engine.config("sq-m").unwrap();
    let weights = Weights::load(&format!("{dir}/ckpt/sq-m.sqt")).unwrap();
    let corpus = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
        .unwrap()
        .get("tokens")
        .unwrap()
        .as_u16()
        .unwrap()
        .to_vec();
    let qm = quantize(&cfg, &weights, &corpus, &PipelineOptions {
        method,
        calib_seqs: 4,
        calib_len: 48,
        ..Default::default()
    })
    .unwrap();
    let runner = Arc::new(ModelRunner::new(engine, &qm).unwrap());
    (
        ServeEngine::new(
            Box::new(RunnerBackend::new(runner, batch)),
            ServeConfig { max_new_cap: 16, seed: 3, ..Default::default() },
        ),
        corpus,
    )
}

#[test]
fn serves_more_requests_than_slots() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (mut serve, corpus) = make_engine(Method::Fp16, 4);
    // 10 requests through 4 slots with assorted prompt lengths
    for id in 0..10u64 {
        let start = 37 * id as usize % (corpus.len() - 80);
        let len = 8 + (id as usize * 7) % 40;
        serve.submit(
            Request::new(id, corpus[start..start + len].to_vec())
                .with_max_new(4 + (id as usize % 8)),
        );
    }
    let responses = serve.run_to_completion().unwrap();
    assert_eq!(responses.len(), 10);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<_>>());
    for r in &responses {
        assert!(!r.tokens.is_empty());
        assert!(r.ttft_s >= 0.0 && r.latency_s >= r.ttft_s);
    }
    assert_eq!(serve.metrics.completed, 10);
    assert!(serve.metrics.decode_steps > 0);
}

#[test]
fn greedy_generation_continues_training_patterns() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // The corpus is grammatical; a greedy continuation of a template stem
    // should produce corpus-like bytes (ascii words), demonstrating the
    // quantized model actually works end to end.
    let (mut serve, _) = make_engine(Method::singlequant(), 4);
    let resp = serve.generate(0, "the weaving master ", 24).unwrap();
    assert!(!resp.text.is_empty());
    let printable = resp
        .text
        .chars()
        .filter(|c| c.is_ascii_graphic() || *c == ' ' || *c == '\n')
        .count();
    assert!(
        printable * 10 >= resp.text.chars().count() * 8,
        "degenerate output: {:?}",
        resp.text
    );
}

#[test]
fn batch_isolation_mid_flight_joins() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // A request generated alone must produce the same greedy tokens as the
    // same request served while other requests join mid-flight.
    let (mut solo, corpus) = make_engine(Method::Fp16, 4);
    let prompt = corpus[500..540].to_vec();
    solo.submit(Request::new(0, prompt.clone()).with_max_new(8));
    let solo_resp = &solo.run_to_completion().unwrap()[0];

    let (mut busy, _) = make_engine(Method::Fp16, 4);
    busy.submit(Request::new(0, prompt.clone()).with_max_new(8));
    // first tick admits request 0
    let mut done: Vec<_> = responses_of(busy.step().unwrap());
    // now add competitors that join while request 0 decodes
    for id in 1..6u64 {
        busy.submit(
            Request::new(id, corpus[(100 * id as usize)..(100 * id as usize + 20)].to_vec())
                .with_max_new(6),
        );
    }
    while busy.pending() > 0 || busy.active() > 0 {
        done.extend(responses_of(busy.step().unwrap()));
    }
    let busy_resp = done.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(
        solo_resp.tokens, busy_resp.tokens,
        "mid-flight joins perturbed an in-flight request's generation"
    );
}

#[test]
fn tokenizer_path_consistency() {
    let text = "in varno , mintak studied the art of weaving .";
    assert_eq!(decode(&encode(text)), text);
}
