//! Golden end-to-end quantization: every method quantizes the built-in
//! demo checkpoint, loads through the native engine, and greedily
//! decodes a fixed prompt — twice. Both the produced artifacts (weights,
//! rotations, clips, packed bytes) and the decoded tokens must be
//! bit-identical between runs, and the whole pipeline must produce
//! bit-identical packages at every `--threads` setting (the determinism
//! contract of the parallel fan-out / ordered-commit pipeline).
//!
//! No artifacts, no PJRT — runs in plain `cargo test` on a bare machine.

use singlequant::model::{ModelConfig, NativeModel, Weights};
use singlequant::pipeline::{quantize, Method, PipelineOptions, QuantizedModel};
use singlequant::quant::WeightQuantizer;
use singlequant::util::rng::Rng;

/// Small-but-real pipeline budget on the demo config.
fn opts(method: Method) -> PipelineOptions {
    PipelineOptions {
        method,
        calib_seqs: 3,
        calib_len: 24,
        ..Default::default()
    }
}

fn demo_inputs() -> (ModelConfig, Weights, Vec<u16>) {
    let cfg = ModelConfig::demo();
    let weights = Weights::random_init(&cfg, 0x5142);
    let mut rng = Rng::new(7);
    let calib: Vec<u16> = (0..2048).map(|_| rng.below(256) as u16).collect();
    (cfg, weights, calib)
}

/// Bit-level equality of two quantized packages. f32 payloads are
/// compared through `to_bits` so -0.0 vs 0.0 or NaN drift cannot hide.
fn assert_identical(a: &QuantizedModel, b: &QuantizedModel, what: &str) {
    assert_eq!(a.method_label, b.method_label, "{what}: method label");
    assert_eq!(a.packed_bytes, b.packed_bytes, "{what}: packed bytes");
    assert_eq!(a.fp_bytes, b.fp_bytes, "{what}: fp bytes");

    let akeys: Vec<&String> = a.weights.map.keys().collect();
    let bkeys: Vec<&String> = b.weights.map.keys().collect();
    assert_eq!(akeys, bkeys, "{what}: weight key sets");
    for (name, ta) in &a.weights.map {
        let tb = &b.weights.map[name];
        assert_eq!(ta.shape(), tb.shape(), "{what}: shape of {name}");
        let same = ta
            .data()
            .iter()
            .zip(tb.data())
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "{what}: weight {name} differs at the bit level");
    }

    assert_eq!(
        a.rots.keys().collect::<Vec<_>>(),
        b.rots.keys().collect::<Vec<_>>(),
        "{what}: rotation key sets"
    );
    for (key, ra) in &a.rots {
        let rb = &b.rots[key];
        for (fa, fb, which) in [(&ra.r1, &rb.r1, "r1"), (&ra.r2, &rb.r2, "r2")] {
            assert_eq!(fa.shape(), fb.shape(), "{what}: {key}.{which} shape");
            let same = fa
                .data()
                .iter()
                .zip(fb.data())
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "{what}: rotation {key}.{which} differs");
        }
    }

    assert_eq!(
        a.clips.keys().collect::<Vec<_>>(),
        b.clips.keys().collect::<Vec<_>>(),
        "{what}: clip key sets"
    );
    for (key, ca) in &a.clips {
        assert_eq!(
            ca.to_bits(),
            b.clips[key].to_bits(),
            "{what}: clip {key} differs"
        );
    }
}

fn argmax(row: &[f32]) -> u16 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u16
}

/// Load the package into the native engine and greedily decode a fixed
/// prompt: prefill, then `steps` argmax continuations.
fn greedy_decode(qm: &QuantizedModel, weight_bits: u32, steps: usize) -> Vec<u16> {
    let prompt: Vec<u16> = vec![72, 101, 108, 108, 111, 32, 119, 111];
    let model = NativeModel::from_quantized(qm, weight_bits, 2).expect("native model");
    let mut kv = model.new_kv();
    let logits = model.prefill(&mut kv, &prompt).expect("prefill");
    let mut tok = argmax(logits.row(logits.rows() - 1));
    let mut out = vec![tok];
    for _ in 1..steps {
        let next = model.decode(&mut kv, tok).expect("decode");
        tok = argmax(&next);
        out.push(tok);
    }
    out
}

#[test]
fn every_method_quantizes_and_decodes_reproducibly() {
    let (cfg, weights, calib) = demo_inputs();
    for (label, method) in [
        ("rtn", Method::Rtn),
        ("smoothquant", Method::SmoothQuant { alpha: 0.5 }),
        ("awq", Method::Awq { grid: 4 }),
        ("quarot", Method::QuaRot),
        ("duquant", Method::DuQuant { steps: 4 }),
        ("singlequant", Method::singlequant()),
    ] {
        let o = opts(method);
        let qm1 = quantize(&cfg, &weights, &calib, &o).expect(label);
        let qm2 = quantize(&cfg, &weights, &calib, &o).expect(label);
        assert_identical(&qm1, &qm2, label);

        let t1 = greedy_decode(&qm1, o.weight_bits, 8);
        let t2 = greedy_decode(&qm2, o.weight_bits, 8);
        assert_eq!(t1.len(), 8, "{label}: decode length");
        assert_eq!(t1, t2, "{label}: greedy decode diverged between runs");
    }
}

#[test]
fn thread_counts_produce_bit_identical_packages() {
    let (cfg, weights, calib) = demo_inputs();
    let variants: [(&str, PipelineOptions); 2] = [
        (
            "singlequant+lct",
            PipelineOptions { lct: true, ..opts(Method::singlequant()) },
        ),
        (
            "rtn+gptq",
            PipelineOptions {
                weight_quantizer: WeightQuantizer::Gptq,
                ..opts(Method::Rtn)
            },
        ),
    ];
    for (label, base) in variants {
        let serial = quantize(&cfg, &weights, &calib, &PipelineOptions {
            threads: 1,
            ..base.clone()
        })
        .expect(label);
        let tokens_serial = greedy_decode(&serial, base.weight_bits, 8);
        for t in [2usize, 4] {
            let par = quantize(&cfg, &weights, &calib, &PipelineOptions {
                threads: t,
                ..base.clone()
            })
            .expect(label);
            assert_identical(&serial, &par, &format!("{label} threads={t}"));
            assert_eq!(
                tokens_serial,
                greedy_decode(&par, base.weight_bits, 8),
                "{label} threads={t}: decode diverged"
            );
        }
    }
}
