//! Offline stand-in for the `anyhow` crate: the subset this workspace
//! uses — [`Error`], [`Result`], [`anyhow!`]/[`bail!`]/[`ensure!`], and
//! the [`Context`] extension — with the same surface semantics
//! (`Display` shows the outermost message, `{:#}` joins the whole chain,
//! `Debug` prints a `Caused by:` trace). Swap for the real crate when
//! registry access exists; no call sites change.

use std::fmt;

/// A chain of error messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a plain message (what `anyhow!` produces).
    pub fn msg(message: impl fmt::Display + Send + Sync + 'static) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Build from a concrete error, capturing its source chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display + Send + Sync + 'static) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-joined, like anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    use super::Error;

    /// The anyhow trick: lets `Context` apply both to concrete errors and
    /// to `anyhow::Result` itself. `Error` deliberately does not implement
    /// `std::error::Error`, so the two impls cannot overlap.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::new(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context(...)` / `.with_context(|| ...)` on fallible values.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or an error
/// value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `if !cond { bail!(...) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn context_layers_and_alternate_format() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        // plain Display: outermost only; {:#}: the chain
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
        // context on an anyhow::Result (the IntoError-for-Error impl)
        let r2: Result<()> = Err(e);
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 3: reading config: missing thing");
        assert!(format!("{e2:?}").contains("Caused by:"));
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too large: {}", x);
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(f(0).unwrap_err().to_string(), "x too small: 0");
        assert_eq!(f(11).unwrap_err().to_string(), "x too large: 11");
        assert_eq!(f(5).unwrap_err().to_string(), "fell through");
        let wrapped = anyhow!(io_err());
        assert_eq!(wrapped.to_string(), "missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
