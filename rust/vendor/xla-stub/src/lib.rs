//! Offline stand-in for the `xla` (PJRT) bindings crate.
//!
//! The real dependency is xla-rs over xla_extension 0.5.1, which needs a
//! native XLA build that is not present in this container. This stub keeps
//! the whole workspace compiling and lets every host-side data path
//! (literals, buffers, shapes) behave normally; only `compile`/`execute`
//! fail, with an error that names the missing runtime. All call sites in
//! `singlequant` gate on `artifacts/manifest.json` before touching PJRT,
//! so tests and examples skip cleanly instead of hitting these errors.
//!
//! API surface mirrored (the subset `singlequant::runtime` uses):
//! `PjRtClient` (cpu, platform_name, buffer_from_host_buffer,
//! buffer_from_host_literal, compile), `PjRtBuffer` (to_literal_sync),
//! `PjRtLoadedExecutable` (execute, execute_b), `Literal` (vec1, scalar,
//! reshape, to_vec, decompose_tuple), `HloModuleProto` (from_text_file),
//! `XlaComputation` (from_proto).

use std::fmt;

/// Error type matching the shape the real bindings expose (an enum-ish
/// opaque error that is Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real PJRT runtime; this build uses the offline \
         xla stub (rust/vendor/xla-stub). Point Cargo.toml's `xla` dependency \
         at the real bindings to execute AOT artifacts."
    ))
}

// ---------------------------------------------------------------------------
// Element types
// ---------------------------------------------------------------------------

/// Host element types a literal can hold. Public only because the
/// [`NativeType`] conversion trait mentions it; not part of the real API.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Sealed-ish conversion trait for the element types the runtime uses.
pub trait NativeType: Copy + 'static {
    fn wrap(data: Vec<Self>) -> Payload
    where
        Self: Sized;
    fn unwrap(p: &Payload) -> Option<&[Self]>
    where
        Self: Sized;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(p: &Payload) -> Option<&[f32]> {
        match p {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(p: &Payload) -> Option<&[i32]> {
        match p {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Literal
// ---------------------------------------------------------------------------

/// A host tensor value (or tuple of them).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal { dims: vec![xs.len() as i64], payload: T::wrap(xs.to_vec()) }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], payload: Payload::F32(vec![v]) }
    }

    fn elem_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want as usize != self.elem_count() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.elem_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.payload, Payload::Tuple(Vec::new())) {
            Payload::Tuple(parts) => Ok(parts),
            other => {
                // A non-tuple "tuple" of one, matching the real bindings'
                // tolerance for single-output executables.
                self.payload = other.clone();
                Ok(vec![Literal { dims: self.dims.clone(), payload: other }])
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Buffers + client + executables
// ---------------------------------------------------------------------------

/// A "device" buffer; on the stub it is just a host literal.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A compiled executable handle. The stub can never produce one, but the
/// type must exist for struct fields and signatures.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (PJRT unavailable)".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { lit: Literal::vec1(data).reshape(&dims)? })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module text (held verbatim; only the real bindings parse it).
#[derive(Debug)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn buffers_hold_data() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[7i32, 8], &[2], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn execution_is_gated() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { _text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
