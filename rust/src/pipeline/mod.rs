//! The quantization pipeline: checkpoint → calibration → pre-quantization
//! transform (SingleQuant or a baseline) → weight quantization → packaged
//! [`QuantizedModel`].
//!
//! Every method in the paper's experiment matrix is dispatched through
//! [`Method`]; all of them emit the same artifact shape (transformed
//! weights + per-site Kronecker rotation factors + clip scalars) so the
//! PJRT graphs are method-agnostic. Scale-fold methods (SmoothQuant, AWQ)
//! rewrite producer parameters and feed identity rotations — exactly how
//! they deploy in practice.
//!
//! Parallelism: calibration sequences, per-site rotation builds, and
//! per-site weight quantization all fan out over the worker pool; every
//! order-sensitive commit happens serially in fixed `BTreeMap` key
//! order, so the package is **bit-identical across thread counts**
//! (pinned by `tests/integration_quant.rs`; contract in DESIGN.md
//! "Quantization pipeline parallelism"). The pipeline is also part of
//! sqlint's panic-free hotpath set: malformed input surfaces as
//! [`PipelineError`], never a panic.

pub mod fold;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::calib::{calib_sequences, run_calibration_pool, Calibration};
use crate::model::forward::QuantCtx;
use crate::model::{ModelConfig, Weights};
use crate::quant::clip::search_act_clip;
use crate::quant::gptq::{gptq_quantize, GptqConfig, Hessian};
use crate::quant::pack::PackedWeight;
use crate::quant::{
    fake_quant_grouped, fake_quant_per_channel, WeightQuantizer,
};
use crate::rotation::baselines::{
    duquant_rotation, learned_kron_rotation, quarot_rotation, quip_rotation,
};
use crate::rotation::cayley::{CayleyConfig, CayleyTrace};
use crate::rotation::kronecker::{kron_rotate_rows, kron_rotate_weight, kron_sandwich};
use crate::rotation::singlequant::{
    build_site_rotation, SingleQuantConfig, SiteProfile, SiteRotation,
};
use crate::tensor::pool::{self, WorkerPool};
use crate::tensor::Tensor;
use crate::util::clock;

/// Typed pipeline failures — the panic-free contract of the hotpath set.
/// Each variant names a structural precondition the caller (or a
/// previous stage) violated; none of them should abort the process.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PipelineError {
    /// A no-quantization method reached a stage that only runs for
    /// quantizing methods (e.g. FP16 hit the rotation builder).
    MethodNotQuantized(&'static str),
    /// A rotation site had no calibration record.
    MissingCalibration(String),
    /// Stage 4 found no built rotation for a site.
    MissingRotation(String),
    /// GPTQ was requested but the calibration pass skipped the Hessian.
    MissingHessian(String),
    /// A scale fold targeted a site without a foldable producer.
    UnfoldableSite(String),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::MethodNotQuantized(m) => {
                write!(f, "method {m} does not quantize; stage not applicable")
            }
            PipelineError::MissingCalibration(k) => {
                write!(f, "no calibration record for site {k}")
            }
            PipelineError::MissingRotation(k) => write!(f, "no rotation built for site {k}"),
            PipelineError::MissingHessian(k) => {
                write!(f, "GPTQ needs a calibration Hessian for site {k}, none accumulated")
            }
            PipelineError::UnfoldableSite(s) => {
                write!(f, "site {s} has no foldable producer parameter")
            }
        }
    }
}

impl std::error::Error for PipelineError {}

/// Pre-quantization transform selection (the rows of Tables 1–6).
#[derive(Clone, Debug)]
pub enum Method {
    /// No quantization at all (the FP16 rows; f32 on this testbed).
    Fp16,
    /// Plain RTN: identity rotations.
    Rtn,
    /// SmoothQuant channel scaling (α-balance), identity rotations.
    SmoothQuant { alpha: f32 },
    /// AWQ-style searched channel scaling, identity rotations.
    Awq { grid: usize },
    /// QuaRot-style incoherence rotation (random-orth ⊗ Hadamard).
    QuaRot,
    /// QuIP-style two-sided random orthogonal rotation.
    Quip,
    /// SpinQuant: Cayley SGD + STE learned rotation (per site).
    SpinQuant { steps: usize },
    /// DuQuant-style greedy Givens + zigzag permutation + Hadamard.
    DuQuant { steps: usize },
    /// FlatQuant-style learned Kronecker transform (LCT handled by `lct`).
    FlatQuant { steps: usize },
    /// The paper's method: closed-form ART + URT + Hadamard (Eq. 45).
    SingleQuant(SingleQuantConfig),
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn => "RTN-only".into(),
            Method::SmoothQuant { .. } => "SmoothQuant".into(),
            Method::Awq { .. } => "AWQ".into(),
            Method::QuaRot => "QuaRot".into(),
            Method::Quip => "QuIP".into(),
            Method::SpinQuant { .. } => "SpinQuant".into(),
            Method::DuQuant { .. } => "DuQuant".into(),
            Method::FlatQuant { .. } => "FlatQuant".into(),
            Method::SingleQuant(_) => "SingleQuant".into(),
        }
    }

    pub fn singlequant() -> Method {
        Method::SingleQuant(SingleQuantConfig::default())
    }

    /// Unambiguous key for caching quantized packages (label() collapses
    /// parameter variants; this must not).
    pub fn cache_key(&self) -> String {
        match self {
            Method::SmoothQuant { alpha } => format!("smooth-a{alpha}"),
            Method::Awq { grid } => format!("awq-g{grid}"),
            Method::SpinQuant { steps } => format!("spin-s{steps}"),
            Method::DuQuant { steps } => format!("duq-s{steps}"),
            Method::FlatQuant { steps } => format!("flat-s{steps}"),
            Method::SingleQuant(c) => format!(
                "sq-art{}-urt{}-h{}-steps{}-rc{}-u2{}",
                c.use_art as u8, c.use_urt as u8, c.use_hadamard as u8,
                c.art_steps, c.art_random_complement as u8, c.urt_axis2 as u8
            ),
            other => other.label(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineOptions {
    pub method: Method,
    pub weight_quantizer: WeightQuantizer,
    pub weight_bits: u32,
    /// 4 for W4A4, 16 for weight-only.
    pub act_bits: u32,
    /// Learnable-clipping-threshold search on activations (Table 5).
    pub lct: bool,
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub seed: u64,
    /// Pool lanes for the pipeline's parallel stages. 0 = the
    /// process-wide pool (all cores); any other value runs on a private
    /// pool of exactly that many lanes. Output is bit-identical either
    /// way — the knob only trades wall-clock.
    pub threads: usize,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            method: Method::singlequant(),
            weight_quantizer: WeightQuantizer::Rtn,
            weight_bits: 4,
            act_bits: 4,
            lct: false,
            calib_seqs: 8,
            calib_len: 96,
            seed: 0x5142,
            threads: 0,
        }
    }
}

/// Per-stage wall-clock and run shape, surfaced by the `quantize` CLI
/// progress lines and `bench_quant_time`'s JSON. Timings are the only
/// non-deterministic part of a package; everything else is bit-stable.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub calib_seconds: f64,
    pub fold_seconds: f64,
    pub rotation_seconds: f64,
    pub weight_quant_seconds: f64,
    /// Rotation sites processed (layers × sites).
    pub sites: usize,
    /// Pool lanes the parallel stages ran on.
    pub lanes: usize,
}

impl PipelineStats {
    pub fn total_seconds(&self) -> f64 {
        self.calib_seconds + self.fold_seconds + self.rotation_seconds
            + self.weight_quant_seconds
    }
}

/// A quantized, deployable model package.
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    /// Transformed parameters: quantized linears are stored dequantized-f32
    /// (what the fake-quant graphs consume); norms/embeddings stay fp.
    pub weights: Weights,
    pub rots: BTreeMap<String, SiteRotation>,
    pub clips: BTreeMap<String, f32>,
    pub act_bits: u32,
    /// Static per-tensor activation quantization (SmoothQuant's original
    /// quantizer form): the clip values carry per-site scales Δ.
    pub static_act: bool,
    pub method_label: String,
    /// Input-dim scale-group size of the weight quantizer (`None` =
    /// per-channel). The native engine packs grouped packages on this
    /// exact grid instead of re-deriving per-channel scales.
    pub weight_group: Option<usize>,
    /// Exact packed-int weight bytes (quantized linears) + f32 bytes (rest):
    /// the Table 8 storage model.
    pub packed_bytes: usize,
    pub fp_bytes: usize,
    pub calib_seconds: f64,
    /// Legacy aggregate (folds + rotation builds); see `stats` for the
    /// per-stage split.
    pub transform_seconds: f64,
    pub weight_quant_seconds: f64,
    /// Per-stage timing/shape breakdown of the run that built this.
    pub stats: PipelineStats,
    /// Optimization traces for learned baselines (Fig. 2 inputs).
    pub traces: BTreeMap<String, CayleyTrace>,
}

impl QuantizedModel {
    pub fn total_seconds(&self) -> f64 {
        self.calib_seconds + self.transform_seconds + self.weight_quant_seconds
    }

    pub fn graph_mode(&self) -> &'static str {
        if self.method_label == "FP16" {
            "fp"
        } else if self.act_bits >= 16 {
            "w4a16"
        } else if self.static_act {
            "w4a4s"
        } else {
            "w4a4"
        }
    }

    /// Context for the Rust quantized reference forward.
    pub fn quant_ctx(&self) -> Option<QuantCtx> {
        if self.graph_mode() == "fp" {
            return None;
        }
        Some(QuantCtx {
            rots: self.rots.clone(),
            clips: self.clips.clone(),
            act_bits: self.act_bits,
            static_act: self.static_act,
        })
    }
}

/// Run the full pipeline on the default (process-wide) pool sizing from
/// `opts.threads`, without progress reporting.
pub fn quantize(
    cfg: &ModelConfig,
    weights: &Weights,
    calib_tokens: &[u16],
    opts: &PipelineOptions,
) -> Result<QuantizedModel> {
    quantize_with_progress(cfg, weights, calib_tokens, opts, None)
}

/// Run the full pipeline, reporting one line per completed stage through
/// `progress` (the `quantize` CLI prints these live).
pub fn quantize_with_progress(
    cfg: &ModelConfig,
    weights: &Weights,
    calib_tokens: &[u16],
    opts: &PipelineOptions,
    progress: Option<&dyn Fn(&str)>,
) -> Result<QuantizedModel> {
    if matches!(opts.method, Method::Fp16) {
        return Ok(fp16_package(cfg, weights));
    }
    let note = |msg: String| {
        if let Some(p) = progress {
            p(&msg);
        }
    };
    // 0 lanes = the process-wide pool; otherwise a private pool of the
    // requested width. Stage outputs are bit-identical either way.
    let owned_pool;
    let pool: &WorkerPool = if opts.threads == 0 {
        pool::global()
    } else {
        owned_pool = WorkerPool::new(opts.threads);
        &owned_pool
    };
    let mut stats = PipelineStats { lanes: pool.lanes(), ..Default::default() };

    // ---- 1. single calibration pass (sequences fan out on the pool) --------
    let t0 = clock::now();
    let seqs = calib_sequences(calib_tokens, opts.calib_seqs, opts.calib_len, opts.seed);
    let need_hessian = matches!(
        opts.weight_quantizer,
        WeightQuantizer::Gptq | WeightQuantizer::GptqGrouped(_)
    );
    let mut calibration =
        run_calibration_pool(cfg, weights, &seqs, opts.seed, need_hessian, pool)?;
    stats.calib_seconds = t0.elapsed().as_secs_f64();
    note(format!(
        "[quantize] calibration: {} seqs, {} tokens, {} sites in {:.3}s ({} lanes)",
        calibration.n_sequences, calibration.n_tokens, calibration.sites.len(),
        stats.calib_seconds, pool.lanes(),
    ));

    // ---- 2. scale folds (SmoothQuant / AWQ) --------------------------------
    let t1 = clock::now();
    let mut w = weights.clone();
    match &opts.method {
        Method::SmoothQuant { alpha } => {
            fold::fold_smoothquant(cfg, &mut w, &mut calibration, *alpha)?;
        }
        Method::Awq { grid } => {
            fold::fold_awq(cfg, &mut w, &mut calibration, opts.weight_bits, *grid)?;
        }
        _ => {}
    }
    stats.fold_seconds = t1.elapsed().as_secs_f64();
    note(format!("[quantize] scale folds: {:.3}s", stats.fold_seconds));

    // Site work-list in BTreeMap key order: `l{layer:02}.{site}` sorts by
    // layer first, so index order below IS commit order.
    let site_keys: Vec<(usize, &'static str, String)> = (0..cfg.n_layers)
        .flat_map(|layer| {
            crate::model::config::ROT_SITES
                .iter()
                .map(move |site| (layer, *site, format!("l{layer:02}.{site}")))
        })
        .collect();
    stats.sites = site_keys.len();

    // ---- 3. per-site rotations (parallel build, ordered commit) ------------
    let t2 = clock::now();
    let built = pool.run_collect(site_keys.len(), |i| {
        let (layer, site, key) = &site_keys[i];
        build_rotation(cfg, &w, &calibration, *layer, site, key, opts)
    });
    let mut rots: BTreeMap<String, SiteRotation> = BTreeMap::new();
    let mut traces: BTreeMap<String, CayleyTrace> = BTreeMap::new();
    for ((_, _, key), b) in site_keys.iter().zip(built) {
        let (rot, trace) = b?;
        if let Some(t) = trace {
            traces.insert(key.clone(), t);
        }
        rots.insert(key.clone(), rot);
    }
    stats.rotation_seconds = t2.elapsed().as_secs_f64();
    note(format!(
        "[quantize] rotations ({}): {} sites in {:.3}s",
        opts.method.label(), stats.sites, stats.rotation_seconds,
    ));

    // ---- 4. rotate + quantize weights; clip search (parallel sites) --------
    let t3 = clock::now();
    let quants = pool.run_collect(site_keys.len(), |i| {
        let (layer, site, key) = &site_keys[i];
        quantize_site(cfg, &w, &calibration, &rots, *layer, site, key, opts)
    });
    let mut clips: BTreeMap<String, f32> = BTreeMap::new();
    let mut packed_bytes = 0usize;
    for ((_, _, key), q) in site_keys.iter().zip(quants) {
        let sq = q?;
        for (wname, qt) in sq.weights {
            w.insert(&wname, qt);
        }
        packed_bytes += sq.packed_bytes;
        clips.insert(key.clone(), sq.clip);
    }
    stats.weight_quant_seconds = t3.elapsed().as_secs_f64();
    note(format!(
        "[quantize] weight quant ({}): {} packed bytes in {:.3}s",
        opts.weight_quantizer.label(), packed_bytes, stats.weight_quant_seconds,
    ));

    // fp bytes: everything not site-quantized (embeddings, norms, head, router)
    let quantized: std::collections::BTreeSet<String> = (0..cfg.n_layers)
        .flat_map(|l| {
            crate::model::config::ROT_SITES
                .iter()
                .flat_map(move |s| cfg.site_weights(l, s))
        })
        .collect();
    let fp_bytes: usize = w
        .map
        .iter()
        .filter(|(k, _)| !quantized.contains(*k))
        .map(|(_, t)| t.len() * 4)
        .sum();

    Ok(QuantizedModel {
        cfg: cfg.clone(),
        weights: w,
        rots,
        clips,
        act_bits: opts.act_bits,
        static_act: matches!(opts.method, Method::SmoothQuant { .. })
            && opts.act_bits < 16,
        method_label: opts.method.label(),
        weight_group: opts.weight_quantizer.group(),
        packed_bytes,
        fp_bytes,
        calib_seconds: stats.calib_seconds,
        transform_seconds: stats.fold_seconds + stats.rotation_seconds,
        weight_quant_seconds: stats.weight_quant_seconds,
        stats,
        traces,
    })
}

/// Stage-3 worker: build the rotation for one site. Pure function of
/// (post-fold weights, calibration, site, opts) — every method's
/// randomness is keyed off `opts.seed` and the site, never off shared
/// mutable state, so the build is safe to run on any pool lane.
#[allow(clippy::too_many_arguments)]
fn build_rotation(
    cfg: &ModelConfig,
    w: &Weights,
    calibration: &Calibration,
    layer: usize,
    site: &str,
    key: &str,
    opts: &PipelineOptions,
) -> Result<(SiteRotation, Option<CayleyTrace>)> {
    let sc = calibration
        .sites
        .get(key)
        .ok_or_else(|| PipelineError::MissingCalibration(key.to_string()))?;
    let (n, _, _) = cfg.site_dims(site);
    let rot = match &opts.method {
        Method::Fp16 => {
            return Err(PipelineError::MethodNotQuantized("FP16").into());
        }
        Method::Rtn | Method::SmoothQuant { .. } | Method::Awq { .. } => {
            SiteRotation::identity(n)
        }
        Method::QuaRot => quarot_rotation(n, opts.seed ^ hash_key(key)),
        Method::Quip => quip_rotation(n, opts.seed ^ hash_key(key)),
        Method::DuQuant { steps } => duquant_rotation(&sc.signed_absmax, *steps, opts.seed),
        Method::SpinQuant { steps } | Method::FlatQuant { steps } => {
            let wcat = site_weight_concat(cfg, w, layer, site)?;
            let ccfg = CayleyConfig {
                steps: *steps,
                act_bits: opts.act_bits.min(8),
                weight_bits: opts.weight_bits,
                ..Default::default()
            };
            let lr = learned_kron_rotation(&sc.sample, &wcat, &ccfg, opts.seed)?;
            return Ok((lr.rotation, Some(lr.trace)));
        }
        Method::SingleQuant(sq) => {
            let profile = SiteProfile {
                n,
                signed_absmax: sc.signed_absmax.clone(),
                median: sc.median(),
            };
            build_site_rotation(&profile, sq)
        }
    };
    Ok((rot, None))
}

/// Stage-4 output for one site, committed serially in key order.
struct SiteQuant {
    /// (weight name, fake-quantized tensor) in `site_weights` order.
    weights: Vec<(String, Tensor)>,
    packed_bytes: usize,
    clip: f32,
}

/// Stage-4 worker: rotate, quantize, and clip-search one site. Reads the
/// *pre-quantization* (post-fold) weights — sites never read each
/// other's quantized outputs (the serial loop never did either: a site's
/// clip search only concatenates that site's own freshly quantized
/// tensors), so fan-out order cannot change the numbers.
#[allow(clippy::too_many_arguments)]
fn quantize_site(
    cfg: &ModelConfig,
    w: &Weights,
    calibration: &Calibration,
    rots: &BTreeMap<String, SiteRotation>,
    layer: usize,
    site: &str,
    key: &str,
    opts: &PipelineOptions,
) -> Result<SiteQuant> {
    let rot = rots
        .get(key)
        .ok_or_else(|| PipelineError::MissingRotation(key.to_string()))?;
    let sc = calibration
        .sites
        .get(key)
        .ok_or_else(|| PipelineError::MissingCalibration(key.to_string()))?;

    // rotated Hessian for GPTQ: H_r = Rᵀ H R with R = r1 ⊗ r2, computed
    // without materializing the kron (see `kron_sandwich`)
    let hess_rot = match opts.weight_quantizer {
        WeightQuantizer::Gptq | WeightQuantizer::GptqGrouped(_) => {
            if sc.hessian.rows() != sc.n {
                return Err(PipelineError::MissingHessian(key.to_string()).into());
            }
            Some(Hessian {
                h: kron_sandwich(&sc.hessian, &rot.r1, &rot.r2),
                count: sc.token_count,
            })
        }
        _ => None,
    };

    let mut out: Vec<(String, Tensor)> = Vec::new();
    let mut packed_bytes = 0usize;
    for wname in cfg.site_weights(layer, site) {
        let rotated = kron_rotate_weight(w.get(&wname)?, &rot.r1, &rot.r2);
        let q = match opts.weight_quantizer {
            WeightQuantizer::Rtn => fake_quant_per_channel(&rotated, opts.weight_bits, 1.0),
            WeightQuantizer::RtnGrouped(g) => {
                fake_quant_grouped(&rotated, opts.weight_bits, g, 1.0)
            }
            WeightQuantizer::Gptq | WeightQuantizer::GptqGrouped(_) => {
                let hess = hess_rot
                    .as_ref()
                    .ok_or_else(|| PipelineError::MissingHessian(key.to_string()))?;
                gptq_quantize(
                    &rotated,
                    hess,
                    &GptqConfig {
                        bits: opts.weight_bits,
                        group: opts.weight_quantizer.group(),
                        ..Default::default()
                    },
                )?
            }
        };
        packed_bytes += PackedWeight::pack(&q, opts.weight_bits)?.nbytes();
        out.push((wname, q));
    }

    // activation clip (LCT) or SmoothQuant's static scale
    let clip = if matches!(opts.method, Method::SmoothQuant { .. }) {
        // static per-tensor scale Delta = absmax/qmax over the (folded)
        // calibration activations at this site
        let absmax = sc.signed_absmax.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        (absmax / 7.0).max(1e-8)
    } else if opts.lct && opts.act_bits < 16 && sc.sample.rows() > 0 {
        let sample_rot = kron_rotate_rows(&sc.sample, &rot.r1, &rot.r2);
        // concat of this site's just-quantized weights, in site_weights
        // order — exactly what the serial loop read back out of `w`
        let parts: Vec<&Tensor> = out.iter().map(|(_, t)| t).collect();
        let wcat = Tensor::hcat(&parts)?;
        search_act_clip(&sample_rot, &wcat, opts.act_bits, 12, 0.4)
    } else {
        1.0
    };
    Ok(SiteQuant { weights: out, packed_bytes, clip })
}

fn fp16_package(cfg: &ModelConfig, weights: &Weights) -> QuantizedModel {
    let fp_bytes = weights.n_params() * 4;
    QuantizedModel {
        cfg: cfg.clone(),
        weights: weights.clone(),
        rots: BTreeMap::new(),
        clips: BTreeMap::new(),
        act_bits: 16,
        static_act: false,
        method_label: "FP16".into(),
        weight_group: None,
        packed_bytes: 0,
        fp_bytes,
        calib_seconds: 0.0,
        transform_seconds: 0.0,
        weight_quant_seconds: 0.0,
        stats: PipelineStats::default(),
        traces: BTreeMap::new(),
    }
}

/// Horizontal concat of all (post-fold) weights at a site.
fn site_weight_concat(
    cfg: &ModelConfig,
    w: &Weights,
    layer: usize,
    site: &str,
) -> Result<Tensor> {
    let names = cfg.site_weights(layer, site);
    let parts: Vec<&Tensor> = names
        .iter()
        .map(|n| w.get(n))
        .collect::<Result<Vec<_>>>()?;
    Tensor::hcat(&parts)
}

fn hash_key(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::forward::{forward_score, sequence_nll};

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    fn run(method: Method, wq: WeightQuantizer) -> QuantizedModel {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let calib = toks(600, 9);
        let opts = PipelineOptions {
            method,
            weight_quantizer: wq,
            calib_seqs: 3,
            calib_len: 32,
            ..Default::default()
        };
        quantize(&cfg, &w, &calib, &opts).unwrap()
    }

    #[test]
    fn singlequant_pipeline_end_to_end() {
        let qm = run(Method::singlequant(), WeightQuantizer::Rtn);
        assert_eq!(qm.graph_mode(), "w4a4");
        assert_eq!(qm.rots.len(), 2 * 4);
        assert!(qm.packed_bytes > 0);
        // all rotations orthogonal
        for (k, r) in &qm.rots {
            assert!(r.defect() < 5e-3, "{k}: {}", r.defect());
        }
        // the quantized forward runs and is finite
        let t = toks(24, 3);
        let ctx = qm.quant_ctx().unwrap();
        let lg = forward_score(&qm.cfg, &qm.weights, &t, Some(&ctx), None).unwrap();
        assert!(lg.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_methods_produce_valid_packages() {
        for m in [
            Method::Rtn,
            Method::SmoothQuant { alpha: 0.5 },
            Method::QuaRot,
            Method::DuQuant { steps: 4 },
            Method::SingleQuant(SingleQuantConfig::default()),
        ] {
            let qm = run(m.clone(), WeightQuantizer::Rtn);
            assert_eq!(qm.rots.len(), 8, "{}", m.label());
            for r in qm.rots.values() {
                assert!(r.defect() < 5e-3, "{}", m.label());
            }
        }
    }

    #[test]
    fn gptq_weight_quantizer_works() {
        let qm = run(Method::QuaRot, WeightQuantizer::Gptq);
        assert!(qm.weights.get("l00.wq").unwrap().data()
                .iter().all(|v| v.is_finite()));
    }

    #[test]
    fn spinquant_records_traces() {
        let qm = run(Method::SpinQuant { steps: 4 }, WeightQuantizer::Rtn);
        assert_eq!(qm.traces.len(), 8);
        assert!(qm.traces.values().all(|t| t.loss.len() == 4));
    }

    #[test]
    fn rotation_quality_beats_plain_rtn() {
        // On a model with injected outliers, SingleQuant's W4A4 NLL should
        // beat identity-rotation RTN. Random-init weights lack outliers, so
        // inject one huge norm-gain channel per layer.
        let cfg = test_config();
        let mut w = Weights::random_init(&cfg, 1);
        for l in 0..cfg.n_layers {
            for gname in [format!("l{l:02}.an"), format!("l{l:02}.mn")] {
                let mut g = w.get(&gname).unwrap().clone();
                g.data_mut()[5] = 25.0;
                g.data_mut()[11] = -18.0;
                w.insert(&gname, g);
            }
        }
        let calib = toks(600, 9);
        let eval = toks(48, 33);
        // Fidelity metric: MSE of quantized logits against the fp logits
        // (NLL on random-init weights is chance-level noise).
        let fp = forward_score(&cfg, &w, &eval, None, None).unwrap();
        let mut errs = BTreeMap::new();
        for (name, m) in [("rtn", Method::Rtn), ("sq", Method::singlequant())] {
            let opts = PipelineOptions {
                method: m,
                calib_seqs: 4,
                calib_len: 32,
                ..Default::default()
            };
            let qm = quantize(&cfg, &w, &calib, &opts).unwrap();
            let ctx = qm.quant_ctx().unwrap();
            let lg = forward_score(&qm.cfg, &qm.weights, &eval, Some(&ctx), None)
                .unwrap();
            errs.insert(name, lg.mse(&fp));
        }
        assert!(errs["sq"] < errs["rtn"],
                "singlequant {} !< rtn {}", errs["sq"], errs["rtn"]);
    }

    #[test]
    fn stats_cover_all_stages_and_lanes() {
        let qm = run(Method::singlequant(), WeightQuantizer::Rtn);
        assert_eq!(qm.stats.sites, 8);
        assert!(qm.stats.lanes >= 1);
        assert!((qm.total_seconds() - qm.stats.total_seconds()).abs() < 1e-9);
        assert!((qm.transform_seconds
                 - (qm.stats.fold_seconds + qm.stats.rotation_seconds)).abs() < 1e-12);
    }

    #[test]
    fn packages_are_bit_identical_across_thread_counts() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let calib = toks(600, 9);
        let base = PipelineOptions {
            method: Method::singlequant(),
            lct: true,
            calib_seqs: 3,
            calib_len: 32,
            threads: 1,
            ..Default::default()
        };
        let reference = quantize(&cfg, &w, &calib, &base).unwrap();
        for threads in [2usize, 5] {
            let opts = PipelineOptions { threads, ..base.clone() };
            let qm = quantize(&cfg, &w, &calib, &opts).unwrap();
            for (name, t) in &reference.weights.map {
                let bits = |x: &Tensor| x.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(t), bits(&qm.weights.map[name]), "threads={threads} {name}");
            }
            assert_eq!(reference.clips, qm.clips, "threads={threads}");
            assert_eq!(reference.packed_bytes, qm.packed_bytes, "threads={threads}");
        }
    }

    #[test]
    fn rotation_builder_rejects_fp16_with_typed_error() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let cal = crate::calib::run_calibration(&cfg, &w, &[toks(8, 1)], 7).unwrap();
        let opts = PipelineOptions { method: Method::Fp16, ..Default::default() };
        let err = build_rotation(&cfg, &w, &cal, 0, "qkv", "l00.qkv", &opts).unwrap_err();
        assert!(err.to_string().contains("does not quantize"), "{err}");
        let miss = build_rotation(&cfg, &w, &cal, 0, "qkv", "l99.nope",
                                  &PipelineOptions::default()).unwrap_err();
        assert!(miss.to_string().contains("no calibration record"), "{miss}");
    }

    #[test]
    fn fp16_passthrough() {
        let qm = run(Method::Fp16, WeightQuantizer::Rtn);
        assert_eq!(qm.graph_mode(), "fp");
        assert_eq!(qm.packed_bytes, 0);
    }

    #[test]
    fn weight_only_mode() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions {
            act_bits: 16,
            weight_bits: 3,
            method: Method::singlequant(),
            calib_seqs: 2,
            calib_len: 24,
            ..Default::default()
        };
        let qm = quantize(&cfg, &w, &toks(400, 5), &opts).unwrap();
        assert_eq!(qm.graph_mode(), "w4a16");
    }
}
