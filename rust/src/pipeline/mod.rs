//! The quantization pipeline: checkpoint → calibration → pre-quantization
//! transform (SingleQuant or a baseline) → weight quantization → packaged
//! [`QuantizedModel`].
//!
//! Every method in the paper's experiment matrix is dispatched through
//! [`Method`]; all of them emit the same artifact shape (transformed
//! weights + per-site Kronecker rotation factors + clip scalars) so the
//! PJRT graphs are method-agnostic. Scale-fold methods (SmoothQuant, AWQ)
//! rewrite producer parameters and feed identity rotations — exactly how
//! they deploy in practice.

pub mod fold;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::calib::{calib_sequences, run_calibration_opts};
use crate::model::forward::QuantCtx;
use crate::model::{ModelConfig, Weights};
use crate::quant::clip::search_act_clip;
use crate::quant::gptq::{gptq_quantize, GptqConfig, Hessian};
use crate::quant::pack::PackedWeight;
use crate::quant::{
    fake_quant_grouped, fake_quant_per_channel, WeightQuantizer,
};
use crate::rotation::baselines::{
    duquant_rotation, learned_kron_rotation, quarot_rotation, quip_rotation,
};
use crate::rotation::cayley::{CayleyConfig, CayleyTrace};
use crate::rotation::kronecker::kron_rotate_weight;
use crate::rotation::singlequant::{
    build_site_rotation, SingleQuantConfig, SiteProfile, SiteRotation,
};
use crate::tensor::Tensor;
use crate::util::clock;

/// Pre-quantization transform selection (the rows of Tables 1–6).
#[derive(Clone, Debug)]
pub enum Method {
    /// No quantization at all (the FP16 rows; f32 on this testbed).
    Fp16,
    /// Plain RTN: identity rotations.
    Rtn,
    /// SmoothQuant channel scaling (α-balance), identity rotations.
    SmoothQuant { alpha: f32 },
    /// AWQ-style searched channel scaling, identity rotations.
    Awq { grid: usize },
    /// QuaRot-style incoherence rotation (random-orth ⊗ Hadamard).
    QuaRot,
    /// QuIP-style two-sided random orthogonal rotation.
    Quip,
    /// SpinQuant: Cayley SGD + STE learned rotation (per site).
    SpinQuant { steps: usize },
    /// DuQuant-style greedy Givens + zigzag permutation + Hadamard.
    DuQuant { steps: usize },
    /// FlatQuant-style learned Kronecker transform (LCT handled by `lct`).
    FlatQuant { steps: usize },
    /// The paper's method: closed-form ART + URT + Hadamard (Eq. 45).
    SingleQuant(SingleQuantConfig),
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn => "RTN-only".into(),
            Method::SmoothQuant { .. } => "SmoothQuant".into(),
            Method::Awq { .. } => "AWQ".into(),
            Method::QuaRot => "QuaRot".into(),
            Method::Quip => "QuIP".into(),
            Method::SpinQuant { .. } => "SpinQuant".into(),
            Method::DuQuant { .. } => "DuQuant".into(),
            Method::FlatQuant { .. } => "FlatQuant".into(),
            Method::SingleQuant(_) => "SingleQuant".into(),
        }
    }

    pub fn singlequant() -> Method {
        Method::SingleQuant(SingleQuantConfig::default())
    }

    /// Unambiguous key for caching quantized packages (label() collapses
    /// parameter variants; this must not).
    pub fn cache_key(&self) -> String {
        match self {
            Method::SmoothQuant { alpha } => format!("smooth-a{alpha}"),
            Method::Awq { grid } => format!("awq-g{grid}"),
            Method::SpinQuant { steps } => format!("spin-s{steps}"),
            Method::DuQuant { steps } => format!("duq-s{steps}"),
            Method::FlatQuant { steps } => format!("flat-s{steps}"),
            Method::SingleQuant(c) => format!(
                "sq-art{}-urt{}-h{}-steps{}-rc{}-u2{}",
                c.use_art as u8, c.use_urt as u8, c.use_hadamard as u8,
                c.art_steps, c.art_random_complement as u8, c.urt_axis2 as u8
            ),
            other => other.label(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineOptions {
    pub method: Method,
    pub weight_quantizer: WeightQuantizer,
    pub weight_bits: u32,
    /// 4 for W4A4, 16 for weight-only.
    pub act_bits: u32,
    /// Learnable-clipping-threshold search on activations (Table 5).
    pub lct: bool,
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub seed: u64,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            method: Method::singlequant(),
            weight_quantizer: WeightQuantizer::Rtn,
            weight_bits: 4,
            act_bits: 4,
            lct: false,
            calib_seqs: 8,
            calib_len: 96,
            seed: 0x5142,
        }
    }
}

/// A quantized, deployable model package.
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    /// Transformed parameters: quantized linears are stored dequantized-f32
    /// (what the fake-quant graphs consume); norms/embeddings stay fp.
    pub weights: Weights,
    pub rots: BTreeMap<String, SiteRotation>,
    pub clips: BTreeMap<String, f32>,
    pub act_bits: u32,
    /// Static per-tensor activation quantization (SmoothQuant's original
    /// quantizer form): the clip values carry per-site scales Δ.
    pub static_act: bool,
    pub method_label: String,
    /// Input-dim scale-group size of the weight quantizer (`None` =
    /// per-channel). The native engine packs grouped packages on this
    /// exact grid instead of re-deriving per-channel scales.
    pub weight_group: Option<usize>,
    /// Exact packed-int weight bytes (quantized linears) + f32 bytes (rest):
    /// the Table 8 storage model.
    pub packed_bytes: usize,
    pub fp_bytes: usize,
    pub calib_seconds: f64,
    pub transform_seconds: f64,
    pub weight_quant_seconds: f64,
    /// Optimization traces for learned baselines (Fig. 2 inputs).
    pub traces: BTreeMap<String, CayleyTrace>,
}

impl QuantizedModel {
    pub fn total_seconds(&self) -> f64 {
        self.calib_seconds + self.transform_seconds + self.weight_quant_seconds
    }

    pub fn graph_mode(&self) -> &'static str {
        if self.method_label == "FP16" {
            "fp"
        } else if self.act_bits >= 16 {
            "w4a16"
        } else if self.static_act {
            "w4a4s"
        } else {
            "w4a4"
        }
    }

    /// Context for the Rust quantized reference forward.
    pub fn quant_ctx(&self) -> Option<QuantCtx> {
        if self.graph_mode() == "fp" {
            return None;
        }
        Some(QuantCtx {
            rots: self.rots.clone(),
            clips: self.clips.clone(),
            act_bits: self.act_bits,
            static_act: self.static_act,
        })
    }
}

/// Run the full pipeline.
pub fn quantize(
    cfg: &ModelConfig,
    weights: &Weights,
    calib_tokens: &[u16],
    opts: &PipelineOptions,
) -> Result<QuantizedModel> {
    if matches!(opts.method, Method::Fp16) {
        return Ok(fp16_package(cfg, weights));
    }

    // ---- 1. single calibration pass ---------------------------------------
    let t0 = clock::now();
    let seqs = calib_sequences(calib_tokens, opts.calib_seqs, opts.calib_len, opts.seed);
    let need_hessian = matches!(
        opts.weight_quantizer,
        WeightQuantizer::Gptq | WeightQuantizer::GptqGrouped(_)
    );
    let mut calibration =
        run_calibration_opts(cfg, weights, &seqs, opts.seed, need_hessian)?;
    let calib_seconds = t0.elapsed().as_secs_f64();

    // ---- 2. scale folds (SmoothQuant / AWQ) --------------------------------
    let t1 = clock::now();
    let mut w = weights.clone();
    match &opts.method {
        Method::SmoothQuant { alpha } => {
            fold::fold_smoothquant(cfg, &mut w, &mut calibration, *alpha)?;
        }
        Method::Awq { grid } => {
            fold::fold_awq(cfg, &mut w, &mut calibration, opts.weight_bits, *grid)?;
        }
        _ => {}
    }

    // ---- 3. per-site rotations ----------------------------------------------
    let mut rots: BTreeMap<String, SiteRotation> = BTreeMap::new();
    let mut traces: BTreeMap<String, CayleyTrace> = BTreeMap::new();
    for layer in 0..cfg.n_layers {
        for site in crate::model::config::ROT_SITES {
            let key = format!("l{layer:02}.{site}");
            let sc = &calibration.sites[&key];
            let (n, _, _) = cfg.site_dims(site);
            let rot = match &opts.method {
                Method::Fp16 => unreachable!(),
                Method::Rtn | Method::SmoothQuant { .. } | Method::Awq { .. } => {
                    SiteRotation::identity(n)
                }
                Method::QuaRot => quarot_rotation(n, opts.seed ^ hash_key(&key)),
                Method::Quip => quip_rotation(n, opts.seed ^ hash_key(&key)),
                Method::DuQuant { steps } => {
                    duquant_rotation(&sc.signed_absmax, *steps, opts.seed)
                }
                Method::SpinQuant { steps } | Method::FlatQuant { steps } => {
                    let wcat = site_weight_concat(cfg, &w, layer, site)?;
                    let ccfg = CayleyConfig {
                        steps: *steps,
                        act_bits: opts.act_bits.min(8),
                        weight_bits: opts.weight_bits,
                        ..Default::default()
                    };
                    let lr = learned_kron_rotation(&sc.sample, &wcat, &ccfg,
                                                   opts.seed)?;
                    traces.insert(key.clone(), lr.trace);
                    lr.rotation
                }
                Method::SingleQuant(sq) => {
                    let profile = SiteProfile {
                        n,
                        signed_absmax: sc.signed_absmax.clone(),
                        median: sc.median(),
                    };
                    build_site_rotation(&profile, sq)
                }
            };
            rots.insert(key, rot);
        }
    }
    let transform_seconds = t1.elapsed().as_secs_f64();

    // ---- 4. rotate + quantize weights; clip search --------------------------
    let t2 = clock::now();
    let mut clips: BTreeMap<String, f32> = BTreeMap::new();
    let mut packed_bytes = 0usize;
    for layer in 0..cfg.n_layers {
        for site in crate::model::config::ROT_SITES {
            let key = format!("l{layer:02}.{site}");
            let rot = rots[&key].clone();
            let sc = &calibration.sites[&key];

            // rotated Hessian for GPTQ: H_r = Rᵀ H R with R = r1 ⊗ r2
            let rotated_hessian = |h: &Tensor| -> Tensor {
                let r = rot.r1.kron(&rot.r2);
                r.matmul_tn(&h.matmul(&r))
            };
            let hess_rot = match opts.weight_quantizer {
                WeightQuantizer::Gptq | WeightQuantizer::GptqGrouped(_) => {
                    Some(Hessian {
                        h: rotated_hessian(&sc.hessian),
                        count: sc.token_count,
                    })
                }
                _ => None,
            };

            for wname in cfg.site_weights(layer, site) {
                let orig = w.get(&wname)?.clone();
                let rotated = kron_rotate_weight(&orig, &rot.r1, &rot.r2);
                let q = match opts.weight_quantizer {
                    WeightQuantizer::Rtn => {
                        fake_quant_per_channel(&rotated, opts.weight_bits, 1.0)
                    }
                    WeightQuantizer::RtnGrouped(g) => {
                        fake_quant_grouped(&rotated, opts.weight_bits, g, 1.0)
                    }
                    WeightQuantizer::Gptq => gptq_quantize(
                        &rotated,
                        hess_rot.as_ref().unwrap(),
                        &GptqConfig { bits: opts.weight_bits, ..Default::default() },
                    )?,
                    WeightQuantizer::GptqGrouped(g) => gptq_quantize(
                        &rotated,
                        hess_rot.as_ref().unwrap(),
                        &GptqConfig {
                            bits: opts.weight_bits,
                            group: Some(g),
                            ..Default::default()
                        },
                    )?,
                };
                packed_bytes += PackedWeight::pack(&q, opts.weight_bits)?.nbytes();
                w.insert(&wname, q);
            }

            // activation clip (LCT) or SmoothQuant's static scale
            let clip = if matches!(opts.method, Method::SmoothQuant { .. }) {
                // static per-tensor scale Delta = absmax/qmax over the
                // (folded) calibration activations at this site
                let absmax = sc
                    .signed_absmax
                    .iter()
                    .fold(0.0f32, |m, &v| m.max(v.abs()));
                (absmax / 7.0).max(1e-8)
            } else if opts.lct && opts.act_bits < 16 && sc.sample.rows() > 0 {
                let sample_rot = crate::rotation::kronecker::kron_rotate_rows(
                    &sc.sample, &rot.r1, &rot.r2);
                let wcat = site_weight_concat(cfg, &w, layer, site)?;
                search_act_clip(&sample_rot, &wcat, opts.act_bits, 12, 0.4)
            } else {
                1.0
            };
            clips.insert(key, clip);
        }
    }
    let weight_quant_seconds = t2.elapsed().as_secs_f64();

    // fp bytes: everything not site-quantized (embeddings, norms, head, router)
    let quantized: std::collections::BTreeSet<String> = (0..cfg.n_layers)
        .flat_map(|l| {
            crate::model::config::ROT_SITES
                .iter()
                .flat_map(move |s| cfg.site_weights(l, s))
        })
        .collect();
    let fp_bytes: usize = w
        .map
        .iter()
        .filter(|(k, _)| !quantized.contains(*k))
        .map(|(_, t)| t.len() * 4)
        .sum();

    Ok(QuantizedModel {
        cfg: cfg.clone(),
        weights: w,
        rots,
        clips,
        act_bits: opts.act_bits,
        static_act: matches!(opts.method, Method::SmoothQuant { .. })
            && opts.act_bits < 16,
        method_label: opts.method.label(),
        weight_group: opts.weight_quantizer.group(),
        packed_bytes,
        fp_bytes,
        calib_seconds,
        transform_seconds,
        weight_quant_seconds,
        traces,
    })
}

fn fp16_package(cfg: &ModelConfig, weights: &Weights) -> QuantizedModel {
    let fp_bytes = weights.n_params() * 4;
    QuantizedModel {
        cfg: cfg.clone(),
        weights: weights.clone(),
        rots: BTreeMap::new(),
        clips: BTreeMap::new(),
        act_bits: 16,
        static_act: false,
        method_label: "FP16".into(),
        weight_group: None,
        packed_bytes: 0,
        fp_bytes,
        calib_seconds: 0.0,
        transform_seconds: 0.0,
        weight_quant_seconds: 0.0,
        traces: BTreeMap::new(),
    }
}

/// Horizontal concat of all (post-fold) weights at a site.
fn site_weight_concat(
    cfg: &ModelConfig,
    w: &Weights,
    layer: usize,
    site: &str,
) -> Result<Tensor> {
    let names = cfg.site_weights(layer, site);
    let parts: Vec<&Tensor> = names
        .iter()
        .map(|n| w.get(n))
        .collect::<Result<Vec<_>>>()?;
    Tensor::hcat(&parts)
}

fn hash_key(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::forward::{forward_score, sequence_nll};

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    fn run(method: Method, wq: WeightQuantizer) -> QuantizedModel {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let calib = toks(600, 9);
        let opts = PipelineOptions {
            method,
            weight_quantizer: wq,
            calib_seqs: 3,
            calib_len: 32,
            ..Default::default()
        };
        quantize(&cfg, &w, &calib, &opts).unwrap()
    }

    #[test]
    fn singlequant_pipeline_end_to_end() {
        let qm = run(Method::singlequant(), WeightQuantizer::Rtn);
        assert_eq!(qm.graph_mode(), "w4a4");
        assert_eq!(qm.rots.len(), 2 * 4);
        assert!(qm.packed_bytes > 0);
        // all rotations orthogonal
        for (k, r) in &qm.rots {
            assert!(r.defect() < 5e-3, "{k}: {}", r.defect());
        }
        // the quantized forward runs and is finite
        let t = toks(24, 3);
        let ctx = qm.quant_ctx().unwrap();
        let lg = forward_score(&qm.cfg, &qm.weights, &t, Some(&ctx), None).unwrap();
        assert!(lg.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_methods_produce_valid_packages() {
        for m in [
            Method::Rtn,
            Method::SmoothQuant { alpha: 0.5 },
            Method::QuaRot,
            Method::DuQuant { steps: 4 },
            Method::SingleQuant(SingleQuantConfig::default()),
        ] {
            let qm = run(m.clone(), WeightQuantizer::Rtn);
            assert_eq!(qm.rots.len(), 8, "{}", m.label());
            for r in qm.rots.values() {
                assert!(r.defect() < 5e-3, "{}", m.label());
            }
        }
    }

    #[test]
    fn gptq_weight_quantizer_works() {
        let qm = run(Method::QuaRot, WeightQuantizer::Gptq);
        assert!(qm.weights.get("l00.wq").unwrap().data()
                .iter().all(|v| v.is_finite()));
    }

    #[test]
    fn spinquant_records_traces() {
        let qm = run(Method::SpinQuant { steps: 4 }, WeightQuantizer::Rtn);
        assert_eq!(qm.traces.len(), 8);
        assert!(qm.traces.values().all(|t| t.loss.len() == 4));
    }

    #[test]
    fn rotation_quality_beats_plain_rtn() {
        // On a model with injected outliers, SingleQuant's W4A4 NLL should
        // beat identity-rotation RTN. Random-init weights lack outliers, so
        // inject one huge norm-gain channel per layer.
        let cfg = test_config();
        let mut w = Weights::random_init(&cfg, 1);
        for l in 0..cfg.n_layers {
            for gname in [format!("l{l:02}.an"), format!("l{l:02}.mn")] {
                let mut g = w.get(&gname).unwrap().clone();
                g.data_mut()[5] = 25.0;
                g.data_mut()[11] = -18.0;
                w.insert(&gname, g);
            }
        }
        let calib = toks(600, 9);
        let eval = toks(48, 33);
        // Fidelity metric: MSE of quantized logits against the fp logits
        // (NLL on random-init weights is chance-level noise).
        let fp = forward_score(&cfg, &w, &eval, None, None).unwrap();
        let mut errs = BTreeMap::new();
        for (name, m) in [("rtn", Method::Rtn), ("sq", Method::singlequant())] {
            let opts = PipelineOptions {
                method: m,
                calib_seqs: 4,
                calib_len: 32,
                ..Default::default()
            };
            let qm = quantize(&cfg, &w, &calib, &opts).unwrap();
            let ctx = qm.quant_ctx().unwrap();
            let lg = forward_score(&qm.cfg, &qm.weights, &eval, Some(&ctx), None)
                .unwrap();
            errs.insert(name, lg.mse(&fp));
        }
        assert!(errs["sq"] < errs["rtn"],
                "singlequant {} !< rtn {}", errs["sq"], errs["rtn"]);
    }

    #[test]
    fn fp16_passthrough() {
        let qm = run(Method::Fp16, WeightQuantizer::Rtn);
        assert_eq!(qm.graph_mode(), "fp");
        assert_eq!(qm.packed_bytes, 0);
    }

    #[test]
    fn weight_only_mode() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions {
            act_bits: 16,
            weight_bits: 3,
            method: Method::singlequant(),
            calib_seqs: 2,
            calib_len: 24,
            ..Default::default()
        };
        let qm = quantize(&cfg, &w, &toks(400, 5), &opts).unwrap();
        assert_eq!(qm.graph_mode(), "w4a16");
    }
}
