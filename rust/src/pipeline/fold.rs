//! Scale folding for SmoothQuant / AWQ: rewrite producer parameters so the
//! site input arrives pre-divided by the per-channel scale `s`, compensate
//! the consuming weights, and rescale the recorded calibration statistics —
//! the exact deployment mechanics of those methods (no runtime op).
//!
//! Producer per site:
//! * `qkv`  — attention RMSNorm gain `an` (and consumer rows of wq/wk/wv)
//! * `mlp`  — MLP RMSNorm gain `mn` (consumers wg/wu, and the MoE router,
//!   which reads the same normed input and must keep its routing)
//! * `o`    — `wv` output columns (attention output is linear in v)
//! * `down` — `wu` output columns (h = silu(g)·u is linear in u)

use anyhow::Result;

use super::PipelineError;
use crate::calib::{Calibration, SiteCalib};
use crate::model::{ModelConfig, Weights};
use crate::quant::awq::awq_search;
use crate::rotation::baselines::smoothquant_scales;
use crate::tensor::Tensor;

/// Rescale a site's calibration statistics after folding x ← x / s.
fn scale_site_calib(sc: &mut SiteCalib, s: &[f32]) {
    for (j, &sj) in s.iter().enumerate() {
        sc.signed_absmax[j] /= sj;
    }
    for i in 0..sc.sample.rows() {
        for (j, v) in sc.sample.row_mut(i).iter_mut().enumerate() {
            *v /= s[j];
        }
    }
    // H' = diag(1/s) H diag(1/s)
    let n = sc.hessian.rows();
    for i in 0..n {
        for j in 0..n {
            let v = sc.hessian.at(i, j) / (s[i] * s[j]);
            sc.hessian.set(i, j, v);
        }
    }
}

/// Scale rows of a [n, C] weight by `s` (consumer compensation).
fn scale_rows(w: &mut Tensor, s: &[f32]) {
    for i in 0..w.rows() {
        let si = s[i];
        for v in w.row_mut(i) {
            *v *= si;
        }
    }
}

/// Scale columns of a [n, C] weight by `s` (producer output scaling).
fn scale_cols(w: &mut Tensor, s: &[f32]) {
    for i in 0..w.rows() {
        for (j, v) in w.row_mut(i).iter_mut().enumerate() {
            *v *= s[j];
        }
    }
}

/// Per-input-channel absmax of the concatenated site weights.
fn site_weight_absmax(cfg: &ModelConfig, w: &Weights, layer: usize,
                      site: &str) -> Result<Vec<f32>> {
    let names = cfg.site_weights(layer, site);
    let first = names
        .first()
        .ok_or_else(|| PipelineError::UnfoldableSite(format!("l{layer:02}.{site}")))?;
    let n = w.get(first)?.rows();
    let mut out = vec![0.0f32; n];
    for name in &names {
        let t = w.get(name)?;
        for i in 0..n {
            for &v in t.row(i) {
                out[i] = out[i].max(v.abs());
            }
        }
    }
    Ok(out)
}

/// Apply one site's fold: producer ÷ s, consumers × s, calibration ÷ s.
fn apply_site_fold(
    cfg: &ModelConfig,
    w: &mut Weights,
    calibration: &mut Calibration,
    layer: usize,
    site: &str,
    s: &[f32],
) -> Result<()> {
    let p = format!("l{layer:02}");
    match site {
        "qkv" => {
            let mut an = w.get(&format!("{p}.an"))?.clone();
            for (j, v) in an.data_mut().iter_mut().enumerate() {
                *v /= s[j];
            }
            w.insert(&format!("{p}.an"), an);
        }
        "mlp" => {
            let mut mn = w.get(&format!("{p}.mn"))?.clone();
            for (j, v) in mn.data_mut().iter_mut().enumerate() {
                *v /= s[j];
            }
            w.insert(&format!("{p}.mn"), mn);
            if cfg.is_moe() {
                // keep routing decisions identical
                let mut router = w.get(&format!("{p}.router"))?.clone();
                scale_rows(&mut router, s);
                w.insert(&format!("{p}.router"), router);
            }
        }
        "o" => {
            // producer: v-projection output columns ÷ s
            let inv: Vec<f32> = s.iter().map(|x| 1.0 / x).collect();
            let mut wv = w.get(&format!("{p}.wv"))?.clone();
            scale_cols(&mut wv, &inv);
            w.insert(&format!("{p}.wv"), wv);
        }
        "down" => {
            let inv: Vec<f32> = s.iter().map(|x| 1.0 / x).collect();
            if cfg.is_moe() {
                for e in 0..cfg.n_experts {
                    let mut wu = w.get(&format!("{p}.x{e}.wu"))?.clone();
                    scale_cols(&mut wu, &inv);
                    w.insert(&format!("{p}.x{e}.wu"), wu);
                }
            } else {
                let mut wu = w.get(&format!("{p}.wu"))?.clone();
                scale_cols(&mut wu, &inv);
                w.insert(&format!("{p}.wu"), wu);
            }
        }
        other => {
            return Err(PipelineError::UnfoldableSite(other.to_string()).into());
        }
    }
    // consumers × s
    for name in cfg.site_weights(layer, site) {
        let mut t = w.get(&name)?.clone();
        scale_rows(&mut t, s);
        w.insert(&name, t);
    }
    let key = format!("l{layer:02}.{site}");
    let sc = calibration
        .sites
        .get_mut(&key)
        .ok_or_else(|| PipelineError::MissingCalibration(key.clone()))?;
    scale_site_calib(sc, s);
    Ok(())
}

/// Sites scale-fold methods can actually reach in deployment: SmoothQuant
/// (and AWQ's fold) smooth the attention and FFN *inputs* by rewriting the
/// preceding norm gain; the o-proj and down-proj inputs have no preceding
/// static op to fold into in the published methods — precisely the sites
/// where rotation methods pull ahead (QuaRot §3, SpinQuant §4).
const FOLDABLE_SITES: [&str; 2] = ["qkv", "mlp"];

/// SmoothQuant: s = absmax(X)^α / absmax(W)^{1−α} per channel, foldable
/// sites only.
pub fn fold_smoothquant(
    cfg: &ModelConfig,
    w: &mut Weights,
    calibration: &mut Calibration,
    alpha: f32,
) -> Result<()> {
    for layer in 0..cfg.n_layers {
        for site in FOLDABLE_SITES {
            let key = format!("l{layer:02}.{site}");
            let act = calibration.sites[&key].absmax();
            let wmax = site_weight_absmax(cfg, w, layer, site)?;
            let s = smoothquant_scales(&act, &wmax, alpha);
            apply_site_fold(cfg, w, calibration, layer, site, &s)?;
        }
    }
    Ok(())
}

/// AWQ: α grid-searched per site against quantized layer-output error.
pub fn fold_awq(
    cfg: &ModelConfig,
    w: &mut Weights,
    calibration: &mut Calibration,
    weight_bits: u32,
    grid: usize,
) -> Result<()> {
    for layer in 0..cfg.n_layers {
        for site in FOLDABLE_SITES {
            let key = format!("l{layer:02}.{site}");
            let sample = calibration.sites[&key].sample.clone();
            if sample.rows() == 0 {
                continue;
            }
            let names = cfg.site_weights(layer, site);
            let parts: Vec<&Tensor> = names
                .iter()
                .map(|n| w.get(n))
                .collect::<Result<Vec<_>>>()?;
            let wcat = Tensor::hcat(&parts)?;
            let res = awq_search(&sample, &wcat, weight_bits, grid);
            apply_site_fold(cfg, w, calibration, layer, site, &res.scale)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::run_calibration;
    use crate::model::config::tests::test_config;
    use crate::model::forward::forward_score;

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    #[test]
    fn smoothquant_fold_preserves_fp_function() {
        let cfg = test_config();
        let w0 = Weights::random_init(&cfg, 1);
        let seqs = vec![toks(16, 1), toks(16, 2)];
        let mut cal = run_calibration(&cfg, &w0, &seqs, 3).unwrap();
        let mut w1 = w0.clone();
        fold_smoothquant(&cfg, &mut w1, &mut cal, 0.5).unwrap();
        let t = toks(12, 4);
        let a = forward_score(&cfg, &w0, &t, None, None).unwrap();
        let b = forward_score(&cfg, &w1, &t, None, None).unwrap();
        let scale = a.max_abs().max(1.0);
        assert!(a.sub(&b).max_abs() / scale < 2e-3,
                "fold changed function by {}", a.sub(&b).max_abs());
    }

    #[test]
    fn fold_rescales_calibration() {
        let cfg = test_config();
        let w0 = Weights::random_init(&cfg, 1);
        let seqs = vec![toks(16, 1)];
        let mut cal = run_calibration(&cfg, &w0, &seqs, 3).unwrap();
        let before = cal.site(0, "qkv").absmax();
        let mut w1 = w0.clone();
        fold_smoothquant(&cfg, &mut w1, &mut cal, 0.8).unwrap();
        let after = cal.site(0, "qkv").absmax();
        // strong alpha strongly flattens the activation absmax profile
        let spread = |v: &[f32]| {
            let mx = v.iter().cloned().fold(0f32, f32::max);
            let mn = v.iter().cloned().fold(f32::INFINITY, f32::min).max(1e-9);
            mx / mn
        };
        assert!(spread(&after) < spread(&before),
                "{} !< {}", spread(&after), spread(&before));
    }

    #[test]
    fn moe_fold_preserves_function() {
        let mut cfg = test_config();
        cfg.n_experts = 3;
        cfg.top_k = 2;
        let w0 = Weights::random_init(&cfg, 5);
        let seqs = vec![toks(12, 7)];
        let mut cal = run_calibration(&cfg, &w0, &seqs, 3).unwrap();
        let mut w1 = w0.clone();
        fold_smoothquant(&cfg, &mut w1, &mut cal, 0.5).unwrap();
        let t = toks(10, 8);
        let a = forward_score(&cfg, &w0, &t, None, None).unwrap();
        let b = forward_score(&cfg, &w1, &t, None, None).unwrap();
        let scale = a.max_abs().max(1.0);
        assert!(a.sub(&b).max_abs() / scale < 2e-3);
    }
}
