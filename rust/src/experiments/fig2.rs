//! Fig. 2 / B.1 — SpinQuant's STE-driven oscillation: loss and gradient-
//! norm traces of Cayley SGD + STE on real calibration activations, at the
//! prescribed step count and at 10× (Fig. 2's orange curve), across three
//! models (Fig. B.1). Terminal sparklines replace the plot; the raw traces
//! land in reports/fig2_traces.json.

use anyhow::Result;

use super::ExpContext;
use crate::analysis::ste::{sparkline, ste_study};
use crate::calib::{calib_sequences, run_calibration};
use crate::util::bench::Table;
use crate::util::json::Json;

pub const MODELS: [&str; 3] = ["sq-s", "sq-m", "sq-l"];
pub const BASE_STEPS: usize = 100;

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let calib_corpus = ctx.corpus("wiki_train")?;
    let mut table = Table::new(
        "Fig 2/B.1: Cayley SGD + STE instability (loss & grad-norm tails)",
        &["site", "steps", "loss osc.", "grad floor", "step floor",
          "loss spark", "grad spark"],
    );
    let base = if ctx.budget.ppl_windows <= 4 { 30 } else { BASE_STEPS };
    let mut traces_json = Vec::new();
    for model in MODELS {
        let cfg = ctx.config(model)?;
        let weights = ctx.weights(model)?;
        let seqs = calib_sequences(&calib_corpus, 6, 48, 3);
        let cal = run_calibration(&cfg, &weights, &seqs, 3)?;
        for rep in ste_study(&cfg, &cal, &weights, base)? {
            table.row(vec![
                rep.site.clone(),
                rep.steps.to_string(),
                format!("{:.3}", rep.loss_oscillation),
                format!("{:.2e}", rep.grad_floor),
                format!("{:.2e}", rep.step_floor),
                sparkline(&rep.trace.loss, 32),
                sparkline(&rep.trace.grad_norm, 32),
            ]);
            println!("  [fig2] {} steps={}: osc {:.3} grad_floor {:.2e}",
                     rep.site, rep.steps, rep.loss_oscillation, rep.grad_floor);
            traces_json.push(Json::obj(vec![
                ("site", Json::str(rep.site.clone())),
                ("steps", Json::num(rep.steps as f64)),
                ("loss", Json::arr(rep.trace.loss.iter()
                                   .map(|&v| Json::num(v as f64)).collect())),
                ("grad_norm", Json::arr(rep.trace.grad_norm.iter()
                                        .map(|&v| Json::num(v as f64)).collect())),
            ]));
        }
    }
    table.print();
    ctx.write_report("fig2", &table.render())?;
    let dir = format!("{}/../reports", ctx.dir);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(format!("{dir}/fig2_traces.json"),
                   Json::arr(traces_json).to_string())?;
    Ok(vec![table])
}
