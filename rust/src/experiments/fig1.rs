//! Fig. 1a — the headline trade-off: quantization speed vs task accuracy
//! vs inference latency for the main methods, summarized in one table.
//!
//! Fig. 1b — deterministic outlier smoothing: quantization-space
//! utilization of real calibrated activations before/after each rotation
//! construction, plus a 2-D Lemma-1 demo.

use anyhow::Result;

use super::ExpContext;
use crate::analysis::outliers::{site_outlier_stats, utilization_after};
use crate::calib::{calib_sequences, run_calibration};
use crate::eval::tasks::zero_shot_suite;
use crate::pipeline::{quantize, Method, PipelineOptions};
use crate::rotation::givens::lemma1_givens;
use crate::util::bench::{bench_for, Table};

pub const MODEL: &str = "sq-m";

pub fn run_tradeoff(ctx: &ExpContext) -> Result<Vec<Table>> {
    let suite = ctx.tasks()?;
    let calib = ctx.corpus("wiki_train")?;
    let cfg = ctx.config(MODEL)?;
    let weights = ctx.weights(MODEL)?;

    let methods: Vec<(String, Method)> = vec![
        ("SpinQuant".into(), Method::SpinQuant { steps: 100 }),
        ("DuQuant".into(), Method::DuQuant { steps: 16 }),
        ("FlatQuant-like".into(), Method::FlatQuant { steps: 60 }),
        ("SingleQuant".into(), Method::singlequant()),
    ];
    let mut table = Table::new(
        "Fig 1a: quantization speed / accuracy / decode latency trade-off",
        &["method", "quant time (s)", "models/hour", "0-shot avg↑",
          "decode ms (b4)"],
    );
    for (label, method) in &methods {
        let opts = PipelineOptions { method: method.clone(), ..Default::default() };
        // quant time (single run here; Table 7 has the repeated-run version)
        let t0 = crate::util::clock::now();
        let _ = quantize(&cfg, &weights, &calib, &opts)?;
        let qt = t0.elapsed().as_secs_f64();
        let runner = ctx.runner(MODEL, &opts)?;
        let (_, zs) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
        // decode latency at batch 4
        let t = cfg.score_seq;
        let tokens = vec![3i32; 4 * t];
        let (_, mut kv) = runner.prefill(4, &tokens)?;
        let toks_step = vec![7i32; 4];
        let pos = vec![t as i32; 4];
        let d = bench_for("decode", 0.4, || {
            runner.decode(&mut kv, &toks_step, &pos).unwrap();
        });
        println!("  [fig1a] {label}: quant {qt:.2}s zs {:.1} decode {:.2}ms",
                 zs * 100.0, d.mean_s * 1e3);
        table.row(vec![
            label.clone(),
            format!("{qt:.3}"),
            format!("{:.0}", 3600.0 / qt.max(1e-9)),
            format!("{:.1}", zs * 100.0),
            format!("{:.2}", d.mean_s * 1e3),
        ]);
    }
    table.print();
    ctx.write_report("fig1a", &table.render())?;
    Ok(vec![table])
}

pub fn run_utilization(ctx: &ExpContext) -> Result<Vec<Table>> {
    // 2-D Lemma-1 demo
    let mut demo = Table::new(
        "Fig 1b (left): Lemma-1 Givens on a 2-D massive outlier",
        &["vector", "x", "y", "‖·‖∞"],
    );
    let v0 = [28.0f32, 0.4];
    let g = lemma1_givens(&v0, 0, 1);
    let mut v1 = v0;
    g.apply_row(&mut v1);
    demo.row(vec!["before".into(), format!("{:.2}", v0[0]),
                  format!("{:.2}", v0[1]),
                  format!("{:.2}", v0[0].abs().max(v0[1].abs()))]);
    demo.row(vec!["after θ*".into(), format!("{:.2}", v1[0]),
                  format!("{:.2}", v1[1]),
                  format!("{:.2}", v1[0].abs().max(v1[1].abs()))]);

    // real-site utilization before/after each construction
    let cfg = ctx.config(MODEL)?;
    let weights = ctx.weights(MODEL)?;
    let corpus = ctx.corpus("wiki_train")?;
    let seqs = calib_sequences(&corpus, 6, 64, 5);
    let cal = run_calibration(&cfg, &weights, &seqs, 5)?;

    let mut util = Table::new(
        "Fig 1b (right): quantization-space utilization per site",
        &["site", "MO ratio", "kurtosis", "before", "QuaRot", "DuQuant",
          "SingleQuant"],
    );
    let rot_methods: Vec<(&str, Method)> = vec![
        ("QuaRot", Method::QuaRot),
        ("DuQuant", Method::DuQuant { steps: 16 }),
        ("SingleQuant", Method::singlequant()),
    ];
    // build each method's rotations once via the pipeline
    let mut packages = Vec::new();
    for (_, m) in &rot_methods {
        let opts = PipelineOptions { method: m.clone(), ..Default::default() };
        packages.push(ctx.package(MODEL, &opts)?);
    }
    for layer in [0usize, cfg.n_layers - 1] {
        for site in ["qkv", "mlp", "down"] {
            let key = format!("l{layer:02}.{site}");
            let stats = site_outlier_stats(&cal, &key);
            let sample = &cal.sites[&key].sample;
            let mut row = vec![
                key.clone(),
                format!("{:.1}", stats.mo_ratio),
                format!("{:.1}", stats.kurtosis),
                format!("{:.3}", stats.utilization),
            ];
            for pkg in &packages {
                row.push(format!("{:.3}", utilization_after(sample, &pkg.rots[&key])));
            }
            util.row(row);
        }
    }
    demo.print();
    util.print();
    ctx.write_report("fig1b", &format!("{}\n{}", demo.render(), util.render()))?;
    Ok(vec![demo, util])
}
