//! Table 3 — the instruction-tuned (Vicuna stand-in) model on the
//! four-domain MMLU-like suite, 0-shot and 5-shot, W4A4.

use anyhow::Result;

use super::ExpContext;
use crate::eval::tasks::mmlu_suite;
use crate::pipeline::{Method, PipelineOptions};
use crate::quant::WeightQuantizer;
use crate::util::bench::Table;

pub const MODEL: &str = "sq-m-chat";

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let suite = ctx.mmlu()?;
    let methods: Vec<(String, PipelineOptions)> = vec![
        ("FP16".into(),
         PipelineOptions { method: Method::Fp16, ..Default::default() }),
        ("SmoothQuant".into(),
         PipelineOptions { method: Method::SmoothQuant { alpha: 0.5 },
                           ..Default::default() }),
        ("Atom-like (RTN-g)".into(),
         PipelineOptions { method: Method::Rtn,
                           weight_quantizer: WeightQuantizer::RtnGrouped(32),
                           ..Default::default() }),
        ("DuQuant".into(),
         PipelineOptions { method: Method::DuQuant { steps: 16 },
                           ..Default::default() }),
        ("SingleQuant".into(),
         PipelineOptions { method: Method::singlequant(), ..Default::default() }),
    ];

    let mut cols = vec!["method".to_string()];
    for shot in ["0shot", "5shot"] {
        for d in crate::eval::MMLU_DOMAINS {
            cols.push(format!("{shot} {d}"));
        }
        cols.push(format!("{shot} avg↑"));
    }
    let mut table = Table::new(
        "Table 3: MMLU-like accuracy, chat model (W4A4)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for (label, opts) in &methods {
        let runner = ctx.runner(MODEL, opts)?;
        let mut row = vec![label.clone()];
        for five in [false, true] {
            let (per, avg) = mmlu_suite(&runner, &suite, ctx.budget.mmlu_items, five)?;
            for (_, acc) in &per {
                row.push(format!("{:.1}", acc * 100.0));
            }
            row.push(format!("{:.1}", avg * 100.0));
            println!("  [table3] {label} {}shot: avg {:.1}",
                     if five { 5 } else { 0 }, avg * 100.0);
        }
        table.row(row);
    }
    table.print();
    ctx.write_report("table3", &table.render())?;
    Ok(vec![table])
}
