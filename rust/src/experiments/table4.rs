//! Table 4 — the MoE (Mixtral stand-in) model: W4A4 perplexity on both
//! corpora across methods. Expected shape: SingleQuant < DuQuant < AWQ <
//! QuaRot-RTN, all ≪ naive; FP16 best.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::pipeline::{Method, PipelineOptions};
use crate::util::bench::Table;

pub const MODEL: &str = "sq-moe";

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let methods: Vec<(String, PipelineOptions)> = vec![
        ("FP16".into(),
         PipelineOptions { method: Method::Fp16, ..Default::default() }),
        ("QuaRot".into(),
         PipelineOptions { method: Method::QuaRot, ..Default::default() }),
        ("AWQ".into(),
         PipelineOptions { method: Method::Awq { grid: 10 }, ..Default::default() }),
        ("DuQuant".into(),
         PipelineOptions { method: Method::DuQuant { steps: 16 },
                           ..Default::default() }),
        ("SingleQuant".into(),
         PipelineOptions { method: Method::singlequant(), ..Default::default() }),
    ];

    let mut table = Table::new(
        "Table 4: MoE (Mixtral-style) W4A4 perplexity",
        &["method", "wiki↓", "web↓"],
    );
    let cfg = ctx.config(MODEL)?;
    for (label, opts) in &methods {
        let runner = ctx.runner(MODEL, opts)?;
        let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
        let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
        println!("  [table4] {label}: wiki {p1:.3} web {p2:.3}");
        table.row(vec![label.clone(), format!("{p1:.3}"), format!("{p2:.3}")]);
    }
    table.print();
    ctx.write_report("table4", &table.render())?;
    Ok(vec![table])
}
