//! Table B.3 — weight-only quantization (W4A16 / W3A16) on the sq-m model:
//! RTN, GPTQ, GPTQ-grouped, AWQ, QuIP-style incoherence, SingleQuant.
//! Expected shape: at W4 everything is close; at W3 plain RTN collapses
//! while rotation/compensation methods stay usable, SingleQuant
//! competitive.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::pipeline::{Method, PipelineOptions};
use crate::quant::WeightQuantizer;
use crate::util::bench::Table;

pub const MODEL: &str = "sq-m";

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let cfg = ctx.config(MODEL)?;

    let methods: Vec<(String, Method, WeightQuantizer)> = vec![
        ("FP16".into(), Method::Fp16, WeightQuantizer::Rtn),
        ("RTN".into(), Method::Rtn, WeightQuantizer::Rtn),
        ("GPTQ".into(), Method::Rtn, WeightQuantizer::Gptq),
        ("GPTQ-g32".into(), Method::Rtn, WeightQuantizer::GptqGrouped(32)),
        ("AWQ".into(), Method::Awq { grid: 10 }, WeightQuantizer::Rtn),
        ("QuIP-like".into(), Method::Quip, WeightQuantizer::Rtn),
        ("SingleQuant".into(), Method::singlequant(), WeightQuantizer::Rtn),
    ];

    let mut table = Table::new(
        "Table B.3: weight-only perplexity (sq-m)",
        &["method", "W4A16 wiki↓", "W3A16 wiki↓", "W4A16 web↓", "W3A16 web↓"],
    );
    for (label, method, wq) in &methods {
        let mut cells = vec![label.clone()];
        let mut wiki_cells = Vec::new();
        let mut web_cells = Vec::new();
        for bits in [4u32, 3] {
            if matches!(method, Method::Fp16) && bits == 3 {
                wiki_cells.push("-".to_string());
                web_cells.push("-".to_string());
                continue;
            }
            let opts = PipelineOptions {
                method: method.clone(),
                weight_quantizer: *wq,
                weight_bits: bits,
                act_bits: 16,
                ..Default::default()
            };
            let runner = ctx.runner(MODEL, &opts)?;
            let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
            let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
            println!("  [tableb3] {label} W{bits}A16: wiki {p1:.3} web {p2:.3}");
            wiki_cells.push(format!("{p1:.3}"));
            web_cells.push(format!("{p2:.3}"));
        }
        cells.extend(wiki_cells);
        cells.extend(web_cells);
        table.row(cells);
    }
    table.print();
    ctx.write_report("tableb3", &table.render())?;
    Ok(vec![table])
}
