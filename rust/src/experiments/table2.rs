//! Table 2 + Appendix B.1 — zero-shot QA accuracy (six suites) of W4A4
//! models. Emits both the average table (Table 2) and the per-task detail
//! (Table B.1).

use anyhow::Result;

use super::ExpContext;
use crate::eval::tasks::zero_shot_suite;
use crate::util::bench::Table;

pub const MODELS: [&str; 3] = ["sq-s", "sq-m", "sq-l"];

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let suite = ctx.tasks()?;
    let methods = super::w4a4_method_matrix(true);

    let mut avg_cols = vec!["method".to_string()];
    avg_cols.extend(MODELS.iter().map(|m| format!("{m} avg↑")));
    let mut avg_table = Table::new(
        "Table 2: zero-shot 6-task average accuracy (W4A4)",
        &avg_cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let mut detail_cols = vec!["model".to_string(), "method".to_string()];
    detail_cols.extend(suite.tasks.iter().map(|(n, _)| format!("{n}↑")));
    detail_cols.push("avg↑".to_string());
    let mut detail = Table::new(
        "Table B.1: per-task zero-shot accuracy (W4A4)",
        &detail_cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for (label, opts) in &methods {
        let mut row = vec![label.clone()];
        for model in MODELS {
            let runner = ctx.runner(model, opts)?;
            let (per, avg) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
            row.push(format!("{:.1}", avg * 100.0));
            let mut drow = vec![model.to_string(), label.clone()];
            drow.extend(per.iter().map(|(_, a)| format!("{:.1}", a * 100.0)));
            drow.push(format!("{:.1}", avg * 100.0));
            detail.row(drow);
            println!("  [table2] {label} {model}: avg {:.1}", avg * 100.0);
        }
        avg_table.row(row);
    }
    avg_table.print();
    detail.print();
    ctx.write_report("table2", &format!("{}\n{}", avg_table.render(), detail.render()))?;
    Ok(vec![avg_table, detail])
}
