//! Table 8 — peak memory at batch 1 for prefill and decode: model storage
//! (packed-int weights + fp residue) plus activation/KV working set,
//! FP16(f32 here) vs the W4A4 methods. Expected shape: ~3–4× savings for
//! all W4A4 methods, SingleQuant marginally smallest (no extra transform
//! state beyond the Kronecker factors).

use anyhow::Result;

use super::ExpContext;
use crate::pipeline::{Method, PipelineOptions};
use crate::util::bench::Table;

pub const MODEL: &str = "sq-m";

struct MemRow {
    label: String,
    prefill_mb: f64,
    decode_mb: f64,
}

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let cfg = ctx.config(MODEL)?;
    let batch = 1usize;
    let t = cfg.score_seq;
    // Working-set model (bytes): KV cache + peak activation + logits.
    let kv = 2 * cfg.n_layers * batch * cfg.n_heads * cfg.max_seq * cfg.d_head() * 4;
    let act_prefill = batch * t * (cfg.d_model * 6 + cfg.d_ff * 2) * 4
        + batch * t * cfg.vocab_size * 4;
    let act_decode = batch * (cfg.d_model * 6 + cfg.d_ff * 2) * 4
        + batch * cfg.vocab_size * 4;

    let methods: Vec<(String, PipelineOptions)> = vec![
        ("FP16".into(),
         PipelineOptions { method: Method::Fp16, ..Default::default() }),
        ("SmoothQuant".into(),
         PipelineOptions { method: Method::SmoothQuant { alpha: 0.5 },
                           ..Default::default() }),
        ("QuaRot".into(),
         PipelineOptions { method: Method::QuaRot, ..Default::default() }),
        ("DuQuant".into(),
         PipelineOptions { method: Method::DuQuant { steps: 16 },
                           ..Default::default() }),
        ("SingleQuant".into(),
         PipelineOptions { method: Method::singlequant(), ..Default::default() }),
    ];

    let mut rows = Vec::new();
    for (label, opts) in &methods {
        let qm = ctx.package(MODEL, opts)?;
        // weight storage: packed ints for quantized linears, f32 rest;
        // plus the per-site rotation factors/clips a method must keep live.
        let rot_bytes: usize = qm
            .rots
            .values()
            .map(|r| (r.r1.len() + r.r2.len() + 1) * 4)
            .sum();
        let weights = if qm.packed_bytes > 0 {
            qm.packed_bytes + qm.fp_bytes + rot_bytes
        } else {
            qm.fp_bytes
        };
        rows.push(MemRow {
            label: label.clone(),
            prefill_mb: (weights + kv + act_prefill) as f64 / 1e6,
            decode_mb: (weights + kv + act_decode) as f64 / 1e6,
        });
    }

    let fp = (rows[0].prefill_mb, rows[0].decode_mb);
    let mut table = Table::new(
        "Table 8: peak memory at batch 1 (storage + working set)",
        &["method", "prefill (MB)", "saving", "decode (MB)", "saving"],
    );
    for r in &rows {
        table.row(vec![
            r.label.clone(),
            format!("{:.3}", r.prefill_mb),
            if r.label == "FP16" { "-".into() } else { format!("{:.2}×", fp.0 / r.prefill_mb) },
            format!("{:.3}", r.decode_mb),
            if r.label == "FP16" { "-".into() } else { format!("{:.2}×", fp.1 / r.decode_mb) },
        ]);
    }
    table.print();
    ctx.write_report("table8", &table.render())?;
    Ok(vec![table])
}
