//! Table 1 — W4A4 perplexity on the wiki-like (WikiText-2 stand-in) and
//! web-like (C4 stand-in) corpora across model scales and methods.
//!
//! Expected shape (paper): rotation methods ≪ SmoothQuant; SingleQuant
//! (RTN) competitive with or better than the optimized baselines, closest
//! to FP16.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::util::bench::Table;

pub const MODELS: [&str; 4] = ["sq-s", "sq-m", "sq-l", "sq-xl"];

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let methods = super::w4a4_method_matrix(true);

    let mut cols = vec!["method".to_string()];
    for m in MODELS {
        cols.push(format!("{m} wiki↓"));
        cols.push(format!("{m} web↓"));
    }
    let mut table = Table::new(
        "Table 1: W4A4 perplexity (wiki-like / web-like)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for (label, opts) in &methods {
        let mut row = vec![label.clone()];
        for model in MODELS {
            let cfg = ctx.config(model)?;
            let runner = ctx.runner(model, opts)?;
            let w = cfg.score_seq;
            let p1 = perplexity(&runner, &wiki, w, ctx.budget.ppl_windows)?;
            let p2 = perplexity(&runner, &web, w, ctx.budget.ppl_windows)?;
            row.push(format!("{p1:.3}"));
            row.push(format!("{p2:.3}"));
            println!("  [table1] {label} {model}: wiki {p1:.3} web {p2:.3}");
        }
        table.row(row);
    }
    table.print();
    ctx.write_report("table1", &table.render())?;
    Ok(vec![table])
}
