//! Experiment drivers: one module per table/figure of the paper.
//!
//! Each driver regenerates its artifact's rows through the full stack
//! (pipeline → PJRT runtime → eval harness) and returns rendered tables;
//! `singlequant reproduce <id>` is the CLI entry and `cargo bench` wraps
//! the timing-sensitive ones. Absolute numbers are testbed-bound (CPU
//! PJRT, ~1M-parameter models); the *shape* of each result — who wins, by
//! roughly what factor, where the crossovers sit — is the reproduction
//! target (DESIGN.md §Substitutions).

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod tableb3;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::eval::{MmluSuite, TaskSuite};
use crate::model::{ModelConfig, Weights};
use crate::pipeline::{quantize, Method, PipelineOptions, QuantizedModel};
use crate::quant::WeightQuantizer;
use crate::runtime::{Engine, ModelRunner};
use crate::util::bench::Table;
use crate::util::sqt::SqtFile;

/// Evaluation budget knobs (trimmed by `--fast`).
#[derive(Clone, Debug)]
pub struct EvalBudget {
    pub ppl_windows: usize,
    pub task_items: usize,
    pub mmlu_items: usize,
    pub serve_requests: usize,
}

impl EvalBudget {
    pub fn full() -> EvalBudget {
        EvalBudget { ppl_windows: 16, task_items: 48, mmlu_items: 32, serve_requests: 24 }
    }

    pub fn fast() -> EvalBudget {
        EvalBudget { ppl_windows: 4, task_items: 10, mmlu_items: 8, serve_requests: 6 }
    }
}

/// Shared experiment context: engine, corpora, suites, package cache.
pub struct ExpContext {
    pub engine: Arc<Engine>,
    pub dir: String,
    pub budget: EvalBudget,
    corpora: HashMap<String, Vec<u16>>,
    packages: std::sync::Mutex<HashMap<String, Arc<QuantizedModel>>>,
    runners: std::sync::Mutex<HashMap<String, Arc<ModelRunner>>>,
}

impl ExpContext {
    pub fn new(artifacts_dir: &str, budget: EvalBudget) -> Result<ExpContext> {
        let engine = Arc::new(Engine::new(artifacts_dir)?);
        Ok(ExpContext {
            engine,
            dir: artifacts_dir.to_string(),
            budget,
            corpora: HashMap::new(),
            packages: std::sync::Mutex::new(HashMap::new()),
            runners: std::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn corpus(&self, name: &str) -> Result<Vec<u16>> {
        if let Some(c) = self.corpora.get(name) {
            return Ok(c.clone());
        }
        let f = SqtFile::load(&format!("{}/data/corpus_{name}.sqt", self.dir))?;
        Ok(f.get("tokens")?.as_u16()?.to_vec())
    }

    pub fn tasks(&self) -> Result<TaskSuite> {
        TaskSuite::load(&format!("{}/data/tasks.json", self.dir))
    }

    pub fn mmlu(&self) -> Result<MmluSuite> {
        MmluSuite::load(&format!("{}/data/mmlu.json", self.dir))
    }

    pub fn weights(&self, model: &str) -> Result<Weights> {
        Weights::load(&format!("{}/ckpt/{model}.sqt", self.dir))
    }

    pub fn config(&self, model: &str) -> Result<ModelConfig> {
        self.engine.config(model)
    }

    /// Quantize (cached) under the given options.
    pub fn package(&self, model: &str, opts: &PipelineOptions) -> Result<Arc<QuantizedModel>> {
        let key = format!(
            "{model}|{}|{}|w{}a{}|lct{}",
            opts.method.cache_key(),
            opts.weight_quantizer.label(),
            opts.weight_bits,
            opts.act_bits,
            opts.lct
        );
        if let Some(p) = self.packages.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let cfg = self.config(model)?;
        let weights = self.weights(model)?;
        let calib = self.corpus("wiki_train")?;
        let qm = Arc::new(quantize(&cfg, &weights, &calib, opts)?);
        self.packages.lock().unwrap().insert(key, qm.clone());
        Ok(qm)
    }

    /// Runner for a quantized package (cached by the same key).
    pub fn runner(&self, model: &str, opts: &PipelineOptions) -> Result<Arc<ModelRunner>> {
        let key = format!(
            "{model}|{}|{}|w{}a{}|lct{}",
            opts.method.cache_key(),
            opts.weight_quantizer.label(),
            opts.weight_bits,
            opts.act_bits,
            opts.lct
        );
        if let Some(r) = self.runners.lock().unwrap().get(&key) {
            return Ok(r.clone());
        }
        let qm = self.package(model, opts)?;
        let runner = Arc::new(ModelRunner::new(self.engine.clone(), &qm)?);
        self.runners.lock().unwrap().insert(key, runner.clone());
        Ok(runner)
    }

    /// Write a rendered report to `<artifacts>/../reports/<name>.txt`.
    pub fn write_report(&self, name: &str, text: &str) -> Result<()> {
        let dir = format!("{}/../reports", self.dir);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(format!("{dir}/{name}.txt"), text)?;
        Ok(())
    }
}

/// The W4A4 method matrix shared by Tables 1 and 2 (label, options).
pub fn w4a4_method_matrix(full: bool) -> Vec<(String, PipelineOptions)> {
    let mut rows: Vec<(String, PipelineOptions)> = Vec::new();
    let base = PipelineOptions::default();
    let mk = |label: &str, method: Method, wq: WeightQuantizer| {
        (
            label.to_string(),
            PipelineOptions { method, weight_quantizer: wq, ..base.clone() },
        )
    };
    rows.push(mk("FP16", Method::Fp16, WeightQuantizer::Rtn));
    rows.push(mk("SmoothQuant (RTN)", Method::SmoothQuant { alpha: 0.5 },
                 WeightQuantizer::Rtn));
    rows.push(mk("RTN-only", Method::Rtn, WeightQuantizer::Rtn));
    rows.push(mk("QuaRot (RTN)", Method::QuaRot, WeightQuantizer::Rtn));
    if full {
        rows.push(mk("QuaRot (GPTQ)", Method::QuaRot, WeightQuantizer::Gptq));
    }
    rows.push(mk("SpinQuant (RTN)", Method::SpinQuant { steps: 100 },
                 WeightQuantizer::Rtn));
    if full {
        rows.push(mk("SpinQuant (GPTQ)", Method::SpinQuant { steps: 100 },
                     WeightQuantizer::Gptq));
    }
    rows.push(mk("DuQuant (RTN)", Method::DuQuant { steps: 16 },
                 WeightQuantizer::Rtn));
    rows.push(mk("SingleQuant (RTN)", Method::singlequant(), WeightQuantizer::Rtn));
    rows
}

/// Run one driver by id.
pub fn run_experiment(ctx: &ExpContext, id: &str) -> Result<Vec<Table>> {
    match id {
        "table1" => table1::run(ctx),
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        "table4" => table4::run(ctx),
        "table5" => table5::run(ctx),
        "table6" => table6::run(ctx),
        "table7" => table7::run(ctx),
        "table8" => table8::run(ctx),
        "tableb3" => tableb3::run(ctx),
        "fig1a" => fig1::run_tradeoff(ctx),
        "fig1b" => fig1::run_utilization(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "all" => {
            let mut out = Vec::new();
            for id in [
                "table1", "table2", "table3", "table4", "table5", "table6",
                "table7", "table8", "tableb3", "fig1a", "fig1b", "fig2",
                "fig3", "fig4",
            ] {
                println!(">>> {id}");
                out.extend(run_experiment(ctx, id)?);
            }
            Ok(out)
        }
        other => Err(anyhow!(
            "unknown experiment {other:?} (try table1..table8, tableb3, fig1a, fig1b, fig2, fig3, fig4, all)"
        )),
    }
}
