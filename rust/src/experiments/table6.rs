//! Table 6 — component ablation: ART and URT on/off in all four
//! combinations. Expected shape: ART alone > URT alone; ART+URT best.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::eval::tasks::zero_shot_suite;
use crate::pipeline::{Method, PipelineOptions};
use crate::rotation::singlequant::SingleQuantConfig;
use crate::util::bench::Table;

pub const MODELS: [&str; 2] = ["sq-m", "sq-l"];

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let suite = ctx.tasks()?;

    let mut cols = vec!["ART".to_string(), "URT".to_string()];
    for m in MODELS {
        cols.push(format!("{m} PPL avg↓"));
        cols.push(format!("{m} 0-shot↑"));
    }
    let mut table = Table::new(
        "Table 6: ART/URT ablation (W4A4, RTN weights)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for (art, urt) in [(false, false), (true, false), (false, true), (true, true)] {
        let sq = SingleQuantConfig { use_art: art, use_urt: urt, ..Default::default() };
        let opts = PipelineOptions {
            method: Method::SingleQuant(sq),
            ..Default::default()
        };
        let mark = |b: bool| if b { "✓" } else { "–" }.to_string();
        let mut row = vec![mark(art), mark(urt)];
        for model in MODELS {
            let cfg = ctx.config(model)?;
            let runner = ctx.runner(model, &opts)?;
            let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
            let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
            let (_, zs) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
            row.push(format!("{:.3}", (p1 + p2) / 2.0));
            row.push(format!("{:.1}", zs * 100.0));
            println!("  [table6] art={art} urt={urt} {model}: ppl {:.3} zs {:.1}",
                     (p1 + p2) / 2.0, zs * 100.0);
        }
        table.row(row);
    }
    table.print();
    ctx.write_report("table6", &table.render())?;
    Ok(vec![table])
}
