//! Table 5 — SingleQuant vs FlatQuant (both Kronecker-structured), with
//! and without the learnable clipping threshold (LCT). PPL AVG is the mean
//! of the two corpora; 0-shot is the 6-task average.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::eval::tasks::zero_shot_suite;
use crate::pipeline::{Method, PipelineOptions};
use crate::util::bench::Table;

pub const MODELS: [&str; 2] = ["sq-m", "sq-l"];

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let suite = ctx.tasks()?;

    let mut cols = vec!["config".to_string(), "method".to_string()];
    for m in MODELS {
        cols.push(format!("{m} PPL avg↓"));
        cols.push(format!("{m} 0-shot↑"));
    }
    let mut table = Table::new(
        "Table 5: SingleQuant vs FlatQuant, with/without LCT (W4A4)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for lct in [true, false] {
        for (label, method) in [
            ("FlatQuant", Method::FlatQuant { steps: 60 }),
            ("SingleQuant", Method::singlequant()),
        ] {
            let opts = PipelineOptions { method, lct, ..Default::default() };
            let mut row = vec![
                if lct { "w/ LCT" } else { "w/o LCT" }.to_string(),
                label.to_string(),
            ];
            for model in MODELS {
                let cfg = ctx.config(model)?;
                let runner = ctx.runner(model, &opts)?;
                let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
                let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
                let (_, zs) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
                row.push(format!("{:.3}", (p1 + p2) / 2.0));
                row.push(format!("{:.1}", zs * 100.0));
                println!("  [table5] lct={lct} {label} {model}: ppl {:.3} zs {:.1}",
                         (p1 + p2) / 2.0, zs * 100.0);
            }
            table.row(row);
        }
    }
    table.print();
    ctx.write_report("table5", &table.render())?;
    Ok(vec![table])
}
