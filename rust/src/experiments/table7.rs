//! Table 7 / B.2 — quantization wall-clock: SingleQuant's closed-form
//! construction vs the optimization-based baselines (OSTQuant-like =
//! FlatQuant-optimizer, SpinQuant). The paper's headline: SingleQuant is
//! 2–4 orders of magnitude faster (37 s vs 14 h on LLaMA-2-13B).

use anyhow::Result;

use super::ExpContext;
use crate::pipeline::{quantize, Method, PipelineOptions};
use crate::util::bench::Table;

pub const MODELS: [&str; 5] = ["sq-s", "sq-m", "sq-l", "sq-xl", "sq-moe"];
/// Repetitions per cell (the paper uses 10; trimmed under --fast).
pub fn reps(ctx: &ExpContext) -> usize {
    if ctx.budget.ppl_windows <= 4 { 2 } else { 5 }
}

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let methods: Vec<(String, Method)> = vec![
        ("OSTQuant-like".into(), Method::FlatQuant { steps: 60 }),
        ("SpinQuant".into(), Method::SpinQuant { steps: 100 }),
        ("SingleQuant".into(), Method::singlequant()),
    ];
    let mut cols = vec!["method".to_string()];
    cols.extend(MODELS.iter().map(|m| format!("{m} (s)")));
    cols.push("speedup vs Spin".to_string());
    let mut table = Table::new(
        "Table 7/B.2: quantization wall-clock (mean of repeated runs)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let calib = ctx.corpus("wiki_train")?;
    let n = reps(ctx);
    let mut spin_times = vec![0.0f64; MODELS.len()];
    let mut rows_raw: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, method) in &methods {
        let mut times = Vec::new();
        for (mi, model) in MODELS.iter().enumerate() {
            let cfg = ctx.config(model)?;
            let weights = ctx.weights(model)?;
            let opts = PipelineOptions { method: method.clone(), ..Default::default() };
            let mut total = 0.0f64;
            for _ in 0..n {
                let t0 = crate::util::clock::now();
                let qm = quantize(&cfg, &weights, &calib, &opts)?;
                std::hint::black_box(&qm.rots);
                total += t0.elapsed().as_secs_f64();
            }
            let mean = total / n as f64;
            if label == "SpinQuant" {
                spin_times[mi] = mean;
            }
            println!("  [table7] {label} {model}: {mean:.2}s");
            times.push(mean);
        }
        rows_raw.push((label.clone(), times));
    }
    for (label, times) in &rows_raw {
        let mut row = vec![label.clone()];
        row.extend(times.iter().map(|t| format!("{t:.3}")));
        let speedup: f64 = spin_times
            .iter()
            .zip(times)
            .map(|(s, t)| s / t.max(1e-9))
            .sum::<f64>()
            / MODELS.len() as f64;
        row.push(format!("{speedup:.0}×"));
        table.row(row);
    }
    table.print();
    ctx.write_report("table7", &table.render())?;
    Ok(vec![table])
}
