//! Fig. 4 — ART step sweep: performance vs the number of detect-and-rotate
//! repetitions. The paper's point: one closed-form rotation already
//! saturates; more steps add cost without consistent gains.

use anyhow::Result;

use super::ExpContext;
use crate::eval::ppl::perplexity;
use crate::eval::tasks::zero_shot_suite;
use crate::pipeline::{Method, PipelineOptions};
use crate::rotation::singlequant::SingleQuantConfig;
use crate::util::bench::Table;

pub const MODELS: [&str; 2] = ["sq-s", "sq-m"];
pub const STEPS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let suite = ctx.tasks()?;

    let mut cols = vec!["ART steps".to_string()];
    for m in MODELS {
        cols.push(format!("{m} PPL avg↓"));
        cols.push(format!("{m} 0-shot↑"));
    }
    let mut table = Table::new(
        "Fig 4: SingleQuant vs ART step count",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for steps in STEPS {
        let sq = SingleQuantConfig { art_steps: steps, ..Default::default() };
        let opts = PipelineOptions {
            method: Method::SingleQuant(sq),
            ..Default::default()
        };
        let mut row = vec![steps.to_string()];
        for model in MODELS {
            let cfg = ctx.config(model)?;
            let runner = ctx.runner(model, &opts)?;
            let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
            let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
            let (_, zs) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
            row.push(format!("{:.3}", (p1 + p2) / 2.0));
            row.push(format!("{:.1}", zs * 100.0));
            println!("  [fig4] steps={steps} {model}: ppl {:.3} zs {:.1}",
                     (p1 + p2) / 2.0, zs * 100.0);
        }
        table.row(row);
    }
    table.print();
    ctx.write_report("fig4", &table.render())?;
    Ok(vec![table])
}
