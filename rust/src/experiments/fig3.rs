//! Fig. 3 — prefill and decode speedup vs batch size through the serving
//! coordinator: FP16(f32) baseline vs the W4A4 runtime graph (SingleQuant
//! rotations, the INT4-path stand-in) vs a FlatQuant-style dense online
//! transform. Expected shape: quantized graphs faster than fp at equal
//! batch; speedup roughly stable across batch sizes; the Kronecker
//! transform's overhead small (Single ≈ INT4 > Flat-style).
//!
//! Note: on this CPU plugin INT4 GEMMs are fake-quant f32, so the
//! "speedup" here measures the *runtime-graph overhead* shape rather than
//! tensor-core gains; the analytic INT4 projection lives in
//! EXPERIMENTS.md.

use anyhow::Result;

use super::ExpContext;
use crate::pipeline::{Method, PipelineOptions};
use crate::util::bench::{bench_for, Table};
use crate::util::rng::Rng;

pub const MODEL: &str = "sq-m";

pub fn run(ctx: &ExpContext) -> Result<Vec<Table>> {
    let batches: Vec<usize> = ctx
        .engine
        .manifest
        .get("serve_batches")?
        .as_arr()?
        .iter()
        .map(|b| b.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let cfg = ctx.config(MODEL)?;
    let t = cfg.score_seq;

    let fp_opts = PipelineOptions { method: Method::Fp16, ..Default::default() };
    let sq_opts = PipelineOptions::default();
    let flat_opts = PipelineOptions {
        method: Method::FlatQuant { steps: 20 },
        ..Default::default()
    };
    let fp = ctx.runner(MODEL, &fp_opts)?;
    let sq = ctx.runner(MODEL, &sq_opts)?;
    let flat = ctx.runner(MODEL, &flat_opts)?;

    let mut rng = Rng::new(11);
    let budget = if ctx.budget.ppl_windows <= 4 { 0.4 } else { 1.2 };

    let mut prefill = Table::new(
        "Fig 3 (top): prefill time per call & speedup vs FP16",
        &["batch", "fp16 (ms)", "SingleQuant (ms)", "speedup", "Flat-style (ms)",
          "speedup"],
    );
    let mut decode = Table::new(
        "Fig 3 (bottom): decode step time & speedup vs FP16",
        &["batch", "fp16 (ms)", "SingleQuant (ms)", "speedup", "Flat-style (ms)",
          "speedup"],
    );

    for &b in &batches {
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(256) as i32).collect();
        let mut row_p = vec![b.to_string()];
        let mut row_d = vec![b.to_string()];
        let mut fp_ms = (0.0, 0.0);
        for (i, runner) in [&fp, &sq, &flat].iter().enumerate() {
            let s = bench_for(&format!("prefill b{b}"), budget, || {
                runner.prefill(b, &tokens).unwrap();
            });
            let (_, mut kv) = runner.prefill(b, &tokens)?;
            let toks_step: Vec<i32> = (0..b).map(|_| 7i32).collect();
            let pos: Vec<i32> = (0..b).map(|_| t as i32).collect();
            let d = bench_for(&format!("decode b{b}"), budget, || {
                runner.decode(&mut kv, &toks_step, &pos).unwrap();
            });
            let (pm, dm) = (s.mean_s * 1e3, d.mean_s * 1e3);
            if i == 0 {
                fp_ms = (pm, dm);
                row_p.push(format!("{pm:.1}"));
                row_d.push(format!("{dm:.2}"));
            } else {
                row_p.push(format!("{pm:.1}"));
                row_p.push(format!("{:.2}×", fp_ms.0 / pm));
                row_d.push(format!("{dm:.2}"));
                row_d.push(format!("{:.2}×", fp_ms.1 / dm));
            }
            println!("  [fig3] b{b} runner{i}: prefill {pm:.1}ms decode {dm:.2}ms");
        }
        prefill.row(row_p);
        decode.row(row_d);
    }
    prefill.print();
    decode.print();
    ctx.write_report("fig3", &format!("{}\n{}", prefill.render(), decode.render()))?;
    Ok(vec![prefill, decode])
}
