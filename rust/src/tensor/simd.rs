//! Runtime-selected SIMD microkernels for the dense and packed tiles.
//!
//! One [`Kernel`] is latched per process ([`active`]) and every matmul
//! dispatches through it: AVX2 on x86_64, NEON on aarch64, the scalar
//! code (the exact loops the repo shipped with) everywhere else and as
//! the forced fallback (`--kernel scalar` / `SQ_KERNEL=scalar`).
//!
//! Determinism contract, in two parts:
//!
//! * **Dense path is bit-identical to `Tensor::matmul`.** The reference
//!   accumulates ikj with a zero-skip (`*d += av * bv`). The vector
//!   version keeps that exact per-element operation sequence — it only
//!   vectorizes across output columns `j`, which are independent
//!   accumulators, and it never uses FMA (separate multiply and add,
//!   same IEEE ops as scalar). So every existing bit-identity test holds
//!   under SIMD, and results are invariant to both kernel and thread
//!   count.
//! * **Packed path is deterministic and thread-invariant, within the
//!   1e-4 dequant-reference tolerance.** Each quant group accumulates
//!   as `hsum(vector lanes) + scalar head/tail`, where the horizontal
//!   sum is a fixed pairwise tree — the same reduction order on every
//!   call. Different from the pure-scalar order (hence tolerance vs the
//!   dequantized reference, not bit-equality), but identical run-to-run
//!   and across thread counts, because threading partitions output
//!   elements, never the k-dimension.
//!
//! Packed decode does 8 codes per step: int≤4 columns store two codes
//! per byte, so one little-endian u32 load at an even code offset holds
//! lanes `0..8` as nibbles `(word >> 4*lane) & 0xF` ([`RepackedWeight`]
//! pads every column stride to 8 bytes so full-width loads are always
//! in-bounds). int5–8 columns sign-extend 8 bytes per step.

use std::sync::OnceLock;

use crate::quant::repack::RepackedWeight;
use crate::tensor::Tensor;

/// Output-column tile width for the dense kernel: one f32 C tile (and
/// the matching B panel stripe) stays L1-resident while k streams.
pub(crate) const NC: usize = 128;

/// A selected microkernel implementation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kernel {
    /// Portable reference loops — the universal fallback.
    Scalar,
    /// 8-lane AVX2 (x86_64, runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 4×2-lane NEON (aarch64, runtime-detected).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    pub fn label(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }
}

/// Best kernel this machine supports (runtime feature detection).
pub fn best() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

static ACTIVE: OnceLock<Kernel> = OnceLock::new();

/// The process-wide kernel, latched on first use. `SQ_KERNEL=scalar`
/// forces the fallback (how the CI matrix leg pins `cargo test` without
/// CLI plumbing); any other value autodetects.
pub fn active() -> Kernel {
    *ACTIVE.get_or_init(|| match std::env::var("SQ_KERNEL").as_deref() {
        Ok("scalar") => Kernel::Scalar,
        _ => best(),
    })
}

/// Pin the kernel by name (the `--kernel` flag). Returns the kernel
/// actually in effect — an earlier selection wins because dispatch
/// latches once per process.
pub fn force(name: &str) -> anyhow::Result<Kernel> {
    let want = match name {
        "scalar" => Kernel::Scalar,
        "simd" | "auto" => best(),
        other => anyhow::bail!("unknown kernel {other:?} (expected scalar|simd)"),
    };
    Ok(*ACTIVE.get_or_init(|| want))
}

/// Dense f32 tile: rows `i0..i1` × cols `j0..j1` of A·B into `out`
/// (row-major `[(i1-i0), (j1-j0)]`), bit-identical to `Tensor::matmul`
/// under every kernel.
pub(crate) fn f32_tile(
    kernel: Kernel,
    a: &Tensor,
    b: &Tensor,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    // the dense kernel accumulates into `out`, so the shadow pass must
    // replay from the same starting contents
    #[cfg(feature = "audit")]
    let before: Vec<f32> = if kernel == Kernel::Scalar { Vec::new() } else { out.to_vec() };
    match kernel {
        Kernel::Scalar => scalar::f32_tile(a, b, i0, i1, j0, j1, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Kernel::Avx2 is only constructed after runtime detection.
        Kernel::Avx2 => unsafe { avx2::f32_tile(a, b, i0, i1, j0, j1, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Kernel::Neon is only constructed after runtime detection.
        Kernel::Neon => unsafe { neon::f32_tile(a, b, i0, i1, j0, j1, out) },
    }
    #[cfg(feature = "audit")]
    audit::shadow_f32_tile(kernel, a, b, i0, i1, j0, j1, &before, out);
}

/// Packed tile: rows `i0..i1` × cols `c0..c1` of A·dequant(W) with the
/// dequantization fused into the k-loop.
pub(crate) fn packed_tile(
    kernel: Kernel,
    a: &Tensor,
    w: &RepackedWeight,
    i0: usize,
    i1: usize,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    match kernel {
        Kernel::Scalar => scalar::packed_tile(a, w, i0, i1, c0, c1, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Kernel::Avx2 is only constructed after runtime detection.
        Kernel::Avx2 => unsafe { avx2::packed_tile(a, w, i0, i1, c0, c1, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Kernel::Neon is only constructed after runtime detection.
        Kernel::Neon => unsafe { neon::packed_tile(a, w, i0, i1, c0, c1, out) },
    }
    #[cfg(feature = "audit")]
    audit::shadow_packed_tile(kernel, a, w, i0, i1, c0, c1, out);
}

/// Shadow execution (`--features audit`): every vector tile that the
/// unsafe kernels produce is recomputed with the scalar reference at
/// call granularity and compared — bit-exact for the dense path (whose
/// contract *is* bit-equality), within the 1e-4 dequant tolerance for
/// the packed path (whose fixed hsum tree reassociates the group sum).
/// A divergence panics with the tile coordinates; the audit build is a
/// debugging harness, not a serving configuration.
#[cfg(feature = "audit")]
pub(crate) mod audit {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::{scalar, Kernel, RepackedWeight, Tensor};

    /// Vector tiles cross-checked so far (tests assert this advances).
    pub static TILES_CHECKED: AtomicUsize = AtomicUsize::new(0);

    #[allow(clippy::too_many_arguments)]
    pub(super) fn shadow_f32_tile(
        kernel: Kernel,
        a: &Tensor,
        b: &Tensor,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        before: &[f32],
        got: &[f32],
    ) {
        if kernel == Kernel::Scalar {
            return;
        }
        let mut want = before.to_vec();
        scalar::f32_tile(a, b, i0, i1, j0, j1, &mut want);
        for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "audit: dense tile ({}) diverged from scalar at flat {idx} \
                 (rows {i0}..{i1}, cols {j0}..{j1}): {g:e} vs {w:e}",
                kernel.label()
            );
        }
        TILES_CHECKED.fetch_add(1, Ordering::Relaxed);
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn shadow_packed_tile(
        kernel: Kernel,
        a: &Tensor,
        w: &RepackedWeight,
        i0: usize,
        i1: usize,
        c0: usize,
        c1: usize,
        got: &[f32],
    ) {
        if kernel == Kernel::Scalar {
            return;
        }
        let mut want = vec![0.0f32; got.len()];
        scalar::packed_tile(a, w, i0, i1, c0, c1, &mut want);
        for (idx, (g, want)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - want).abs() <= 1e-4 * want.abs().max(1.0),
                "audit: packed tile ({}) diverged from scalar at flat {idx} \
                 (rows {i0}..{i1}, cols {c0}..{c1}): {g:e} vs {want:e}",
                kernel.label()
            );
        }
        TILES_CHECKED.fetch_add(1, Ordering::Relaxed);
    }
}

/// The portable reference loops — also the semantics contract the
/// vector paths are tested against.
mod scalar {
    use super::{RepackedWeight, Tensor, NC};

    pub fn f32_tile(
        a: &Tensor,
        b: &Tensor,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        out: &mut [f32],
    ) {
        let w = j1 - j0;
        for i in i0..i1 {
            let arow = a.row(i);
            let orow = &mut out[(i - i0) * w..(i - i0 + 1) * w];
            let mut t0 = j0;
            while t0 < j1 {
                let t1 = (t0 + NC).min(j1);
                let dst = &mut orow[t0 - j0..t1 - j0];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b.row(kk)[t0..t1];
                    for (d, &bv) in dst.iter_mut().zip(brow) {
                        *d += av * bv;
                    }
                }
                t0 = t1;
            }
        }
    }

    pub fn packed_tile(
        a: &Tensor,
        w: &RepackedWeight,
        i0: usize,
        i1: usize,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) {
        let width = c1 - c0;
        let k = w.rows;
        let group = w.group;
        let off = w.nibble_offset();
        let nibble = w.bits <= 4;
        for i in i0..i1 {
            let arow = a.row(i);
            let orow = &mut out[(i - i0) * width..(i - i0 + 1) * width];
            for c in c0..c1 {
                let codes = w.col_codes(c);
                let scales = w.col_scales(c);
                let mut total = 0.0f32;
                let mut k0 = 0usize;
                let mut g = 0usize;
                while k0 < k {
                    let k1 = (k0 + group).min(k);
                    let mut acc = 0.0f32;
                    if nibble {
                        let mut kk = k0;
                        if kk % 2 == 1 && kk < k1 {
                            let u = codes[kk / 2] >> 4;
                            acc += arow[kk] * (u as i32 - off) as f32;
                            kk += 1;
                        }
                        while kk + 1 < k1 {
                            let byte = codes[kk / 2];
                            acc += arow[kk] * ((byte & 0x0F) as i32 - off) as f32;
                            acc += arow[kk + 1] * ((byte >> 4) as i32 - off) as f32;
                            kk += 2;
                        }
                        if kk < k1 {
                            let byte = codes[kk / 2];
                            let u = if kk % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                            acc += arow[kk] * (u as i32 - off) as f32;
                        }
                    } else {
                        for (kk, &byte) in codes.iter().enumerate().take(k1).skip(k0) {
                            acc += arow[kk] * (byte as i8 as f32);
                        }
                    }
                    total += acc * scales[g];
                    g += 1;
                    k0 = k1;
                }
                orow[c - c0] = total;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{RepackedWeight, Tensor, NC};
    use std::arch::x86_64::*;

    /// Fixed pairwise reduction tree: (l0+l4)+(l2+l6) + ((l1+l5)+(l3+l7))
    /// — the same order on every call, so group sums are deterministic.
    ///
    /// SAFETY: caller must hold the runtime AVX2 witness (`Kernel::Avx2`
    /// is only constructed after detection).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum8(v: __m256) -> f32 {
        // SAFETY: pure register ops on the owned vector; no memory access.
        unsafe {
            let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
            _mm_cvtss_f32(s)
        }
    }

    /// Vectorized across output columns `j` only: each column keeps its
    /// own accumulator performing the identical `mul` then `add` the
    /// scalar loop does (no FMA), so results are bit-equal to scalar.
    ///
    /// SAFETY: caller must hold the runtime AVX2 witness (`Kernel::Avx2`
    /// is only constructed after detection).
    #[target_feature(enable = "avx2")]
    pub unsafe fn f32_tile(
        a: &Tensor,
        b: &Tensor,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        out: &mut [f32],
    ) {
        // SAFETY: unaligned loads/stores stay inside `dst`/`brow` — the
        // vector loop runs only while `j + 8 <= dst.len()` and both
        // slices are `t1 - t0` long; everything else is safe slice code.
        unsafe {
            let w = j1 - j0;
            for i in i0..i1 {
                let arow = a.row(i);
                let orow = &mut out[(i - i0) * w..(i - i0 + 1) * w];
                let mut t0 = j0;
                while t0 < j1 {
                    let t1 = (t0 + NC).min(j1);
                    let dst = &mut orow[t0 - j0..t1 - j0];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.row(kk)[t0..t1];
                        let va = _mm256_set1_ps(av);
                        let mut j = 0usize;
                        while j + 8 <= dst.len() {
                            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
                            let bb = _mm256_loadu_ps(brow.as_ptr().add(j));
                            let p = _mm256_mul_ps(va, bb);
                            _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(d, p));
                            j += 8;
                        }
                        while j < dst.len() {
                            dst[j] += av * brow[j];
                            j += 1;
                        }
                    }
                    t0 = t1;
                }
            }
        }
    }

    /// SAFETY: caller must hold the runtime AVX2 witness (`Kernel::Avx2`
    /// is only constructed after detection).
    #[target_feature(enable = "avx2")]
    pub unsafe fn packed_tile(
        a: &Tensor,
        w: &RepackedWeight,
        i0: usize,
        i1: usize,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) {
        // SAFETY: vector loads stay in-bounds — activation loads run only
        // while `kk + 8 <= k1 <= arow.len()`, and `RepackedWeight` pads
        // every column's code stride to 8 bytes so the 8-byte int8 load
        // at `kk` is always backed; everything else is safe slice code.
        unsafe {
            let width = c1 - c0;
            let k = w.rows;
            let group = w.group;
            let off = w.nibble_offset();
            let nibble = w.bits <= 4;
            let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
            let mask = _mm256_set1_epi32(0x0F);
            let voff = _mm256_set1_epi32(off);
            for i in i0..i1 {
                let arow = a.row(i);
                let orow = &mut out[(i - i0) * width..(i - i0 + 1) * width];
                for c in c0..c1 {
                    let codes = w.col_codes(c);
                    let scales = w.col_scales(c);
                    let mut total = 0.0f32;
                    let mut k0 = 0usize;
                    let mut g = 0usize;
                    while k0 < k {
                        let k1 = (k0 + group).min(k);
                        let mut acc = 0.0f32;
                        let mut vacc = _mm256_setzero_ps();
                        let mut kk = k0;
                        if nibble {
                            if kk % 2 == 1 && kk < k1 {
                                // align to an even code so u32 loads start on a byte
                                let u = codes[kk / 2] >> 4;
                                acc += arow[kk] * (u as i32 - off) as f32;
                                kk += 1;
                            }
                            while kk + 8 <= k1 {
                                // 4 bytes at code offset kk (even) = 8 nibble lanes
                                let word = u32::from_le_bytes(
                                    codes[kk / 2..kk / 2 + 4].try_into().unwrap(),
                                );
                                let q = _mm256_and_si256(
                                    _mm256_srlv_epi32(_mm256_set1_epi32(word as i32), shifts),
                                    mask,
                                );
                                let qf = _mm256_cvtepi32_ps(_mm256_sub_epi32(q, voff));
                                let av = _mm256_loadu_ps(arow.as_ptr().add(kk));
                                vacc = _mm256_add_ps(vacc, _mm256_mul_ps(av, qf));
                                kk += 8;
                            }
                            while kk < k1 {
                                let byte = codes[kk / 2];
                                let u = if kk % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                                acc += arow[kk] * (u as i32 - off) as f32;
                                kk += 1;
                            }
                        } else {
                            while kk + 8 <= k1 {
                                let bytes =
                                    _mm_loadl_epi64(codes.as_ptr().add(kk) as *const __m128i);
                                let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
                                let av = _mm256_loadu_ps(arow.as_ptr().add(kk));
                                vacc = _mm256_add_ps(vacc, _mm256_mul_ps(av, qf));
                                kk += 8;
                            }
                            while kk < k1 {
                                acc += arow[kk] * (codes[kk] as i8 as f32);
                                kk += 1;
                            }
                        }
                        total += (hsum8(vacc) + acc) * scales[g];
                        g += 1;
                        k0 = k1;
                    }
                    orow[c - c0] = total;
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{RepackedWeight, Tensor, NC};
    use std::arch::aarch64::*;

    /// Fixed pairwise tree over two 4-lane accumulators — deterministic
    /// reduction order, mirroring the AVX2 path.
    ///
    /// SAFETY: caller must hold the runtime NEON witness (`Kernel::Neon`
    /// is only constructed after detection).
    #[target_feature(enable = "neon")]
    unsafe fn hsum8(lo: float32x4_t, hi: float32x4_t) -> f32 {
        // SAFETY: pure register ops on the owned vectors; no memory access.
        unsafe {
            let s = vaddq_f32(lo, hi);
            let p = vadd_f32(vget_low_f32(s), vget_high_f32(s));
            vget_lane_f32::<0>(vpadd_f32(p, p))
        }
    }

    /// SAFETY: caller must hold the runtime NEON witness (`Kernel::Neon`
    /// is only constructed after detection).
    #[target_feature(enable = "neon")]
    pub unsafe fn f32_tile(
        a: &Tensor,
        b: &Tensor,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        out: &mut [f32],
    ) {
        // SAFETY: loads/stores stay inside `dst`/`brow` — the vector loop
        // runs only while `j + 4 <= dst.len()` and both slices are
        // `t1 - t0` long; everything else is safe slice code.
        unsafe {
            let w = j1 - j0;
            for i in i0..i1 {
                let arow = a.row(i);
                let orow = &mut out[(i - i0) * w..(i - i0 + 1) * w];
                let mut t0 = j0;
                while t0 < j1 {
                    let t1 = (t0 + NC).min(j1);
                    let dst = &mut orow[t0 - j0..t1 - j0];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.row(kk)[t0..t1];
                        let va = vdupq_n_f32(av);
                        let mut j = 0usize;
                        while j + 4 <= dst.len() {
                            let d = vld1q_f32(dst.as_ptr().add(j));
                            let bb = vld1q_f32(brow.as_ptr().add(j));
                            // separate mul + add (no vfmaq): bit-equal to scalar
                            vst1q_f32(dst.as_mut_ptr().add(j), vaddq_f32(d, vmulq_f32(va, bb)));
                            j += 4;
                        }
                        while j < dst.len() {
                            dst[j] += av * brow[j];
                            j += 1;
                        }
                    }
                    t0 = t1;
                }
            }
        }
    }

    /// SAFETY: caller must hold the runtime NEON witness (`Kernel::Neon`
    /// is only constructed after detection).
    #[target_feature(enable = "neon")]
    pub unsafe fn packed_tile(
        a: &Tensor,
        w: &RepackedWeight,
        i0: usize,
        i1: usize,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) {
        // SAFETY: vector loads stay in-bounds — activation loads run only
        // while `kk + 8 <= k1 <= arow.len()`, and `RepackedWeight` pads
        // every column's code stride to 8 bytes so the 8-byte int8 load
        // at `kk` is always backed; everything else is safe slice code.
        unsafe {
            let width = c1 - c0;
            let k = w.rows;
            let group = w.group;
            let off = w.nibble_offset();
            let nibble = w.bits <= 4;
            // vshlq by a negative count is a right shift
            let sh_lo = vld1q_s32([0i32, -4, -8, -12].as_ptr());
            let sh_hi = vld1q_s32([-16i32, -20, -24, -28].as_ptr());
            let mask = vdupq_n_u32(0x0F);
            let voff = vdupq_n_s32(off);
            for i in i0..i1 {
                let arow = a.row(i);
                let orow = &mut out[(i - i0) * width..(i - i0 + 1) * width];
                for c in c0..c1 {
                    let codes = w.col_codes(c);
                    let scales = w.col_scales(c);
                    let mut total = 0.0f32;
                    let mut k0 = 0usize;
                    let mut g = 0usize;
                    while k0 < k {
                        let k1 = (k0 + group).min(k);
                        let mut acc = 0.0f32;
                        let mut acc_lo = vdupq_n_f32(0.0);
                        let mut acc_hi = vdupq_n_f32(0.0);
                        let mut kk = k0;
                        if nibble {
                            if kk % 2 == 1 && kk < k1 {
                                let u = codes[kk / 2] >> 4;
                                acc += arow[kk] * (u as i32 - off) as f32;
                                kk += 1;
                            }
                            while kk + 8 <= k1 {
                                let word = u32::from_le_bytes(
                                    codes[kk / 2..kk / 2 + 4].try_into().unwrap(),
                                );
                                let vw = vdupq_n_u32(word);
                                let lo = vandq_u32(vshlq_u32(vw, sh_lo), mask);
                                let hi = vandq_u32(vshlq_u32(vw, sh_hi), mask);
                                let qlo =
                                    vcvtq_f32_s32(vsubq_s32(vreinterpretq_s32_u32(lo), voff));
                                let qhi =
                                    vcvtq_f32_s32(vsubq_s32(vreinterpretq_s32_u32(hi), voff));
                                let a_lo = vld1q_f32(arow.as_ptr().add(kk));
                                let a_hi = vld1q_f32(arow.as_ptr().add(kk + 4));
                                acc_lo = vaddq_f32(acc_lo, vmulq_f32(a_lo, qlo));
                                acc_hi = vaddq_f32(acc_hi, vmulq_f32(a_hi, qhi));
                                kk += 8;
                            }
                            while kk < k1 {
                                let byte = codes[kk / 2];
                                let u = if kk % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                                acc += arow[kk] * (u as i32 - off) as f32;
                                kk += 1;
                            }
                        } else {
                            while kk + 8 <= k1 {
                                let b8 = vld1_s8(codes.as_ptr().add(kk) as *const i8);
                                let w16 = vmovl_s8(b8);
                                let qlo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16)));
                                let qhi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16)));
                                let a_lo = vld1q_f32(arow.as_ptr().add(kk));
                                let a_hi = vld1q_f32(arow.as_ptr().add(kk + 4));
                                acc_lo = vaddq_f32(acc_lo, vmulq_f32(a_lo, qlo));
                                acc_hi = vaddq_f32(acc_hi, vmulq_f32(a_hi, qhi));
                                kk += 8;
                            }
                            while kk < k1 {
                                acc += arow[kk] * (codes[kk] as i8 as f32);
                                kk += 1;
                            }
                        }
                        total += (hsum8(acc_lo, acc_hi) + acc) * scales[g];
                        g += 1;
                        k0 = k1;
                    }
                    orow[c - c0] = total;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tile_full_f32(kernel: Kernel, a: &Tensor, b: &Tensor) -> Vec<f32> {
        let mut out = vec![0.0f32; a.rows() * b.cols()];
        f32_tile(kernel, a, b, 0, a.rows(), 0, b.cols(), &mut out);
        out
    }

    fn tile_full_packed(kernel: Kernel, a: &Tensor, w: &RepackedWeight) -> Vec<f32> {
        let mut out = vec![0.0f32; a.rows() * w.cols];
        packed_tile(kernel, a, w, 0, a.rows(), 0, w.cols, &mut out);
        out
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Kernel::Scalar.label(), "scalar");
        assert!(["scalar", "avx2", "neon"].contains(&best().label()));
        assert!(["scalar", "avx2", "neon"].contains(&active().label()));
    }

    #[test]
    fn dense_simd_is_bit_identical_to_scalar() {
        let kern = best();
        let mut rng = Rng::new(11);
        // odd shapes exercise the vector tails; 0.0-heavy A exercises
        // the zero-skip both paths share
        for (m, k, n) in [(1usize, 17usize, 23usize), (3, 64, 130), (5, 33, 8)] {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            for (idx, v) in a.data_mut().iter_mut().enumerate() {
                if idx % 5 == 0 {
                    *v = 0.0;
                }
            }
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            assert_eq!(
                tile_full_f32(kern, &a, &b),
                tile_full_f32(Kernel::Scalar, &a, &b),
                "m={m} k={k} n={n} kernel={}",
                kern.label()
            );
        }
    }

    #[test]
    fn packed_simd_matches_scalar_within_tolerance() {
        let kern = best();
        let mut rng = Rng::new(12);
        // spans: nibble + byte layouts, odd k (head/tail lanes), odd groups
        for bits in [2u32, 4, 5, 8] {
            for (k, group) in [(37usize, 8usize), (64, 16), (51, 51), (9, 3)] {
                let w = Tensor::randn(&[k, 13], 0.7, &mut rng);
                let x = Tensor::randn(&[2, k], 1.0, &mut rng);
                let rw = RepackedWeight::pack(&w, bits, group).unwrap();
                let simd = tile_full_packed(kern, &x, &rw);
                let scalar = tile_full_packed(Kernel::Scalar, &x, &rw);
                for (a, b) in simd.iter().zip(&scalar) {
                    assert!(
                        (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                        "bits={bits} k={k} group={group} kernel={}: {a} vs {b}",
                        kern.label()
                    );
                }
            }
        }
    }

    /// With `--features audit`, every vector tile above also ran its
    /// scalar shadow; this pins that the cross-check actually fires
    /// (on scalar-only hosts the audit is vacuous by design).
    #[cfg(feature = "audit")]
    #[test]
    fn audit_shadow_checks_fire_on_vector_kernels() {
        use std::sync::atomic::Ordering;
        let kern = best();
        let before = audit::TILES_CHECKED.load(Ordering::Relaxed);
        let mut rng = Rng::new(14);
        let a = Tensor::randn(&[2, 24], 1.0, &mut rng);
        let b = Tensor::randn(&[24, 16], 1.0, &mut rng);
        tile_full_f32(kern, &a, &b);
        let w = Tensor::randn(&[24, 5], 0.5, &mut rng);
        let rw = RepackedWeight::pack(&w, 4, 8).unwrap();
        tile_full_packed(kern, &a, &rw);
        let after = audit::TILES_CHECKED.load(Ordering::Relaxed);
        if kern == Kernel::Scalar {
            assert_eq!(after, before, "scalar tiles need no shadow");
        } else {
            assert!(after >= before + 2, "shadow checks did not run");
        }
    }

    #[test]
    fn packed_simd_is_deterministic_across_calls() {
        let kern = best();
        let mut rng = Rng::new(13);
        let w = Tensor::randn(&[40, 6], 0.5, &mut rng);
        let x = Tensor::randn(&[1, 40], 1.0, &mut rng);
        let rw = RepackedWeight::pack(&w, 4, 16).unwrap();
        let first = tile_full_packed(kern, &x, &rw);
        for _ in 0..3 {
            assert_eq!(tile_full_packed(kern, &x, &rw), first);
        }
    }
}
