//! Distribution statistics used by calibration and the outlier analyses
//! (Fig. 1b quantization-space utilization, MO/NO detection).

use super::Tensor;

pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

pub fn variance(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
}

/// Excess kurtosis (0 for a Gaussian): the paper's proxy for how heavy-
/// tailed / outlier-dominated an activation distribution is.
pub fn kurtosis(xs: &[f32]) -> f32 {
    let m = mean(xs);
    let var = variance(xs).max(1e-12);
    let m4 = xs.iter().map(|x| (x - m).powi(4)).sum::<f32>() / xs.len() as f32;
    m4 / (var * var) - 3.0
}

/// p-th percentile (0..=100) by sorting a copy.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Indices that sort `xs` ascending.
pub fn argsort(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    idx
}

pub fn argmax_abs(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if x.abs() > xs[best].abs() {
            best = i;
        }
    }
    best
}

pub fn argmin_abs(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if x.abs() < xs[best].abs() {
            best = i;
        }
    }
    best
}

/// Per-column max |x| of a [T, n] activation matrix (channel absmax profile).
pub fn col_absmax(x: &Tensor) -> Vec<f32> {
    let (t, n) = (x.rows(), x.cols());
    let mut out = vec![0.0f32; n];
    for i in 0..t {
        for (j, &v) in x.row(i).iter().enumerate() {
            out[j] = out[j].max(v.abs());
        }
    }
    let _ = t;
    out
}

/// Per-column signed value of maximum magnitude (keeps the outlier's sign,
/// which ART's closed-form angle uses).
pub fn col_signed_absmax(x: &Tensor) -> Vec<f32> {
    let (t, n) = (x.rows(), x.cols());
    let mut out = vec![0.0f32; n];
    for i in 0..t {
        for (j, &v) in x.row(i).iter().enumerate() {
            if v.abs() > out[j].abs() {
                out[j] = v;
            }
        }
    }
    let _ = t;
    out
}

/// Per-column median of a [T, n] matrix (URT's NO profile; medians are the
/// "consistent across tokens" statistic the paper cites for normal outliers).
pub fn col_median(x: &Tensor) -> Vec<f32> {
    let (t, n) = (x.rows(), x.cols());
    let mut out = vec![0.0f32; n];
    let mut buf = vec![0.0f32; t];
    for j in 0..n {
        for i in 0..t {
            buf[i] = x.at(i, j);
        }
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[j] = buf[t / 2];
    }
    out
}

/// Per-row max |x| (per-token scale basis of the A4 quantizer).
pub fn row_absmax(x: &Tensor) -> Vec<f32> {
    (0..x.rows())
        .map(|i| x.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
        .collect()
}

/// Quantization-space utilization (Fig. 1b): the fraction of the
/// [-absmax, absmax] range that the bulk (99th percentile) of the data
/// actually occupies. Near 1.0 = well-spread; ≪ 1 = outlier-dominated.
pub fn quant_space_utilization(xs: &[f32]) -> f32 {
    let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let abs: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    percentile(&abs, 99.0) / absmax
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kurtosis_gaussian_near_zero() {
        let mut rng = Rng::new(1);
        let xs = rng.normal_vec(30_000, 1.0);
        assert!(kurtosis(&xs).abs() < 0.3, "{}", kurtosis(&xs));
    }

    #[test]
    fn kurtosis_spiked_is_large() {
        let mut rng = Rng::new(2);
        let mut xs = rng.normal_vec(1000, 1.0);
        xs[0] = 100.0;
        assert!(kurtosis(&xs) > 50.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn argsort_sorts() {
        let xs = vec![3.0, 1.0, 2.0];
        assert_eq!(argsort(&xs), vec![1, 2, 0]);
    }

    #[test]
    fn utilization_detects_outliers() {
        let mut rng = Rng::new(3);
        let clean = rng.normal_vec(2000, 1.0);
        let mut spiked = clean.clone();
        spiked[7] = 50.0;
        assert!(quant_space_utilization(&clean) > 0.5);
        assert!(quant_space_utilization(&spiked) < 0.2);
    }

    #[test]
    fn col_profiles() {
        let x = Tensor::from_raw(vec![2, 3], vec![1., -5., 2., -3., 4., 2.]);
        assert_eq!(col_absmax(&x), vec![3., 5., 2.]);
        assert_eq!(col_signed_absmax(&x), vec![-3., -5., 2.]);
        assert_eq!(row_absmax(&x), vec![5., 4.]);
    }
}
