//! Multi-threaded CPU matmul kernels — the native serving backend's hot
//! path.
//!
//! Three kernels, all `std::thread::scope`-parallel with deterministic
//! results (each output element's k-accumulation order is fixed, so thread
//! count never changes the numbers):
//!
//! * [`matmul_threaded`] — dense f32 GEMM, element-identical to
//!   `Tensor::matmul` (same ascending-k, zero-skip accumulation), blocked
//!   over output-column tiles so the C row and streamed B rows stay in
//!   cache.
//! * [`matmul_packed`] — fused dequant-in-inner-loop GEMM over
//!   [`RepackedWeight`]: nibble-interleaved int≤4 codes decode inside the
//!   k-loop, group scales multiply once per (element, group) — the weight
//!   never materializes as f32.
//! * [`givens_rotate_rows`] — O(k)-per-row fused [`GivensChain`]
//!   application (k = chain length), the chain-form alternative to a dense
//!   rotation matmul for URT-style site rotations.
//!
//! Work is partitioned over output rows when the activation batch is tall
//! (prefill) and over output columns when it is short (single-token
//! decode), so both serving phases scale with cores.

use crate::quant::repack::RepackedWeight;
use crate::rotation::givens::GivensChain;
use crate::tensor::Tensor;

/// Resolve a requested worker count: 0 means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Output-column tile width: one f32 C tile (and the matching B panel
/// stripe) stays L1-resident while k streams.
const NC: usize = 128;

/// Below this many multiply-adds a GEMM runs serially: thread spawn/join
/// costs more than the math (small-model decode steps issue many tiny
/// matmuls). Results are identical either way — the serial path is the
/// same kernel.
const PAR_THRESHOLD_FLOPS: usize = 64 * 1024;

/// Dense f32 tile: rows `i0..i1` × cols `j0..j1` of A·B into `out`
/// (row-major `[(i1-i0), (j1-j0)]`). Accumulation per element is ascending
/// k with `a == 0.0` skipped — exactly `Tensor::matmul`'s order.
fn f32_tile(a: &Tensor, b: &Tensor, i0: usize, i1: usize, j0: usize, j1: usize,
            out: &mut [f32]) {
    let w = j1 - j0;
    for i in i0..i1 {
        let arow = a.row(i);
        let orow = &mut out[(i - i0) * w..(i - i0 + 1) * w];
        let mut t0 = j0;
        while t0 < j1 {
            let t1 = (t0 + NC).min(j1);
            let dst = &mut orow[t0 - j0..t1 - j0];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b.row(kk)[t0..t1];
                for (d, &bv) in dst.iter_mut().zip(brow) {
                    *d += av * bv;
                }
            }
            t0 = t1;
        }
    }
}

/// Packed tile: rows `i0..i1` × cols `c0..c1` of A·dequant(W) with the
/// dequantization fused into the k-loop (codes decode in registers, the
/// group scale multiplies the partial sum once per group).
fn packed_tile(a: &Tensor, w: &RepackedWeight, i0: usize, i1: usize,
               c0: usize, c1: usize, out: &mut [f32]) {
    let width = c1 - c0;
    let k = w.rows;
    let group = w.group;
    let off = w.nibble_offset();
    let nibble = w.bits <= 4;
    for i in i0..i1 {
        let arow = a.row(i);
        let orow = &mut out[(i - i0) * width..(i - i0 + 1) * width];
        for c in c0..c1 {
            let codes = w.col_codes(c);
            let scales = w.col_scales(c);
            let mut total = 0.0f32;
            let mut k0 = 0usize;
            let mut g = 0usize;
            while k0 < k {
                let k1 = (k0 + group).min(k);
                let mut acc = 0.0f32;
                if nibble {
                    let mut kk = k0;
                    if kk % 2 == 1 && kk < k1 {
                        let u = codes[kk / 2] >> 4;
                        acc += arow[kk] * (u as i32 - off) as f32;
                        kk += 1;
                    }
                    while kk + 1 < k1 {
                        let byte = codes[kk / 2];
                        acc += arow[kk] * ((byte & 0x0F) as i32 - off) as f32;
                        acc += arow[kk + 1] * ((byte >> 4) as i32 - off) as f32;
                        kk += 2;
                    }
                    if kk < k1 {
                        let byte = codes[kk / 2];
                        let u = if kk % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                        acc += arow[kk] * (u as i32 - off) as f32;
                    }
                } else {
                    for (kk, &byte) in codes.iter().enumerate().take(k1).skip(k0) {
                        acc += arow[kk] * (byte as i8 as f32);
                    }
                }
                total += acc * scales[g];
                g += 1;
                k0 = k1;
            }
            orow[c - c0] = total;
        }
    }
}

/// Run a tile computation over `m` output rows × `n` output cols with
/// `threads` workers: row-partitioned when the batch is tall, column-
/// partitioned (per-thread tiles merged afterwards) when it is short.
/// `work` is the approximate multiply-add count (m·n·k) — tiny problems
/// run serially rather than paying thread spawn/join.
fn run_partitioned<F>(m: usize, n: usize, work: usize, threads: usize, tile: F) -> Vec<f32>
where
    F: Fn(usize, usize, usize, usize, &mut [f32]) + Sync,
{
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let threads = threads.clamp(1, m.max(n));
    if threads <= 1 || work < PAR_THRESHOLD_FLOPS {
        tile(0, m, 0, n, &mut out);
        return out;
    }
    if m >= threads {
        // tall batch: contiguous row ranges, written in place
        let chunk = m.div_ceil(threads);
        std::thread::scope(|s| {
            let tile = &tile;
            let mut rest: &mut [f32] = &mut out;
            let mut lo = 0usize;
            while lo < m {
                let hi = (lo + chunk).min(m);
                let (head, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * n);
                rest = tail;
                s.spawn(move || tile(lo, hi, 0, n, head));
                lo = hi;
            }
        });
        return out;
    }
    // short batch (decode): column ranges into per-thread tiles
    let chunk = n.div_ceil(threads).max(1);
    let mut tiles: Vec<(usize, usize, Vec<f32>)> = Vec::new();
    std::thread::scope(|s| {
        let tile = &tile;
        let mut handles = Vec::new();
        let mut c0 = 0usize;
        while c0 < n {
            let c1 = (c0 + chunk).min(n);
            handles.push((c0, c1, s.spawn(move || {
                let mut t = vec![0.0f32; m * (c1 - c0)];
                tile(0, m, c0, c1, &mut t);
                t
            })));
            c0 = c1;
        }
        for (c0, c1, h) in handles {
            tiles.push((c0, c1, h.join().expect("kernel worker panicked")));
        }
    });
    for (c0, c1, t) in tiles {
        let w = c1 - c0;
        for i in 0..m {
            out[i * n + c0..i * n + c1].copy_from_slice(&t[i * w..(i + 1) * w]);
        }
    }
    out
}

/// C = A·B with `threads` workers (0 = all cores). Element-identical to
/// `Tensor::matmul` at any thread count.
pub fn matmul_threaded(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_threaded {:?} @ {:?}", a.shape(), b.shape());
    let threads = resolve_threads(threads);
    let out = run_partitioned(m, n, m * n * k, threads, |i0, i1, j0, j1, dst| {
        f32_tile(a, b, i0, i1, j0, j1, dst);
    });
    Tensor::from_raw(vec![m, n], out)
}

/// C = A·dequant(W) with dequantization fused into the inner loop —
/// the packed weight is never materialized as f32.
pub fn matmul_packed(a: &Tensor, w: &RepackedWeight, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(k, w.rows, "matmul_packed {:?} @ [{}, {}]", a.shape(), w.rows, w.cols);
    let threads = resolve_threads(threads);
    let n = w.cols;
    let out = run_partitioned(m, n, m * n * k, threads, |i0, i1, c0, c1, dst| {
        packed_tile(a, w, i0, i1, c0, c1, dst);
    });
    Tensor::from_raw(vec![m, n], out)
}

/// Apply a Givens chain to every row of `x` in place: O(len(chain)) per
/// row instead of the O(n²) dense-rotation matmul.
pub fn givens_rotate_rows(x: &mut Tensor, chain: &GivensChain, threads: usize) {
    let t = x.rows();
    let n = x.cols();
    if t == 0 || n == 0 {
        return;
    }
    let threads = resolve_threads(threads).min(t);
    // ~6 flops per rotation; below the parallel threshold spawn cost wins
    if threads <= 1 || t * chain.len() * 6 < PAR_THRESHOLD_FLOPS {
        for i in 0..t {
            chain.apply_row(x.row_mut(i));
        }
        return;
    }
    let chunk = t.div_ceil(threads);
    let data = x.data_mut();
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = data;
        while !rest.is_empty() {
            let take = (chunk * n).min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            s.spawn(move || {
                for row in head.chunks_mut(n) {
                    chain.apply_row(row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rotation::givens::map_to_e1;
    use crate::util::rng::Rng;

    #[test]
    fn threaded_matmul_matches_serial_exactly() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1usize, 7usize, 13usize), (5, 9, 4), (17, 33, 29), (8, 64, 96)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let reference = a.matmul(&b);
            for threads in [1usize, 2, 4, 7] {
                let got = matmul_threaded(&a, &b, threads);
                assert_eq!(got.data(), reference.data(),
                           "m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn packed_matmul_matches_dequantized_reference() {
        let mut rng = Rng::new(2);
        for bits in [2u32, 3, 4, 5, 8] {
            let w = Tensor::randn(&[37, 11], 0.7, &mut rng);
            let x = Tensor::randn(&[5, 37], 1.0, &mut rng);
            for group in [8usize, 37] {
                let rw = RepackedWeight::pack(&w, bits, group).unwrap();
                let reference = x.matmul(&rw.dequantize());
                let got = matmul_packed(&x, &rw, 3);
                for (a, b) in got.data().iter().zip(reference.data()) {
                    assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                            "bits={bits} group={group}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_is_thread_count_invariant() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[64, 24], 0.5, &mut rng);
        let x = Tensor::randn(&[3, 64], 1.0, &mut rng);
        let rw = RepackedWeight::pack(&w, 4, 16).unwrap();
        let one = matmul_packed(&x, &rw, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(matmul_packed(&x, &rw, threads).data(), one.data());
        }
    }

    #[test]
    fn givens_rows_match_dense_rotation() {
        let mut rng = Rng::new(4);
        let chain = map_to_e1(&rng.normal_vec(16, 1.0));
        let x = Tensor::randn(&[9, 16], 1.0, &mut rng);
        let dense = x.matmul(&chain.to_matrix(16));
        for threads in [1usize, 3] {
            let mut got = x.clone();
            givens_rotate_rows(&mut got, &chain, threads);
            assert!(got.sub(&dense).max_abs() < 1e-4);
        }
    }
}
