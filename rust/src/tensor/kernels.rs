//! Multi-threaded CPU matmul kernels — the native serving backend's hot
//! path.
//!
//! Three kernels, all dispatched over the persistent worker pool
//! ([`crate::tensor::pool`]) with deterministic results (each output
//! element's k-accumulation order is fixed, so thread count never
//! changes the numbers):
//!
//! * [`matmul_threaded`] — dense f32 GEMM, element-identical to
//!   `Tensor::matmul` (same ascending-k, zero-skip accumulation) under
//!   every SIMD kernel, blocked over output-column tiles so the C row
//!   and streamed B rows stay in cache.
//! * [`matmul_packed`] — fused dequant-in-inner-loop GEMM over
//!   [`RepackedWeight`]: nibble-interleaved int≤4 codes decode inside the
//!   k-loop (8 lanes per step on AVX2/NEON), group scales multiply once
//!   per (element, group) — the weight never materializes as f32.
//! * [`givens_rotate_rows`] — O(k)-per-row fused [`GivensChain`]
//!   application (k = chain length), the chain-form alternative to a dense
//!   rotation matmul for URT-style site rotations.
//!
//! Work is partitioned over output rows when the activation batch is tall
//! (prefill) and over output columns when it is short (single-token
//! decode), so both serving phases scale with cores. The inner-loop
//! implementation (scalar vs AVX2/NEON) comes from
//! [`crate::tensor::simd::active`]; the `_with` variants pin a kernel
//! explicitly so tests and benches can compare both in one process.

use std::sync::OnceLock;

use crate::quant::repack::RepackedWeight;
use crate::rotation::givens::GivensChain;
use crate::tensor::pool::{self, SendPtr};
use crate::tensor::simd::{self, Kernel};
use crate::tensor::Tensor;

/// Resolve a requested worker count: 0 means "all available cores",
/// probed once per process (the OS call is not free and this sits on
/// the per-matmul path).
pub fn resolve_threads(requested: usize) -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    if requested > 0 {
        requested
    } else {
        *CORES.get_or_init(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    }
}

/// Below this many multiply-adds a GEMM runs serially. The bar is set by
/// pool dispatch cost (~µs), not thread spawn — an order of magnitude
/// lower than the old spawn-per-call threshold, so small decode matmuls
/// parallelize too. Results are identical either way — the serial path
/// is the same kernel.
const PAR_THRESHOLD_FLOPS: usize = 16 * 1024;

/// Run a tile computation over `m` output rows × `n` output cols split
/// into up to `threads` chunks on the worker pool: row-partitioned when
/// the batch is tall, column-partitioned (per-chunk tiles merged
/// afterwards) when it is short. `work` is the approximate multiply-add
/// count (m·n·k) — tiny problems run serially rather than paying
/// dispatch.
fn run_partitioned<F>(m: usize, n: usize, work: usize, threads: usize, tile: F) -> Vec<f32>
where
    F: Fn(usize, usize, usize, usize, &mut [f32]) + Sync,
{
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let threads = threads.clamp(1, m.max(n));
    if threads <= 1 || work < PAR_THRESHOLD_FLOPS {
        tile(0, m, 0, n, &mut out);
        return out;
    }
    if m >= threads {
        // tall batch: contiguous row ranges, written in place
        let chunk = m.div_ceil(threads);
        let n_chunks = m.div_ceil(chunk);
        let base = SendPtr::new(out.as_mut_ptr());
        pool::global().run(n_chunks, |ci| {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(m);
            // SAFETY: chunks cover disjoint row ranges of `out`, which
            // outlives the job (`run` blocks until every chunk is done).
            let dst =
                unsafe { std::slice::from_raw_parts_mut(base.get().add(lo * n), (hi - lo) * n) };
            tile(lo, hi, 0, n, dst);
        });
        return out;
    }
    // short batch (decode): column ranges into per-chunk tiles
    let chunk = n.div_ceil(threads).max(1);
    let n_chunks = n.div_ceil(chunk);
    let mut tiles: Vec<Vec<f32>> = (0..n_chunks)
        .map(|ci| {
            let c0 = ci * chunk;
            let c1 = (c0 + chunk).min(n);
            vec![0.0f32; m * (c1 - c0)]
        })
        .collect();
    let base = SendPtr::new(tiles.as_mut_ptr());
    pool::global().run(n_chunks, |ci| {
        let c0 = ci * chunk;
        let c1 = (c0 + chunk).min(n);
        // SAFETY: each chunk writes only its own pre-sized tile vector.
        let t: &mut Vec<f32> = unsafe { &mut *base.get().add(ci) };
        tile(0, m, c0, c1, t.as_mut_slice());
    });
    for (ci, t) in tiles.iter().enumerate() {
        let c0 = ci * chunk;
        let c1 = (c0 + chunk).min(n);
        let w = c1 - c0;
        for i in 0..m {
            out[i * n + c0..i * n + c1].copy_from_slice(&t[i * w..(i + 1) * w]);
        }
    }
    out
}

/// C = A·B with `threads` workers (0 = all cores) under the
/// process-selected kernel. Element-identical to `Tensor::matmul` at any
/// thread count and under any kernel.
pub fn matmul_threaded(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    matmul_threaded_with(simd::active(), a, b, threads)
}

/// [`matmul_threaded`] with the kernel pinned explicitly (tests/benches
/// comparing scalar vs SIMD in one process).
pub fn matmul_threaded_with(kernel: Kernel, a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_threaded {:?} @ {:?}", a.shape(), b.shape());
    let threads = resolve_threads(threads);
    let out = run_partitioned(m, n, m * n * k, threads, |i0, i1, j0, j1, dst| {
        simd::f32_tile(kernel, a, b, i0, i1, j0, j1, dst);
    });
    Tensor::from_raw(vec![m, n], out)
}

/// C = A·dequant(W) with dequantization fused into the inner loop —
/// the packed weight is never materialized as f32.
pub fn matmul_packed(a: &Tensor, w: &RepackedWeight, threads: usize) -> Tensor {
    matmul_packed_with(simd::active(), a, w, threads)
}

/// [`matmul_packed`] with the kernel pinned explicitly.
pub fn matmul_packed_with(
    kernel: Kernel,
    a: &Tensor,
    w: &RepackedWeight,
    threads: usize,
) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(k, w.rows, "matmul_packed {:?} @ [{}, {}]", a.shape(), w.rows, w.cols);
    let threads = resolve_threads(threads);
    let n = w.cols;
    let out = run_partitioned(m, n, m * n * k, threads, |i0, i1, c0, c1, dst| {
        simd::packed_tile(kernel, a, w, i0, i1, c0, c1, dst);
    });
    Tensor::from_raw(vec![m, n], out)
}

/// Apply a Givens chain to every row of `x` in place: O(len(chain)) per
/// row instead of the O(n²) dense-rotation matmul.
pub fn givens_rotate_rows(x: &mut Tensor, chain: &GivensChain, threads: usize) {
    givens_rows_dispatch(x, chain, threads, |ch, row| ch.apply_row(row));
}

/// Inverse-chain companion to [`givens_rotate_rows`]: applies
/// `chain.apply_row_inverse` to every row, i.e. multiplies each row by
/// the transpose of the chain's rotation. Same partitioning, same
/// bit-identical-across-thread-counts contract (rows are independent).
pub fn givens_rotate_rows_inv(x: &mut Tensor, chain: &GivensChain, threads: usize) {
    givens_rows_dispatch(x, chain, threads, |ch, row| ch.apply_row_inverse(row));
}

/// Shared row-partitioned dispatcher for the two chain directions. Each
/// row's result depends only on that row and the chain, so the chunk
/// boundaries chosen here can never change the numbers.
fn givens_rows_dispatch(
    x: &mut Tensor,
    chain: &GivensChain,
    threads: usize,
    apply: impl Fn(&GivensChain, &mut [f32]) + Sync,
) {
    let t = x.rows();
    let n = x.cols();
    if t == 0 || n == 0 {
        return;
    }
    let threads = resolve_threads(threads).min(t);
    // ~6 flops per rotation; below the parallel threshold dispatch wins
    if threads <= 1 || t * chain.len() * 6 < PAR_THRESHOLD_FLOPS {
        for i in 0..t {
            apply(chain, x.row_mut(i));
        }
        return;
    }
    let chunk = t.div_ceil(threads);
    let n_chunks = t.div_ceil(chunk);
    let data = x.data_mut();
    let base = SendPtr::new(data.as_mut_ptr());
    pool::global().run(n_chunks, |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(t);
        // SAFETY: chunks own disjoint row ranges of `data`, which
        // outlives the job.
        let rows =
            unsafe { std::slice::from_raw_parts_mut(base.get().add(lo * n), (hi - lo) * n) };
        for row in rows.chunks_mut(n) {
            apply(chain, row);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rotation::givens::map_to_e1;
    use crate::util::rng::Rng;

    #[test]
    fn threaded_matmul_matches_serial_exactly() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1usize, 7usize, 13usize), (5, 9, 4), (17, 33, 29), (8, 64, 96)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let reference = a.matmul(&b);
            for threads in [1usize, 2, 4, 7] {
                let got = matmul_threaded(&a, &b, threads);
                assert_eq!(got.data(), reference.data(),
                           "m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn threaded_matmul_is_bit_identical_under_every_kernel() {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(&[6, 48], 1.0, &mut rng);
        let b = Tensor::randn(&[48, 70], 1.0, &mut rng);
        let reference = a.matmul(&b);
        for kernel in [Kernel::Scalar, simd::best()] {
            for threads in [1usize, 3, 8] {
                let got = matmul_threaded_with(kernel, &a, &b, threads);
                assert_eq!(got.data(), reference.data(),
                           "kernel={} threads={threads}", kernel.label());
            }
        }
    }

    #[test]
    fn packed_matmul_matches_dequantized_reference() {
        let mut rng = Rng::new(2);
        for bits in [2u32, 3, 4, 5, 8] {
            let w = Tensor::randn(&[37, 11], 0.7, &mut rng);
            let x = Tensor::randn(&[5, 37], 1.0, &mut rng);
            for group in [8usize, 37] {
                let rw = RepackedWeight::pack(&w, bits, group).unwrap();
                let reference = x.matmul(&rw.dequantize());
                for kernel in [Kernel::Scalar, simd::best()] {
                    let got = matmul_packed_with(kernel, &x, &rw, 3);
                    for (a, b) in got.data().iter().zip(reference.data()) {
                        assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                                "bits={bits} group={group} kernel={}: {a} vs {b}",
                                kernel.label());
                    }
                }
            }
        }
    }

    #[test]
    fn packed_matmul_is_thread_count_invariant() {
        // 3*48*128 multiply-adds clears PAR_THRESHOLD_FLOPS, so thread
        // counts > 1 genuinely hit the column-partitioned pool path
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[128, 48], 0.5, &mut rng);
        let x = Tensor::randn(&[3, 128], 1.0, &mut rng);
        let rw = RepackedWeight::pack(&w, 4, 16).unwrap();
        for kernel in [Kernel::Scalar, simd::best()] {
            let one = matmul_packed_with(kernel, &x, &rw, 1);
            for threads in [2usize, 4, 8] {
                assert_eq!(matmul_packed_with(kernel, &x, &rw, threads).data(), one.data(),
                           "kernel={} threads={threads}", kernel.label());
            }
        }
    }

    #[test]
    fn givens_rows_match_dense_rotation() {
        let mut rng = Rng::new(4);
        let chain = map_to_e1(&rng.normal_vec(16, 1.0));
        let x = Tensor::randn(&[9, 16], 1.0, &mut rng);
        let dense = x.matmul(&chain.to_matrix(16));
        for threads in [1usize, 3] {
            let mut got = x.clone();
            givens_rotate_rows(&mut got, &chain, threads);
            assert!(got.sub(&dense).max_abs() < 1e-4);
        }
    }

    #[test]
    fn givens_parallel_path_matches_serial() {
        // big enough to clear PAR_THRESHOLD_FLOPS and hit the pool
        let mut rng = Rng::new(5);
        let chain = map_to_e1(&rng.normal_vec(64, 1.0));
        let x = Tensor::randn(&[128, 64], 1.0, &mut rng);
        let mut serial = x.clone();
        givens_rotate_rows(&mut serial, &chain, 1);
        let mut par = x.clone();
        givens_rotate_rows(&mut par, &chain, 8);
        assert_eq!(par.data(), serial.data());
    }

    #[test]
    fn givens_inverse_rows_match_dense_transpose() {
        let mut rng = Rng::new(6);
        let chain = map_to_e1(&rng.normal_vec(16, 1.0));
        let x = Tensor::randn(&[9, 16], 1.0, &mut rng);
        let dense = x.matmul(&chain.to_matrix(16).transpose());
        for threads in [1usize, 3] {
            let mut got = x.clone();
            givens_rotate_rows_inv(&mut got, &chain, threads);
            assert!(got.sub(&dense).max_abs() < 1e-4);
        }
    }

    #[test]
    fn givens_inverse_undoes_forward_bit_for_bit_across_threads() {
        // forward then inverse is the identity up to fp rounding, and the
        // parallel path must agree with serial exactly
        let mut rng = Rng::new(8);
        let chain = map_to_e1(&rng.normal_vec(64, 1.0));
        let x = Tensor::randn(&[128, 64], 1.0, &mut rng);
        let mut serial = x.clone();
        givens_rotate_rows(&mut serial, &chain, 1);
        givens_rotate_rows_inv(&mut serial, &chain, 1);
        assert!(serial.sub(&x).max_abs() < 1e-4);
        for threads in [2usize, 8] {
            let mut par = x.clone();
            givens_rotate_rows(&mut par, &chain, threads);
            givens_rotate_rows_inv(&mut par, &chain, threads);
            assert_eq!(par.data(), serial.data(), "threads={threads}");
        }
    }
}
