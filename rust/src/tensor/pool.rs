//! Persistent worker pool for the CPU kernels.
//!
//! The old kernels paid `std::thread::scope` spawn/join on every matmul,
//! which priced small decode matmuls out of parallelism entirely. This
//! pool spawns its workers once (process lifetime), parks them on a
//! condvar, and broadcasts each call as a job of `chunks` independent
//! work items that workers claim with an atomic cursor. The caller
//! participates as a worker too, then blocks until the last chunk
//! completes — so [`WorkerPool::run`] has exactly the structured
//! semantics of a scoped spawn (borrowed closures are safe) at a few
//! microseconds of dispatch cost.
//!
//! Determinism: chunk→worker assignment is racy, but every chunk is a
//! self-contained computation writing its own output region, so which
//! worker runs it never changes the numbers. Nested `run` calls (a
//! matmul issued from inside a decode-wave chunk) execute their chunks
//! inline on the calling worker rather than re-entering the dispatcher,
//! which keeps the pool deadlock-free without a job queue.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::kernels::resolve_threads;

/// Raw-pointer wrapper that is `Send + Sync`, for handing chunks write
/// access to disjoint regions of one caller-owned buffer. The caller
/// must guarantee chunk regions never overlap and the buffer outlives
/// the `run` call (it does: `run` blocks until every chunk finishes).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub fn get(self) -> *mut T {
        self.0
    }
}

// SAFETY: a SendPtr is only a capability to form disjoint &mut regions
// inside pool chunks; the caller upholds disjointness (see struct docs).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// One broadcast job. Lives on the heap (`Arc`) so late worker accesses
/// to the claim/completion counters stay valid even after the posting
/// caller has returned — the caller's stack data behind `data` is only
/// dereferenced while executing a claimed chunk, and all chunks are
/// provably finished once `done == chunks`.
struct Job {
    run: unsafe fn(*const (), usize),
    data: *const (),
    chunks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panicked: AtomicBool,
    /// `--features audit`: exactly-once chunk-claim bitmap. The atomic
    /// claim cursor makes double-claims impossible by construction;
    /// this witnesses that construction against future refactors.
    #[cfg(feature = "audit")]
    claimed: Vec<AtomicBool>,
}

// SAFETY: `data` points at a `Sync` closure that outlives every chunk
// execution (the posting thread blocks in `run` until `done == chunks`),
// and the counters are atomics.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Monomorphized trampoline erasing the closure type behind a fn
/// pointer, so `Job` needs no generics or allocation per closure.
///
/// SAFETY: `data` must point at a live `F` for the whole call — upheld
/// because the posting caller blocks in `run` until `done == chunks`.
unsafe fn shim<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    // SAFETY: `data` was erased from `&F` by `run`, which keeps the
    // closure alive on its stack until every chunk has finished.
    unsafe { (*(data as *const F))(i) }
}

#[derive(Default)]
struct Post {
    job: Option<Arc<Job>>,
    /// Bumped per posted job; workers remember the last epoch they saw
    /// so each job is picked up exactly once per worker.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    post: Mutex<Post>,
    /// Wakes parked workers when a job is posted (or shutdown).
    work: Condvar,
    /// Wakes the posting caller when the last chunk finishes.
    done: Condvar,
    /// Chunks of the in-flight job not yet finished (metrics gauge;
    /// racy across concurrent posters, which a gauge tolerates).
    depth: AtomicUsize,
    /// Lifetime jobs dispatched.
    jobs: AtomicUsize,
}

thread_local! {
    /// True while this thread is executing pool chunks. Nested `run`
    /// calls run inline instead of re-entering the dispatcher.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Worker threads + the participating caller.
    lanes: usize,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `lanes` compute lanes total: `lanes - 1` parked
    /// worker threads plus the caller, which always participates.
    pub fn new(lanes: usize) -> WorkerPool {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            post: Mutex::new(Post::default()),
            work: Condvar::new(),
            done: Condvar::new(),
            depth: AtomicUsize::new(0),
            jobs: AtomicUsize::new(0),
        });
        let handles = (1..lanes)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sq-pool-{i}"))
                    .spawn(move || worker(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, lanes, handles }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Unfinished chunks of the in-flight job (0 when idle).
    pub fn queue_depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Jobs dispatched over the pool's lifetime.
    pub fn jobs_dispatched(&self) -> usize {
        self.shared.jobs.load(Ordering::Relaxed)
    }

    /// Run `f(0), f(1), …, f(chunks - 1)` across the pool and block
    /// until all complete. Panics if any chunk panicked (workers
    /// survive). Single-chunk jobs, nested calls, and worker-less pools
    /// execute inline — same results either way, since chunk dispatch
    /// never affects what a chunk computes.
    pub fn run<F: Fn(usize) + Sync>(&self, chunks: usize, f: F) {
        if chunks == 0 {
            return;
        }
        // note: the single-chunk inline path does NOT mark IN_POOL, so a
        // one-slot decode wave still lets its inner matmuls parallelize
        if chunks == 1 || self.handles.is_empty() || IN_POOL.with(|c| c.get()) {
            for i in 0..chunks {
                f(i);
            }
            return;
        }
        let job = Arc::new(Job {
            run: shim::<F>,
            data: &f as *const F as *const (),
            chunks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            #[cfg(feature = "audit")]
            claimed: (0..chunks).map(|_| AtomicBool::new(false)).collect(),
        });
        self.shared.depth.store(chunks, Ordering::Relaxed);
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
        {
            let mut post = self.shared.post.lock().unwrap();
            post.epoch += 1;
            post.job = Some(Arc::clone(&job));
            self.shared.work.notify_all();
        }
        // the caller is the nth lane; chunks it claims run here
        IN_POOL.with(|c| c.set(true));
        work_chunks(&job, &self.shared);
        IN_POOL.with(|c| c.set(false));
        let mut post = self.shared.post.lock().unwrap();
        while job.done.load(Ordering::SeqCst) < job.chunks {
            post = self.shared.done.wait(post).unwrap();
        }
        // drop the broadcast slot's Arc; in-flight workers own clones
        if post.job.as_ref().is_some_and(|j| Arc::ptr_eq(j, &job)) {
            post.job = None;
        }
        drop(post);
        #[cfg(feature = "audit")]
        {
            assert_eq!(job.done.load(Ordering::SeqCst), job.chunks, "audit: done over-counted");
            for (i, c) in job.claimed.iter().enumerate() {
                assert!(c.load(Ordering::SeqCst), "audit: chunk {i} completed but never claimed");
            }
        }
        if job.panicked.load(Ordering::SeqCst) {
            panic!("worker pool job panicked");
        }
    }

    /// Like [`WorkerPool::run`], but each chunk produces a value and the
    /// results come back in chunk-index order regardless of which worker
    /// ran what. This is the fan-out/ordered-commit primitive the
    /// quantization pipeline builds its determinism contract on: chunk
    /// bodies are pure functions of their index, so the returned `Vec`
    /// is bit-identical for any lane count or claim interleaving.
    pub fn run_collect<T, F>(&self, chunks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut slots: Vec<Option<T>> = Vec::with_capacity(chunks);
        slots.resize_with(chunks, || None);
        let ptr = SendPtr::new(slots.as_mut_ptr());
        self.run(chunks, |i| {
            let v = f(i);
            // SAFETY: chunk i writes only slot i (disjoint per chunk) and
            // `slots` outlives the run call, which blocks until every
            // chunk finishes.
            unsafe { *ptr.get().add(i) = Some(v) };
        });
        slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| unreachable!("pool chunk left its result slot empty")))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut post = self.shared.post.lock().unwrap();
            post.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute chunks until the job is exhausted.
fn work_chunks(job: &Job, shared: &Shared) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.chunks {
            return;
        }
        #[cfg(feature = "audit")]
        assert!(!job.claimed[i].swap(true, Ordering::SeqCst), "audit: chunk {i} claimed twice");
        // SAFETY: `data` outlives every chunk execution (see Job docs).
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (job.run)(job.data, i) })).is_ok();
        if !ok {
            job.panicked.store(true, Ordering::SeqCst);
        }
        let _ = shared
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
        if job.done.fetch_add(1, Ordering::SeqCst) + 1 == job.chunks {
            // lock before notifying so the caller can't check-then-sleep
            // between our counter bump and this wakeup
            let _post = shared.post.lock().unwrap();
            shared.done.notify_all();
        }
    }
}

fn worker(shared: Arc<Shared>) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut post = shared.post.lock().unwrap();
            loop {
                if post.shutdown {
                    return;
                }
                if post.epoch != seen {
                    // epochs only ever increment under the post lock; a
                    // worker observing one go backwards means torn state
                    #[cfg(feature = "audit")]
                    assert!(post.epoch > seen, "audit: job epoch went backwards");
                    seen = post.epoch;
                    break post.job.clone();
                }
                post = shared.work.wait(post).unwrap();
            }
        };
        if let Some(job) = job {
            work_chunks(&job, &shared);
        }
    }
}

/// Deterministic virtual scheduler (tests and `audit` builds): execute
/// a job's chunks inline in a caller-chosen claim order, with the same
/// exactly-once accounting as the live dispatcher. Real chunk→worker
/// assignment is racy, but every interleaving the race can produce is
/// some permutation of chunk claims — so if every permutation yields
/// bit-identical output, the computation cannot depend on scheduling.
#[cfg(any(test, feature = "audit"))]
pub fn run_virtual<F: Fn(usize) + Sync>(order: &[usize], f: F) {
    let chunks = order.len();
    let mut claimed = vec![false; chunks];
    for &i in order {
        assert!(i < chunks, "virtual schedule claims out-of-range chunk {i}");
        assert!(!claimed[i], "virtual schedule claims chunk {i} twice");
        claimed[i] = true;
        f(i);
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, sized to the machine once on first use.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(resolve_threads(0)))
}

/// Queue depth of the global pool without forcing it into existence
/// (metrics can scrape before the first matmul).
pub fn global_queue_depth() -> usize {
    GLOBAL.get().map_or(0, |p| p.queue_depth())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(pool.queue_depth(), 0);
        assert_eq!(pool.jobs_dispatched(), 1);
    }

    #[test]
    fn sums_match_serial() {
        let pool = WorkerPool::new(3);
        let n = 1000usize;
        let total = AtomicUsize::new(0);
        pool.run(n, |i| {
            total.fetch_add(i + 1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), n * (n + 1) / 2);
    }

    #[test]
    fn nested_runs_execute_inline_without_deadlock() {
        let pool = WorkerPool::new(4);
        let inner_hits = AtomicUsize::new(0);
        pool.run(8, |_outer| {
            pool.run(5, |_inner| {
                inner_hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(inner_hits.load(Ordering::SeqCst), 8 * 5);
    }

    #[test]
    fn survives_a_panicking_job() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, |i| {
                if i == 2 {
                    panic!("chunk bombed");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // pool still functions afterwards
        let ok = AtomicUsize::new(0);
        pool.run(4, |_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn concurrent_callers_both_complete() {
        let pool = WorkerPool::new(4);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..20 {
                    pool.run(16, |_| {
                        a.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
            s.spawn(|| {
                for _ in 0..20 {
                    pool.run(16, |_| {
                        b.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        });
        assert_eq!(a.load(Ordering::SeqCst), 20 * 16);
        assert_eq!(b.load(Ordering::SeqCst), 20 * 16);
    }

    /// The claim-order invariance contract, checked exhaustively-ish:
    /// the same disjoint-region job run under several permuted virtual
    /// schedules and under the live racy pool must produce bit-identical
    /// buffers. A chunk body that secretly depended on claim order (a
    /// shared running accumulator, an order-sensitive write) fails here
    /// deterministically instead of flaking under the real scheduler.
    #[test]
    fn virtual_scheduler_permutations_match_live_pool() {
        use crate::util::rng::Rng;
        let chunks = 13usize;
        let per = 7usize;
        let fill = |buf: SendPtr<f32>, i: usize| {
            // SAFETY: chunk i writes only its own disjoint `per`-slice,
            // and the buffer outlives the run call.
            let dst = unsafe { std::slice::from_raw_parts_mut(buf.get().add(i * per), per) };
            for (k, d) in dst.iter_mut().enumerate() {
                *d = ((i * per + k) as f32).sin() * 0.5 + i as f32;
            }
        };
        let mut reference = vec![0.0f32; chunks * per];
        let ptr = SendPtr::new(reference.as_mut_ptr());
        let order: Vec<usize> = (0..chunks).collect();
        run_virtual(&order, |i| fill(ptr, i));
        let mut rng = Rng::new(42);
        for _ in 0..8 {
            let order = rng.permutation(chunks);
            let mut out = vec![0.0f32; chunks * per];
            let ptr = SendPtr::new(out.as_mut_ptr());
            run_virtual(&order, |i| fill(ptr, i));
            assert_eq!(out, reference, "claim order {order:?} changed the output");
        }
        let pool = WorkerPool::new(4);
        let mut live = vec![0.0f32; chunks * per];
        let ptr = SendPtr::new(live.as_mut_ptr());
        pool.run(chunks, |i| fill(ptr, i));
        assert_eq!(live, reference, "live pool diverged from the virtual schedule");
    }

    #[test]
    fn run_collect_returns_in_index_order() {
        let pool = WorkerPool::new(4);
        let out = pool.run_collect(23, |i| i * i);
        assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        // result order must be index order even when claim order is not
        let serial = WorkerPool::new(1).run_collect(23, |i| i * i);
        assert_eq!(out, serial);
    }

    #[test]
    fn run_collect_handles_results_and_empty_jobs() {
        let pool = WorkerPool::new(3);
        let out: Vec<Result<usize, String>> =
            pool.run_collect(5, |i| if i == 3 { Err(format!("chunk {i}")) } else { Ok(i) });
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 1);
        let none: Vec<usize> = pool.run_collect(0, |i| i);
        assert!(none.is_empty());
    }

    #[test]
    fn global_depth_is_zero_when_idle() {
        assert_eq!(global_queue_depth(), 0);
        global().run(4, |_| {});
        assert_eq!(global_queue_depth(), 0);
    }
}
