//! Matrix decompositions: Cholesky (GPTQ's Hessian machinery), triangular
//! inversion, and Gram–Schmidt QR (random orthogonal matrices).

use anyhow::{bail, Result};

use super::Tensor;
use crate::util::rng::Rng;

/// Lower-triangular Cholesky factor L of a symmetric positive-definite A
/// (A = L Lᵀ). Fails if a pivot collapses (matrix not PD).
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: non-PD pivot {sum} at {i}");
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.at(j, j));
            }
        }
    }
    Ok(l)
}

/// Inverse of a lower-triangular matrix (forward substitution per column).
pub fn invert_lower(l: &Tensor) -> Tensor {
    let n = l.rows();
    let mut inv = Tensor::zeros(&[n, n]);
    for col in 0..n {
        // Solve L x = e_col.
        let mut x = vec![0.0f32; n];
        for i in col..n {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in col..i {
                sum -= l.at(i, k) * x[k];
            }
            x[i] = sum / l.at(i, i);
        }
        for i in 0..n {
            inv.set(i, col, x[i]);
        }
    }
    inv
}

/// Symmetric-positive-definite inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor> {
    let l = cholesky(a)?;
    let li = invert_lower(&l);
    Ok(li.matmul_tn(&li)) // Liᵀ @ Li
}

/// Upper Cholesky factor U of A (A = Uᵀ U): the form GPTQ uses for the
/// inverse Hessian. U = (lower-cholesky(A))ᵀ.
pub fn cholesky_upper(a: &Tensor) -> Result<Tensor> {
    Ok(cholesky(a)?.transpose())
}

/// General matrix inverse by Gauss–Jordan elimination with partial
/// pivoting (needed for the Cayley transform's (I − α/2 Ω)⁻¹).
pub fn inverse(a: &Tensor) -> Result<Tensor> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut m = a.clone();
    let mut inv = Tensor::eye(n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if m.at(r, col).abs() > m.at(piv, col).abs() {
                piv = r;
            }
        }
        if m.at(piv, col).abs() < 1e-12 {
            bail!("inverse: singular at column {col}");
        }
        if piv != col {
            for j in 0..n {
                let (a1, a2) = (m.at(col, j), m.at(piv, j));
                m.set(col, j, a2);
                m.set(piv, j, a1);
                let (b1, b2) = (inv.at(col, j), inv.at(piv, j));
                inv.set(col, j, b2);
                inv.set(piv, j, b1);
            }
        }
        let d = m.at(col, col);
        for j in 0..n {
            m.set(col, j, m.at(col, j) / d);
            inv.set(col, j, inv.at(col, j) / d);
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m.at(r, col);
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                let v = m.at(r, j) - f * m.at(col, j);
                m.set(r, j, v);
                let w = inv.at(r, j) - f * inv.at(col, j);
                inv.set(r, j, w);
            }
        }
    }
    Ok(inv)
}

/// QR by modified Gram–Schmidt; returns Q ([m, n] with orthonormal columns).
pub fn gram_schmidt_q(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut q = a.clone();
    for j in 0..n {
        // subtract projections onto previous columns
        for k in 0..j {
            let mut dot = 0.0f32;
            for i in 0..m {
                dot += q.at(i, k) * q.at(i, j);
            }
            for i in 0..m {
                let v = q.at(i, j) - dot * q.at(i, k);
                q.set(i, j, v);
            }
        }
        let mut norm = 0.0f32;
        for i in 0..m {
            norm += q.at(i, j) * q.at(i, j);
        }
        let norm = norm.sqrt().max(1e-12);
        for i in 0..m {
            let v = q.at(i, j) / norm;
            q.set(i, j, v);
        }
    }
    q
}

/// Haar-ish random orthogonal matrix: QR of a Gaussian (re-orthogonalized
/// once for numerical hygiene at f32).
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Tensor {
    let g = Tensor::randn(&[n, n], 1.0, rng);
    let q = gram_schmidt_q(&g);
    gram_schmidt_q(&q)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let a = Tensor::randn(&[n, n], 1.0, &mut rng);
        let mut h = a.matmul_tn(&a); // AᵀA is PSD
        for i in 0..n {
            let v = h.at(i, i) + 0.5;
            h.set(i, i, v);
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul_nt(&l);
        assert!(a.sub(&rec).max_abs() < 1e-3, "{}", a.sub(&rec).max_abs());
    }

    #[test]
    fn spd_inverse_works() {
        let a = spd(6, 2);
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Tensor::eye(6)).max_abs() < 1e-3);
    }

    #[test]
    fn invert_lower_correct() {
        let a = spd(5, 3);
        let l = cholesky(&a).unwrap();
        let li = invert_lower(&l);
        assert!(l.matmul(&li).sub(&Tensor::eye(5)).max_abs() < 1e-4);
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(4);
        for n in [2, 5, 16, 33] {
            let q = random_orthogonal(n, &mut rng);
            assert!(q.orthogonality_defect() < 1e-4,
                    "defect {} at n={n}", q.orthogonality_defect());
        }
    }

    #[test]
    fn general_inverse() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[7, 7], 1.0, &mut rng);
        let inv = inverse(&a).unwrap();
        assert!(a.matmul(&inv).sub(&Tensor::eye(7)).max_abs() < 1e-3);
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let a = Tensor::from_raw(vec![2, 2], vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(cholesky(&a).is_err());
    }
}
