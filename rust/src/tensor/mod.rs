//! Dense f32 tensors + the linear algebra the quantization pipeline needs.
//!
//! Row-major, shape-checked, deliberately simple: models in this repo are
//! ≤ a few million parameters. This module serves the *pipeline*
//! (calibration, rotation construction, GPTQ) and the Rust reference
//! forward; the threaded serving kernels live in [`kernels`].

pub mod decomp;
pub mod kernels;
pub mod pool;
pub mod simd;
pub mod stats;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // -- construction ---------------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_raw(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs {} elems", data.len());
        Tensor { shape, data }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Tensor {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Tensor { shape: vec![r, c], data }
    }

    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, sigma) }
    }

    // -- accessors ------------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-matrix {:?}", self.shape);
        self.shape[1]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows()).map(|i| self.at(i, j)).collect()
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // -- elementwise ------------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn mul_elem(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    // -- norms ------------------------------------------------------------------

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mse(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1) as f32;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n
    }

    // -- matmul family ------------------------------------------------------------

    /// C = A @ B for 2-D tensors (ikj loop order; B rows stream through cache).
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul {:?} @ {:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(kk);
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// C = A^T @ B (A: [k, m], B: [k, n]).
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_tn {:?} @ {:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        for kk in 0..k {
            let arow = self.row(kk);
            let brow = b.row(kk);
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// C = A @ B^T (A: [m, k], B: [n, k]).
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_nt {:?} @ {:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                out[i * n + j] = acc;
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// y = x @ A for a single row vector x (len = rows of A).
    pub fn vecmat(&self, x: &[f32]) -> Vec<f32> {
        let (k, n) = (self.rows(), self.cols());
        assert_eq!(x.len(), k);
        let mut out = vec![0.0f32; n];
        for (kk, &a) in x.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let row = self.row(kk);
            for j in 0..n {
                out[j] += a * row[j];
            }
        }
        out
    }

    /// Orthogonality defect ‖AᵀA − I‖∞ (0 for exact rotations).
    pub fn orthogonality_defect(&self) -> f32 {
        let g = self.matmul_tn(self);
        let n = g.rows();
        let mut worst = 0.0f32;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((g.at(i, j) - target).abs());
            }
        }
        worst
    }

    /// Horizontal concatenation of 2-D tensors with equal row counts.
    pub fn hcat(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("hcat of nothing");
        }
        let m = parts[0].rows();
        let total: usize = parts.iter().map(|p| p.cols()).sum();
        let mut out = Tensor::zeros(&[m, total]);
        for i in 0..m {
            let mut off = 0;
            for p in parts {
                if p.rows() != m {
                    bail!("hcat row mismatch");
                }
                out.row_mut(i)[off..off + p.cols()].copy_from_slice(p.row(i));
                off += p.cols();
            }
        }
        Ok(out)
    }

    /// Rows `lo..hi` as a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        Tensor {
            shape: vec![hi - lo, c],
            data: self.data[lo * c..hi * c].to_vec(),
        }
    }

    /// Columns `lo..hi` as a new tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        let m = self.rows();
        let mut out = Tensor::zeros(&[m, hi - lo]);
        for i in 0..m {
            out.row_mut(i).copy_from_slice(&self.row(i)[lo..hi]);
        }
        out
    }

    /// Kronecker product A ⊗ B (used only in tests/analysis; the hot path
    /// uses the two-sided small-GEMM form).
    pub fn kron(&self, b: &Tensor) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let (p, q) = (b.rows(), b.cols());
        let mut out = Tensor::zeros(&[m * p, n * q]);
        for i in 0..m {
            for j in 0..n {
                let a = self.at(i, j);
                if a == 0.0 {
                    continue;
                }
                for r in 0..p {
                    for s in 0..q {
                        out.set(i * p + r, j * q + s, a * b.at(r, s));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_raw(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_raw(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.transpose().matmul_tn(&b);
        let c3 = a.matmul_nt(&b.transpose());
        for i in 0..c1.len() {
            assert!((c1.data()[i] - c2.data()[i]).abs() < 1e-4);
            assert!((c1.data()[i] - c3.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn eye_is_orthogonal() {
        assert!(Tensor::eye(9).orthogonality_defect() < 1e-7);
    }

    #[test]
    fn kron_shape_and_identity() {
        let i2 = Tensor::eye(2);
        let i3 = Tensor::eye(3);
        let k = i2.kron(&i3);
        assert_eq!(k.shape(), &[6, 6]);
        assert!(k.sub(&Tensor::eye(6)).max_abs() < 1e-7);
    }

    #[test]
    fn hcat_and_slices() {
        let a = Tensor::from_raw(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_raw(vec![2, 1], vec![5., 6.]);
        let c = Tensor::hcat(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.row(1), &[3., 4., 6.]);
        assert_eq!(c.slice_cols(2, 3).data(), &[5., 6.]);
        assert_eq!(c.slice_rows(1, 2).data(), &[3., 4., 6.]);
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let x: Vec<f32> = rng.normal_vec(6, 1.0);
        let y1 = a.vecmat(&x);
        let xm = Tensor::from_raw(vec![1, 6], x);
        let y2 = xm.matmul(&a);
        for i in 0..4 {
            assert!((y1[i] - y2.data()[i]).abs() < 1e-4);
        }
    }
}
