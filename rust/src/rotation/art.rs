//! ART — Alignment Rotation Transformation (§4.2, Eq. 38).
//!
//! Targets sparse **massive outliers**: locate the channel holding the
//! largest-magnitude activation and the channel holding the smallest, apply
//! the closed-form Lemma-1 Givens rotation in that 2-D plane (balancing the
//! pair's energy at r/√2 each), and embed the rotation in an n×n orthogonal
//! matrix whose complement block is a seeded random orthogonal matrix `O`
//! (Eq. 38's metric-preserving high-dimensional subspace).
//!
//! `steps` > 1 repeats the detect-and-rotate cycle on the updated profile —
//! the Fig. 4 sweep shows a single step already saturates, which is the
//! paper's single-pass headline; multi-step stays available for that
//! ablation.

use crate::rotation::givens::lemma1_givens;
use crate::tensor::{decomp, stats, Tensor};
use crate::util::rng::Rng;

/// ART construction report (profiles before/after, for analyses like Fig 1b).
pub struct ArtResult {
    pub rotation: Tensor,
    pub profile_before: Vec<f32>,
    pub profile_after: Vec<f32>,
}

/// Build the n×n ART rotation for a signed channel profile `v`
/// (per-channel signed absmax from calibration).
///
/// Each step: i = argmax|v|, j = argmin|v|; G = Lemma-1 rotation in the
/// (i, j) plane; complement dims get a random orthogonal block. The profile
/// is pushed through the step rotation before the next detection.
pub fn art_rotation(v: &[f32], steps: usize, rng: &mut Rng) -> ArtResult {
    let n = v.len();
    assert!(n >= 2, "ART needs at least 2 dims");
    let mut profile = v.to_vec();
    let before = profile.clone();
    let mut total = Tensor::eye(n);
    for _ in 0..steps.max(1) {
        let i = stats::argmax_abs(&profile);
        let mut j = stats::argmin_abs(&profile);
        if i == j {
            j = (i + 1) % n;
        }
        let g = lemma1_givens(&profile, i, j);
        let step = embed_with_complement(n, i, j, &g.to_matrix(n), rng);
        // advance profile and accumulate
        let prof_row = Tensor::from_raw(vec![1, n], profile.clone());
        profile = prof_row.matmul(&step).into_data();
        total = total.matmul(&step);
    }
    ArtResult { rotation: total, profile_before: before, profile_after: profile }
}

/// Embed the 2-D Givens action on dims (i, j) into an n×n orthogonal matrix
/// whose complement block is a random orthogonal `O` (Eq. 38). The Givens
/// part of `g_full` already lives on (i, j); we overwrite the complement.
fn embed_with_complement(n: usize, i: usize, j: usize, g_full: &Tensor,
                         rng: &mut Rng) -> Tensor {
    if n == 2 {
        return g_full.clone();
    }
    let rest: Vec<usize> = (0..n).filter(|&k| k != i && k != j).collect();
    let o = decomp::random_orthogonal(rest.len(), rng);
    let mut out = Tensor::zeros(&[n, n]);
    // Givens block on (i, j)
    for &a in &[i, j] {
        for &b in &[i, j] {
            out.set(a, b, g_full.at(a, b));
        }
    }
    // random orthogonal on the complement
    for (ri, &a) in rest.iter().enumerate() {
        for (rj, &b) in rest.iter().enumerate() {
            out.set(a, b, o.at(ri, rj));
        }
    }
    out
}

/// ART variant without the random complement (identity on other dims) —
/// used by the ablations to isolate the Givens contribution.
pub fn art_rotation_pure(v: &[f32], steps: usize) -> ArtResult {
    let n = v.len();
    let mut profile = v.to_vec();
    let before = profile.clone();
    let mut total = Tensor::eye(n);
    for _ in 0..steps.max(1) {
        let i = stats::argmax_abs(&profile);
        let mut j = stats::argmin_abs(&profile);
        if i == j {
            j = (i + 1) % n;
        }
        let g = lemma1_givens(&profile, i, j);
        g.apply_row(&mut profile);
        total = total.matmul(&g.to_matrix(n));
    }
    ArtResult { rotation: total, profile_before: before, profile_after: profile }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spiked_profile(n: usize, spike: f32) -> Vec<f32> {
        let mut v = vec![1.0f32; n];
        v[n / 3] = spike;
        v[n - 1] = 0.05;
        v
    }

    #[test]
    fn art_is_orthogonal() {
        let mut rng = Rng::new(1);
        let v = spiked_profile(16, 40.0);
        let res = art_rotation(&v, 1, &mut rng);
        assert!(res.rotation.orthogonality_defect() < 1e-3,
                "defect {}", res.rotation.orthogonality_defect());
    }

    #[test]
    fn art_reduces_max_abs() {
        let mut rng = Rng::new(2);
        let v = spiked_profile(12, 30.0);
        let res = art_rotation(&v, 1, &mut rng);
        let before = res.profile_before.iter().fold(0f32, |m, x| m.max(x.abs()));
        let after = res.profile_after.iter().fold(0f32, |m, x| m.max(x.abs()));
        assert!(after < before, "{after} !< {before}");
        // Lemma 1: the rotated pair lands near r/√2
        let r = (30.0f32 * 30.0 + 0.05 * 0.05).sqrt();
        assert!(after <= before.max(r) && after < 30.0);
    }

    #[test]
    fn pure_art_balances_exactly() {
        let v = spiked_profile(8, 20.0);
        let res = art_rotation_pure(&v, 1);
        let r = (20.0f32 * 20.0 + 0.05 * 0.05).sqrt();
        let target = r / 2f32.sqrt();
        // the two rotated coordinates both carry r/√2
        let mut sorted: Vec<f32> = res.profile_after.iter().map(|x| x.abs()).collect();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((sorted[0] - target).abs() < 1e-3, "{sorted:?} vs {target}");
        assert!((sorted[1] - target).abs() < 1e-3);
    }

    #[test]
    fn multi_step_never_worse_than_one() {
        let mut v = vec![1.0f32; 16];
        v[2] = 25.0;
        v[9] = 18.0;
        let r1 = art_rotation_pure(&v, 1);
        let r4 = art_rotation_pure(&v, 4);
        let m1 = r1.profile_after.iter().fold(0f32, |m, x| m.max(x.abs()));
        let m4 = r4.profile_after.iter().fold(0f32, |m, x| m.max(x.abs()));
        assert!(m4 <= m1 + 1e-4);
    }

    #[test]
    fn deterministic_given_seed() {
        let v = spiked_profile(10, 15.0);
        let a = art_rotation(&v, 2, &mut Rng::new(7)).rotation;
        let b = art_rotation(&v, 2, &mut Rng::new(7)).rotation;
        assert!(a.sub(&b).max_abs() < 1e-9);
    }
}
