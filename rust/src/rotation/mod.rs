//! Rotation constructions: the paper's contribution and all its baselines.
//!
//! * [`givens`]      — Givens rotations, chains, the closed-form Lemma-1
//!   angle, and the n−1-rotation map of a vector onto ‖v‖e₁.
//! * [`hadamard`]    — Sylvester–Hadamard matrices + in-place FWHT.
//! * [`kronecker`]   — Algorithm 1 (balanced power-of-two factorization)
//!   and the two-sided O(n^{3/2}) application form (Eq. 31).
//! * [`art`]         — Alignment Rotation Transformation (Eq. 38).
//! * [`urt`]         — Uniformity Rotation Transformation (Eq. 39–44).
//! * [`singlequant`] — the Eq. 45 composer producing per-site Kronecker
//!   factors from calibration profiles.
//! * [`cayley`]      — Cayley SGD + STE on O(n): the SpinQuant baseline and
//!   the §3.2 instability experiments (Fig. 2/B.1).
//! * [`baselines`]   — QuaRot, DuQuant-style greedy, FlatQuant-style
//!   learned Kronecker, SmoothQuant α-scaling, QuIP-style incoherence.

pub mod art;
pub mod baselines;
pub mod cayley;
pub mod givens;
pub mod hadamard;
pub mod kronecker;
pub mod singlequant;
pub mod urt;
