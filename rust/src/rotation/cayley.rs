//! Cayley SGD + STE on O(n): the SpinQuant baseline and the §3.2
//! instability experiments.
//!
//! Optimizes a rotation R minimizing the quantization-aware surrogate
//! (Eq. 8) `L(R) = ½ ‖ Q(XR) · Q(RᵀW) − XW ‖²`
//! with the straight-through estimator replacing the quantizer's derivative
//! by identity, the Euclidean gradient projected to the tangent space, and
//! the Cayley retraction (Eq. 16) keeping R orthogonal. The per-step loss /
//! gradient-norm traces back Fig. 2 and Fig. B.1 (oscillation under STE),
//! and the wall-clock cost backs Table 7's SpinQuant column.

use anyhow::Result;

use crate::quant::{fake_quant_per_channel, fake_quant_per_token};
use crate::tensor::{decomp, Tensor};

pub struct CayleyConfig {
    pub steps: usize,
    pub lr: f32,
    /// Linearly decay the LR to ~0 (SpinQuant's schedule in Fig. 2).
    pub decay: bool,
    pub act_bits: u32,
    pub weight_bits: u32,
}

impl Default for CayleyConfig {
    fn default() -> Self {
        CayleyConfig { steps: 100, lr: 0.05, decay: true, act_bits: 4, weight_bits: 4 }
    }
}

/// Per-step trace of the optimization (Fig. 2's two panels).
#[derive(Clone, Debug, Default)]
pub struct CayleyTrace {
    pub loss: Vec<f32>,
    pub grad_norm: Vec<f32>,
    pub step_norm: Vec<f32>,
}

pub struct CayleyResult {
    pub rotation: Tensor,
    pub trace: CayleyTrace,
}

/// STE loss + Euclidean gradient of Eq. 8 at R.
fn loss_and_grad(x: &Tensor, w: &Tensor, y_ref: &Tensor, r: &Tensor,
                 cfg: &CayleyConfig) -> (f32, Tensor) {
    let xr = x.matmul(r);
    let rw = r.transpose().matmul(w);
    let a = fake_quant_per_token(&xr, cfg.act_bits, 1.0);
    let bq = fake_quant_per_channel(&rw, cfg.weight_bits, 1.0);
    let y = a.matmul(&bq);
    let e = y.sub(y_ref);
    let loss = 0.5 * e.frob_norm().powi(2) / e.len() as f32;
    // STE: dL/d(XR) = E Bqᵀ ; contribution via P = XR: Xᵀ (E Bqᵀ)
    let g1 = x.matmul_tn(&e.matmul_nt(&bq));
    // STE: dL/d(RᵀW) = Aᵀ E ; contribution via S = RᵀW: W (AᵀE)ᵀ = W Eᵀ A
    let g2 = w.matmul(&a.matmul_tn(&e).transpose());
    let scale = 1.0 / e.len() as f32;
    (loss, g1.add(&g2).scale(scale))
}

/// Run Cayley SGD with STE from R = I.
pub fn cayley_sgd(x: &Tensor, w: &Tensor, cfg: &CayleyConfig) -> Result<CayleyResult> {
    let n = x.cols();
    assert_eq!(w.rows(), n);
    let y_ref = x.matmul(w);
    let mut r = Tensor::eye(n);
    let mut trace = CayleyTrace::default();
    let eye = Tensor::eye(n);
    for t in 0..cfg.steps {
        let lr = if cfg.decay {
            cfg.lr * (1.0 - t as f32 / cfg.steps as f32).max(0.02)
        } else {
            cfg.lr
        };
        let (loss, g) = loss_and_grad(x, w, &y_ref, &r, cfg);
        // Skew generator Ω = (G Rᵀ − R Gᵀ)/2 — the Riemannian direction.
        let grt = g.matmul_nt(&r);
        let omega = grt.sub(&grt.transpose()).scale(0.5);
        // Cayley retraction: R ← (I − α/2 Ω)⁻¹ (I + α/2 Ω) R   (Eq. 16)
        let a_minus = eye.sub(&omega.scale(lr * 0.5));
        let a_plus = eye.add(&omega.scale(lr * 0.5));
        let r_new = decomp::inverse(&a_minus)?.matmul(&a_plus).matmul(&r);
        trace.loss.push(loss);
        trace.grad_norm.push(omega.frob_norm());
        trace.step_norm.push(r_new.sub(&r).frob_norm());
        r = r_new;
    }
    Ok(CayleyResult { rotation: r, trace })
}

/// Oscillation score of a trace tail: mean |Δloss| over the last half
/// relative to the mean loss there. Converged smooth optimization → ~0;
/// the STE floor of Prop. 2 keeps it bounded away from 0.
pub fn oscillation_score(trace: &[f32]) -> f32 {
    if trace.len() < 4 {
        return 0.0;
    }
    let tail = &trace[trace.len() / 2..];
    let mean = tail.iter().sum::<f32>() / tail.len() as f32;
    let wiggle = tail
        .windows(2)
        .map(|w| (w[1] - w[0]).abs())
        .sum::<f32>()
        / (tail.len() - 1) as f32;
    wiggle / mean.abs().max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spiked_xw(n: usize, c: usize, t: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::randn(&[t, n], 1.0, &mut rng);
        for i in 0..t {
            x.row_mut(i)[1] *= 20.0; // massive-outlier channel
        }
        let w = Tensor::randn(&[n, c], 0.5, &mut rng);
        (x, w)
    }

    #[test]
    fn rotation_stays_orthogonal() {
        let (x, w) = spiked_xw(12, 8, 48, 1);
        let cfg = CayleyConfig { steps: 20, ..Default::default() };
        let res = cayley_sgd(&x, &w, &cfg).unwrap();
        assert!(res.rotation.orthogonality_defect() < 1e-2,
                "defect {}", res.rotation.orthogonality_defect());
    }

    #[test]
    fn loss_improves_over_identity() {
        let (x, w) = spiked_xw(12, 8, 48, 2);
        let cfg = CayleyConfig { steps: 40, lr: 1.0, ..Default::default() };
        let res = cayley_sgd(&x, &w, &cfg).unwrap();
        let first = res.trace.loss[0];
        let best = res.trace.loss.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(best < first * 0.9, "best {best} vs first {first}");
    }

    #[test]
    fn trace_lengths_match_steps() {
        let (x, w) = spiked_xw(8, 6, 32, 3);
        let cfg = CayleyConfig { steps: 15, ..Default::default() };
        let res = cayley_sgd(&x, &w, &cfg).unwrap();
        assert_eq!(res.trace.loss.len(), 15);
        assert_eq!(res.trace.grad_norm.len(), 15);
    }

    #[test]
    fn ste_gradient_never_vanishes() {
        // Prop. 2's empirical signature: the gradient norm tail stays
        // bounded away from zero even with decayed LR.
        let (x, w) = spiked_xw(12, 8, 64, 4);
        let cfg = CayleyConfig { steps: 60, ..Default::default() };
        let res = cayley_sgd(&x, &w, &cfg).unwrap();
        let tail = &res.trace.grad_norm[40..];
        let min_tail = tail.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(min_tail > 1e-6, "gradient collapsed to {min_tail}");
    }

    #[test]
    fn oscillation_score_behaviour() {
        let smooth: Vec<f32> = (0..50).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let mut rng = Rng::new(5);
        let noisy: Vec<f32> = (0..50).map(|_| 1.0 + 0.5 * rng.normal_f32()).collect();
        assert!(oscillation_score(&smooth) < 0.05);
        assert!(oscillation_score(&noisy) > 0.2);
    }
}
