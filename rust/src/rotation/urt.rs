//! URT — Uniformity Rotation Transformation (§4.2, Eq. 39–44).
//!
//! Targets dense **normal outliers**: build the norm-preserving,
//! rank-preserving uniform target U for the channel profile V (Eq. 41–42),
//! map both V and U onto ‖V‖e₁ with n−1 Givens rotations each (Ma et al.
//! 2024a), and compose Rᵁ = R_map · R'_mapᵀ so that V·Rᵁ = U exactly.
//! O(n) construction, O(n log n) total via the chain representation.

use crate::rotation::givens::{map_to_e1, GivensChain};
use crate::tensor::kernels::givens_rotate_rows;
use crate::tensor::{stats, Tensor};

pub struct UrtResult {
    /// Dense Rᵁ (n×n) — what the pipeline feeds the graphs.
    pub rotation: Tensor,
    /// The uniform target the profile is rotated onto.
    pub target: Vec<f32>,
    /// Chains, kept for O(n)-per-vector application in analyses.
    pub v_chain: GivensChain,
    pub u_chain: GivensChain,
}

/// The centered uniform template q_k = (2k − n − 1)/n, k = 1..n (Eq. 41).
pub fn uniform_template(n: usize) -> Vec<f32> {
    (1..=n)
        .map(|k| (2.0 * k as f32 - n as f32 - 1.0) / n as f32)
        .collect()
}

/// Norm-preserving, rank-preserving uniform target for profile `v` (Eq. 42).
pub fn uniform_target(v: &[f32]) -> Vec<f32> {
    let n = v.len();
    let q = uniform_template(n);
    let vnorm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    let qnorm = q.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
    let order = stats::argsort(v); // ascending ranks of V
    let mut u = vec![0.0f32; n];
    for (k, &idx) in order.iter().enumerate() {
        u[idx] = q[k] * vnorm / qnorm;
    }
    u
}

/// Build Rᵁ with V·Rᵁ = U.
///
/// V·R_map = ‖V‖e₁ᵀ and U·R'_map = ‖U‖e₁ᵀ = ‖V‖e₁ᵀ, hence
/// V·R_map·R'_mapᵀ = U (Eq. 43–44).
pub fn urt_rotation(v: &[f32]) -> UrtResult {
    let n = v.len();
    let u = uniform_target(v);
    let v_chain = map_to_e1(v);
    let u_chain = map_to_e1(&u);
    // Dense form: rows of Rᵁ are e_r -> apply v_chain -> apply u_chain⁻¹.
    // The forward chain fans out across cores (O(n−1) per row); the
    // inverse has no bulk kernel yet, so it stays a per-row loop.
    let mut rot = Tensor::eye(n);
    givens_rotate_rows(&mut rot, &v_chain, 0);
    for r in 0..n {
        u_chain.apply_row_inverse(rot.row_mut(r));
    }
    UrtResult { rotation: rot, target: u, v_chain, u_chain }
}

/// Apply Rᵁ to a row vector in O(n) via the chains (no dense matmul).
pub fn urt_apply_row(res: &UrtResult, v: &mut [f32]) {
    res.v_chain.apply_row(v);
    res.u_chain.apply_row_inverse(v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn template_centered_and_even() {
        let q = uniform_template(5);
        assert!((q.iter().sum::<f32>()).abs() < 1e-6);
        // evenly spaced
        for w in q.windows(2) {
            assert!((w[1] - w[0] - 2.0 / 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn target_preserves_norm_and_rank() {
        let mut rng = Rng::new(1);
        let v = rng.normal_vec(32, 2.0);
        let u = uniform_target(&v);
        let nv = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nu = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((nv - nu).abs() / nv < 1e-4);
        // rank preservation
        let ov = stats::argsort(&v);
        let ou = stats::argsort(&u);
        assert_eq!(ov, ou);
    }

    #[test]
    fn rotation_is_orthogonal() {
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(24, 1.5);
        let res = urt_rotation(&v);
        assert!(res.rotation.orthogonality_defect() < 1e-3,
                "defect {}", res.rotation.orthogonality_defect());
    }

    #[test]
    fn maps_profile_onto_target_exactly() {
        let mut rng = Rng::new(3);
        for n in [4usize, 9, 33] {
            let v = rng.normal_vec(n, 1.0);
            let res = urt_rotation(&v);
            let got = Tensor::from_raw(vec![1, n], v.clone())
                .matmul(&res.rotation)
                .into_data();
            for i in 0..n {
                assert!((got[i] - res.target[i]).abs() < 2e-3,
                        "n={n} i={i}: {} vs {}", got[i], res.target[i]);
            }
        }
    }

    #[test]
    fn chain_apply_matches_dense() {
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(16, 1.0);
        let res = urt_rotation(&v);
        let x = rng.normal_vec(16, 1.0);
        let dense = Tensor::from_raw(vec![1, 16], x.clone())
            .matmul(&res.rotation)
            .into_data();
        let mut fast = x;
        urt_apply_row(&res, &mut fast);
        for i in 0..16 {
            assert!((fast[i] - dense[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn flattens_outlier_profile() {
        // after URT the profile's spread shrinks toward uniform
        let mut v = vec![0.5f32; 20];
        v[3] = 12.0;
        v[11] = -9.0;
        let res = urt_rotation(&v);
        let got = Tensor::from_raw(vec![1, 20], v.clone())
            .matmul(&res.rotation)
            .into_data();
        let max_after = got.iter().fold(0f32, |m, x| m.max(x.abs()));
        let max_before = 12.0;
        assert!(max_after < max_before * 0.5, "max after {max_after}");
    }
}
