//! URT — Uniformity Rotation Transformation (§4.2, Eq. 39–44).
//!
//! Targets dense **normal outliers**: build the norm-preserving,
//! rank-preserving uniform target U for the channel profile V (Eq. 41–42),
//! map both V and U onto ‖V‖e₁ with n−1 Givens rotations each (Ma et al.
//! 2024a), and compose Rᵁ = R_map · R'_mapᵀ so that V·Rᵁ = U exactly.
//! O(n) construction, O(n log n) total via the chain representation.

use crate::rotation::givens::{map_to_e1, GivensChain};
use crate::tensor::kernels::{givens_rotate_rows, givens_rotate_rows_inv};
use crate::tensor::{stats, Tensor};

pub struct UrtResult {
    /// Dense Rᵁ (n×n) — what the pipeline feeds the graphs.
    pub rotation: Tensor,
    /// The uniform target the profile is rotated onto.
    pub target: Vec<f32>,
    /// Chains, kept for O(n)-per-vector application in analyses.
    pub v_chain: GivensChain,
    pub u_chain: GivensChain,
}

/// Chain-only form of Rᵁ = R_map · R'_mapᵀ — the Givens fast path. Both
/// factors are (n−1)-rotation chains, so applying Rᵁ to T rows costs
/// O(T·n) instead of the O(T·n²) dense matmul (or O(n³) to compose Rᵁ
/// into another dense rotation).
pub struct UrtChains {
    /// V·v_chain = ‖V‖e₁ᵀ.
    pub v_chain: GivensChain,
    /// U·u_chain = ‖U‖e₁ᵀ; applied inverted to come back off the axis.
    pub u_chain: GivensChain,
    /// The uniform target the profile is rotated onto.
    pub target: Vec<f32>,
}

/// Build the chain form of Rᵁ for profile `v` (Eq. 43–44, no dense n×n).
pub fn urt_chains(v: &[f32]) -> UrtChains {
    let u = uniform_target(v);
    UrtChains { v_chain: map_to_e1(v), u_chain: map_to_e1(&u), target: u }
}

/// x ← x·Rᵁ for every row of `x`, via the chains: forward chain, then
/// inverse chain, each fanned over the worker pool. Row results are
/// independent of the partitioning, so this is bit-identical across
/// thread counts (same contract as [`givens_rotate_rows`]).
pub fn urt_chains_rotate_rows(x: &mut Tensor, ch: &UrtChains, threads: usize) {
    givens_rotate_rows(x, &ch.v_chain, threads);
    givens_rotate_rows_inv(x, &ch.u_chain, threads);
}

/// The centered uniform template q_k = (2k − n − 1)/n, k = 1..n (Eq. 41).
pub fn uniform_template(n: usize) -> Vec<f32> {
    (1..=n)
        .map(|k| (2.0 * k as f32 - n as f32 - 1.0) / n as f32)
        .collect()
}

/// Norm-preserving, rank-preserving uniform target for profile `v` (Eq. 42).
pub fn uniform_target(v: &[f32]) -> Vec<f32> {
    let n = v.len();
    let q = uniform_template(n);
    let vnorm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    let qnorm = q.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
    let order = stats::argsort(v); // ascending ranks of V
    let mut u = vec![0.0f32; n];
    for (k, &idx) in order.iter().enumerate() {
        u[idx] = q[k] * vnorm / qnorm;
    }
    u
}

/// Build Rᵁ with V·Rᵁ = U.
///
/// V·R_map = ‖V‖e₁ᵀ and U·R'_map = ‖U‖e₁ᵀ = ‖V‖e₁ᵀ, hence
/// V·R_map·R'_mapᵀ = U (Eq. 43–44).
pub fn urt_rotation(v: &[f32]) -> UrtResult {
    let n = v.len();
    let UrtChains { v_chain, u_chain, target } = urt_chains(v);
    // Dense form: rows of Rᵁ are e_r -> apply v_chain -> apply u_chain⁻¹,
    // both directions through the bulk row kernels.
    let mut rot = Tensor::eye(n);
    givens_rotate_rows(&mut rot, &v_chain, 0);
    givens_rotate_rows_inv(&mut rot, &u_chain, 0);
    UrtResult { rotation: rot, target, v_chain, u_chain }
}

/// Apply Rᵁ to a row vector in O(n) via the chains (no dense matmul).
pub fn urt_apply_row(res: &UrtResult, v: &mut [f32]) {
    res.v_chain.apply_row(v);
    res.u_chain.apply_row_inverse(v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn template_centered_and_even() {
        let q = uniform_template(5);
        assert!((q.iter().sum::<f32>()).abs() < 1e-6);
        // evenly spaced
        for w in q.windows(2) {
            assert!((w[1] - w[0] - 2.0 / 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn target_preserves_norm_and_rank() {
        let mut rng = Rng::new(1);
        let v = rng.normal_vec(32, 2.0);
        let u = uniform_target(&v);
        let nv = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nu = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((nv - nu).abs() / nv < 1e-4);
        // rank preservation
        let ov = stats::argsort(&v);
        let ou = stats::argsort(&u);
        assert_eq!(ov, ou);
    }

    #[test]
    fn rotation_is_orthogonal() {
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(24, 1.5);
        let res = urt_rotation(&v);
        assert!(res.rotation.orthogonality_defect() < 1e-3,
                "defect {}", res.rotation.orthogonality_defect());
    }

    #[test]
    fn maps_profile_onto_target_exactly() {
        let mut rng = Rng::new(3);
        for n in [4usize, 9, 33] {
            let v = rng.normal_vec(n, 1.0);
            let res = urt_rotation(&v);
            let got = Tensor::from_raw(vec![1, n], v.clone())
                .matmul(&res.rotation)
                .into_data();
            for i in 0..n {
                assert!((got[i] - res.target[i]).abs() < 2e-3,
                        "n={n} i={i}: {} vs {}", got[i], res.target[i]);
            }
        }
    }

    #[test]
    fn chain_apply_matches_dense() {
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(16, 1.0);
        let res = urt_rotation(&v);
        let x = rng.normal_vec(16, 1.0);
        let dense = Tensor::from_raw(vec![1, 16], x.clone())
            .matmul(&res.rotation)
            .into_data();
        let mut fast = x;
        urt_apply_row(&res, &mut fast);
        for i in 0..16 {
            assert!((fast[i] - dense[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn bulk_chain_rows_match_dense_rotation() {
        let mut rng = Rng::new(5);
        let v = rng.normal_vec(24, 1.5);
        let res = urt_rotation(&v);
        let ch = urt_chains(&v);
        let x = Tensor::randn(&[7, 24], 1.0, &mut rng);
        let dense = x.matmul(&res.rotation);
        let mut fast = x.clone();
        urt_chains_rotate_rows(&mut fast, &ch, 0);
        assert!(fast.sub(&dense).max_abs() < 1e-3,
                "defect {}", fast.sub(&dense).max_abs());
        // and per-row chain application agrees bit-for-bit with the bulk
        let mut rows = x.clone();
        for r in 0..rows.rows() {
            urt_apply_row(&res, rows.row_mut(r));
        }
        assert_eq!(rows.data(), fast.data());
    }

    #[test]
    fn flattens_outlier_profile() {
        // after URT the profile's spread shrinks toward uniform
        let mut v = vec![0.5f32; 20];
        v[3] = 12.0;
        v[11] = -9.0;
        let res = urt_rotation(&v);
        let got = Tensor::from_raw(vec![1, 20], v.clone())
            .matmul(&res.rotation)
            .into_data();
        let max_after = got.iter().fold(0f32, |m, x| m.max(x.abs()));
        let max_before = 12.0;
        assert!(max_after < max_before * 0.5, "max after {max_after}");
    }
}
