//! The SingleQuant composer (§4.2, Eq. 45): closed-form per-site Kronecker
//! rotation factors from calibration profiles — no optimization, a single
//! calibration pass, deterministic given the seed.
//!
//! For a site of width n = n₁·n₂ (Algorithm 1), the composed rotation is
//! `R = (Rᴬ R₁ᵁ) ⊗ (H R₂ᵁ)`
//! in row-vector application order: ART first smooths the massive-outlier
//! axis profile on the n₁ axis, URT then uniformizes it; the n₂ axis gets
//! the Hadamard mixing followed by its own URT. (Eq. 45 writes the first
//! factor transposed; with orthogonal factors this is an equivalent
//! orientation convention — our graphs apply R₁ᵀ on the left of the
//! reshaped token, see Eq. 31 / `kernels.kron_rotate`.)
//!
//! Profiles:
//! * ART consumes the **signed channel absmax** (massive outliers are rare
//!   and extreme, so the max-magnitude representative is the right target
//!   for Lemma 1).
//! * URT consumes the **signed channel median** (normal outliers are the
//!   "consistent median values across feature dimensions" of §4.2).

use crate::rotation::art::{art_rotation, art_rotation_pure};
use crate::rotation::hadamard::hadamard_matrix;
use crate::rotation::kronecker::kron_factor;
use crate::rotation::urt::{urt_chains, urt_chains_rotate_rows};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Calibration summary for one rotation site (one quantized-linear input).
#[derive(Clone, Debug)]
pub struct SiteProfile {
    /// Site width n (input dim of the linears at this site).
    pub n: usize,
    /// Per-channel signed value of maximum magnitude over calibration.
    pub signed_absmax: Vec<f32>,
    /// Per-channel median over calibration tokens.
    pub median: Vec<f32>,
}

/// The Kronecker factor pair fed to the runtime graphs (and used to rotate
/// weights offline via `kron_rotate_weight`).
#[derive(Clone, Debug)]
pub struct SiteRotation {
    pub r1: Tensor,
    pub r2: Tensor,
}

impl SiteRotation {
    pub fn identity(n: usize) -> SiteRotation {
        let (n1, n2) = kron_factor(n);
        SiteRotation { r1: Tensor::eye(n1), r2: Tensor::eye(n2) }
    }

    /// Orthogonality defect of both factors (tests/invariants).
    pub fn defect(&self) -> f32 {
        self.r1
            .orthogonality_defect()
            .max(self.r2.orthogonality_defect())
    }
}

/// Knobs for the composer (the ablation axes of Table 6 / Fig. 4).
#[derive(Clone, Debug)]
pub struct SingleQuantConfig {
    pub use_art: bool,
    pub use_urt: bool,
    /// Hadamard mixing on the n₂ axis (the `H` of Eq. 45).
    pub use_hadamard: bool,
    /// ART detect-and-rotate repetitions. Fig. 4 sweeps 20..210 and shows
    /// saturation at the low end; 20 is the paper's operating point (each
    /// step is one closed-form Givens + complement — still microseconds).
    pub art_steps: usize,
    /// Random complement block in ART (Eq. 38's `O`); disabled in the
    /// "pure" ablation.
    pub art_random_complement: bool,
    /// Also apply URT on the n₂ (Hadamard) axis. Off by default: on this
    /// testbed the ramp-shaped uniform target *after* the FWHT measurably
    /// undoes part of the Hadamard's flattening (see EXPERIMENTS.md §Notes,
    /// Kronecker-axis adaptation of Eq. 45).
    pub urt_axis2: bool,
    pub seed: u64,
}

impl Default for SingleQuantConfig {
    fn default() -> Self {
        SingleQuantConfig {
            use_art: true,
            use_urt: true,
            use_hadamard: true,
            art_steps: 20,
            art_random_complement: true,
            urt_axis2: false,
            seed: 0x51C7,
        }
    }
}

/// Axis profile of a length-n channel vector reshaped to [n1, n2]:
/// per-row (axis 1) or per-column (axis 2) signed absmax.
fn axis_profile(v: &[f32], n1: usize, n2: usize, axis1: bool) -> Vec<f32> {
    let mut out = vec![0.0f32; if axis1 { n1 } else { n2 }];
    for i in 0..n1 {
        for j in 0..n2 {
            let x = v[i * n2 + j];
            let slot = if axis1 { i } else { j };
            if x.abs() > out[slot].abs() {
                out[slot] = x;
            }
        }
    }
    out
}

fn rotate_profile(v: &[f32], r: &Tensor) -> Vec<f32> {
    Tensor::from_raw(vec![1, v.len()], v.to_vec()).matmul(r).into_data()
}

/// Build the SingleQuant rotation for one site.
pub fn build_site_rotation(profile: &SiteProfile, cfg: &SingleQuantConfig) -> SiteRotation {
    let n = profile.n;
    let (n1, n2) = kron_factor(n);
    let mut rng = Rng::new(cfg.seed ^ (n as u64));

    // ---- n1 axis: ART (massive outliers) then URT (normal outliers) ----
    let mo1 = axis_profile(&profile.signed_absmax, n1, n2, true);
    let r_a = if cfg.use_art && n1 >= 2 {
        if cfg.art_random_complement {
            art_rotation(&mo1, cfg.art_steps, &mut rng).rotation
        } else {
            art_rotation_pure(&mo1, cfg.art_steps).rotation
        }
    } else {
        Tensor::eye(n1)
    };
    let r1 = if cfg.use_urt && n1 >= 2 {
        let no1 = axis_profile(&profile.median, n1, n2, true);
        let no1_rot = rotate_profile(&no1, &r_a);
        // Givens fast path: Rᴬ·Rᵁ row-by-row through the URT chains —
        // O(n1²) instead of the O(n1³) dense matmul against a dense Rᵁ.
        let mut r1 = r_a;
        urt_chains_rotate_rows(&mut r1, &urt_chains(&no1_rot), 0);
        r1
    } else {
        r_a
    };

    // ---- n2 axis: Hadamard then URT ----
    let h = if cfg.use_hadamard && n2 >= 2 {
        hadamard_matrix(n2)
    } else {
        Tensor::eye(n2)
    };
    let r2 = if cfg.use_urt && cfg.urt_axis2 && n2 >= 2 {
        let no2 = axis_profile(&profile.median, n1, n2, false);
        let no2_rot = rotate_profile(&no2, &h);
        // Same chain fast path as the n1 axis: H·Rᵁ without a dense Rᵁ.
        let mut r2 = h;
        urt_chains_rotate_rows(&mut r2, &urt_chains(&no2_rot), 0);
        r2
    } else {
        h
    };

    SiteRotation { r1, r2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant_per_token, rel_error};
    use crate::rotation::kronecker::kron_rotate_rows;

    fn outlier_profile(n: usize, seed: u64) -> SiteProfile {
        let mut rng = Rng::new(seed);
        let mut absmax: Vec<f32> = (0..n).map(|_| 1.0 + rng.f32()).collect();
        let mut median: Vec<f32> = (0..n).map(|_| 0.3 * rng.normal_f32()).collect();
        absmax[n / 4] = 35.0;
        median[n / 4] = 6.0;
        absmax[n / 2] = -22.0;
        median[n / 2] = -4.0;
        SiteProfile { n, signed_absmax: absmax, median }
    }

    #[test]
    fn factors_are_orthogonal() {
        let p = outlier_profile(96, 1);
        let rot = build_site_rotation(&p, &SingleQuantConfig::default());
        assert!(rot.defect() < 5e-3, "defect {}", rot.defect());
    }

    #[test]
    fn ablation_combinations_all_orthogonal() {
        let p = outlier_profile(64, 2);
        for (art, urt) in [(false, false), (true, false), (false, true), (true, true)] {
            let cfg = SingleQuantConfig { use_art: art, use_urt: urt, ..Default::default() };
            let rot = build_site_rotation(&p, &cfg);
            assert!(rot.defect() < 5e-3, "art={art} urt={urt}: {}", rot.defect());
        }
    }

    #[test]
    fn identity_config_yields_identity() {
        let p = outlier_profile(64, 3);
        let cfg = SingleQuantConfig {
            use_art: false,
            use_urt: false,
            use_hadamard: false,
            ..Default::default()
        };
        let rot = build_site_rotation(&p, &cfg);
        assert!(rot.r1.sub(&Tensor::eye(rot.r1.rows())).max_abs() < 1e-7);
        assert!(rot.r2.sub(&Tensor::eye(rot.r2.rows())).max_abs() < 1e-7);
    }

    #[test]
    fn rotation_improves_quantization_of_outlier_activations() {
        // End-to-end property: activations with MO channels quantize with
        // materially lower error after the SingleQuant rotation (Fig. 1b).
        let n = 96;
        let mut rng = Rng::new(4);
        let mut x = Tensor::randn(&[64, n], 1.0, &mut rng);
        for i in 0..64 {
            x.row_mut(i)[n / 4] = 35.0 * (0.8 + 0.4 * rng.f32());
            x.row_mut(i)[n / 2] = -22.0 * (0.8 + 0.4 * rng.f32());
        }
        let p = SiteProfile {
            n,
            signed_absmax: crate::tensor::stats::col_signed_absmax(&x),
            median: crate::tensor::stats::col_median(&x),
        };
        let rot = build_site_rotation(&p, &SingleQuantConfig::default());
        let xr = kron_rotate_rows(&x, &rot.r1, &rot.r2);
        let err_plain = rel_error(&x, &fake_quant_per_token(&x, 4, 1.0));
        let err_rot = rel_error(&xr, &fake_quant_per_token(&xr, 4, 1.0));
        assert!(err_rot < 0.6 * err_plain,
                "rotated {err_rot} vs plain {err_plain}");
    }

    #[test]
    fn deterministic() {
        let p = outlier_profile(64, 5);
        let cfg = SingleQuantConfig::default();
        let a = build_site_rotation(&p, &cfg);
        let b = build_site_rotation(&p, &cfg);
        assert!(a.r1.sub(&b.r1).max_abs() < 1e-9);
        assert!(a.r2.sub(&b.r2).max_abs() < 1e-9);
    }
}
