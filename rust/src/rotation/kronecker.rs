//! Kronecker-structured rotations: Algorithm 1 + the two-sided application
//! form of Eq. 31 — the O(n^{3/2}) mechanism behind Tables 5/7 and Fig. 3.

use crate::tensor::Tensor;

/// Algorithm 1: factor n = n1·n2 with n2 the power of two dividing n that
/// is nearest √n (ties resolved toward the smaller candidate, matching the
/// strict `<` update of the paper's pseudocode).
pub fn kron_factor(n: usize) -> (usize, usize) {
    assert!(n >= 1);
    let root = (n as f64).sqrt();
    let mut n2 = 1usize;
    let mut k = 0u32;
    while (1usize << k) <= n {
        let a = 1usize << k;
        if n % a == 0 && (a as f64 - root).abs() < (n2 as f64 - root).abs() {
            n2 = a;
        }
        k += 1;
    }
    (n / n2, n2)
}

/// Apply x ← x (R1 ⊗ R2) to every row of x [T, n] via the two-sided form
/// rvec(R1ᵀ X_mat R2) (Eq. 31). Cost O(T·(n1²n2 + n1n2²)).
pub fn kron_rotate_rows(x: &Tensor, r1: &Tensor, r2: &Tensor) -> Tensor {
    let (t, n) = (x.rows(), x.cols());
    let (n1, n2) = (r1.rows(), r2.rows());
    assert_eq!(n1 * n2, n, "kron factors {n1}x{n2} != {n}");
    let mut out = Tensor::zeros(&[t, n]);
    // scratch for one token's [n1, n2] matrix
    let mut tmp = vec![0.0f32; n1 * n2];
    for trow in 0..t {
        let xr = x.row(trow);
        // tmp = R1^T @ X_mat  (tmp[k, j] = sum_i r1[i, k] * x[i, j])
        tmp.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n1 {
            let xrow = &xr[i * n2..(i + 1) * n2];
            let r1row = r1.row(i);
            for (k, &r) in r1row.iter().enumerate() {
                if r == 0.0 {
                    continue;
                }
                let trow_ = &mut tmp[k * n2..(k + 1) * n2];
                for j in 0..n2 {
                    trow_[j] += r * xrow[j];
                }
            }
        }
        // out = tmp @ R2  (out[k, l] = sum_j tmp[k, j] * r2[j, l])
        let orow = out.row_mut(trow);
        for k in 0..n1 {
            let trow_ = &tmp[k * n2..(k + 1) * n2];
            let dst = &mut orow[k * n2..(k + 1) * n2];
            for (j, &tv) in trow_.iter().enumerate() {
                if tv == 0.0 {
                    continue;
                }
                let r2row = r2.row(j);
                for l in 0..n2 {
                    dst[l] += tv * r2row[l];
                }
            }
        }
    }
    out
}

/// Transform a weight W [n, C] to (R1 ⊗ R2)ᵀ W so that
/// (x(R1⊗R2)) · ((R1⊗R2)ᵀW) = xW (Eq. 1). Implemented by applying the same
/// row transform to Wᵀ.
pub fn kron_rotate_weight(w: &Tensor, r1: &Tensor, r2: &Tensor) -> Tensor {
    kron_rotate_rows(&w.transpose(), r1, r2).transpose()
}

/// Two-sided Hessian sandwich (R1 ⊗ R2)ᵀ H (R1 ⊗ R2) without ever
/// materializing the n×n Kronecker product. The left factor is exactly
/// the weight transform ((R1⊗R2)ᵀ H, via [`kron_rotate_weight`]) and the
/// right factor is the row transform (· (R1⊗R2), via
/// [`kron_rotate_rows`]), so the whole sandwich costs
/// O(n²·(n1 + n2)) — versus O(n³) for the two dense products plus O(n²)
/// transient storage for the kron matrix itself. This is what the
/// pipeline feeds GPTQ when quantizing in the rotated basis.
pub fn kron_sandwich(h: &Tensor, r1: &Tensor, r2: &Tensor) -> Tensor {
    assert_eq!(h.rows(), h.cols(), "kron_sandwich needs square H, got {:?}", h.shape());
    kron_rotate_rows(&kron_rotate_weight(h, r1, r2), r1, r2)
}

/// FLOP count of the Kronecker application per token (the O(n^{3/2}) claim).
pub fn kron_flops(n1: usize, n2: usize) -> usize {
    2 * (n1 * n1 * n2 + n1 * n2 * n2)
}

/// FLOP count of a dense n×n rotation per token.
pub fn dense_flops(n: usize) -> usize {
    2 * n * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::decomp::random_orthogonal;
    use crate::util::rng::Rng;

    #[test]
    fn algorithm1_postconditions() {
        for n in [1usize, 2, 12, 64, 96, 128, 160, 256, 320, 416, 1000] {
            let (n1, n2) = kron_factor(n);
            assert_eq!(n1 * n2, n);
            assert!(n2.is_power_of_two());
            // n2 is the closest dividing power of two to sqrt(n)
            let root = (n as f64).sqrt();
            for k in 0..20 {
                let a = 1usize << k;
                if a <= n && n % a == 0 {
                    assert!((n2 as f64 - root).abs() <= (a as f64 - root).abs() + 1e-9,
                            "n={n}: chose {n2}, but {a} is closer to {root}");
                }
            }
        }
    }

    #[test]
    fn two_sided_matches_dense_kron() {
        let mut rng = Rng::new(1);
        let (n1, n2) = (6, 4);
        let r1 = random_orthogonal(n1, &mut rng);
        let r2 = random_orthogonal(n2, &mut rng);
        let x = Tensor::randn(&[5, n1 * n2], 1.0, &mut rng);
        let fast = kron_rotate_rows(&x, &r1, &r2);
        let dense = x.matmul(&r1.kron(&r2));
        assert!(fast.sub(&dense).max_abs() < 1e-4);
    }

    #[test]
    fn weight_transform_preserves_product() {
        // (xR)(R^T W) == xW — Eq. 1 with Kronecker structure.
        let mut rng = Rng::new(2);
        let (n1, n2, c) = (4, 8, 6);
        let r1 = random_orthogonal(n1, &mut rng);
        let r2 = random_orthogonal(n2, &mut rng);
        let x = Tensor::randn(&[7, n1 * n2], 1.0, &mut rng);
        let w = Tensor::randn(&[n1 * n2, c], 0.5, &mut rng);
        let y_ref = x.matmul(&w);
        let xr = kron_rotate_rows(&x, &r1, &r2);
        let wr = kron_rotate_weight(&w, &r1, &r2);
        let y = xr.matmul(&wr);
        assert!(y.sub(&y_ref).max_abs() < 1e-3,
                "defect {}", y.sub(&y_ref).max_abs());
    }

    #[test]
    fn sandwich_matches_dense_reference() {
        // odd n1, non-square factors, and the degenerate 1-sized axes —
        // every case must agree with the materialized kron sandwich
        let mut rng = Rng::new(4);
        for (n1, n2) in [(3usize, 4usize), (5, 2), (7, 8), (1, 8), (5, 1), (4, 4)] {
            let n = n1 * n2;
            let r1 = random_orthogonal(n1, &mut rng);
            let r2 = random_orthogonal(n2, &mut rng);
            let x = Tensor::randn(&[3 * n + 5, n], 0.6, &mut rng);
            let h = x.matmul_tn(&x); // SPD, like a real calibration Hessian
            let fast = kron_sandwich(&h, &r1, &r2);
            let r = r1.kron(&r2);
            let dense = r.transpose().matmul(&h.matmul(&r));
            let tol = 1e-5 * dense.max_abs().max(1.0);
            assert!(fast.sub(&dense).max_abs() < tol,
                    "n1={n1} n2={n2}: defect {} tol {tol}", fast.sub(&dense).max_abs());
        }
    }

    #[test]
    fn sandwich_preserves_symmetry_and_trace() {
        let mut rng = Rng::new(5);
        let (n1, n2) = (3, 8);
        let n = n1 * n2;
        let r1 = random_orthogonal(n1, &mut rng);
        let r2 = random_orthogonal(n2, &mut rng);
        let x = Tensor::randn(&[64, n], 1.0, &mut rng);
        let h = x.matmul_tn(&x);
        let s = kron_sandwich(&h, &r1, &r2);
        let tr_h: f32 = (0..n).map(|i| h.at(i, i)).sum();
        let tr_s: f32 = (0..n).map(|i| s.at(i, i)).sum();
        assert!((tr_h - tr_s).abs() < 1e-2 * tr_h.abs().max(1.0), "{tr_h} vs {tr_s}");
        assert!(s.sub(&s.transpose()).max_abs() < 1e-4 * s.max_abs().max(1.0));
    }

    #[test]
    fn flops_are_subquadratic() {
        // the O(n^{3/2}) headline: balanced factors beat dense by ~√n/2
        let n = 4096;
        let (n1, n2) = kron_factor(n);
        assert!(kron_flops(n1, n2) * 8 < dense_flops(n));
    }

    #[test]
    fn norm_preserved() {
        let mut rng = Rng::new(3);
        let r1 = random_orthogonal(3, &mut rng);
        let r2 = random_orthogonal(8, &mut rng);
        let x = Tensor::randn(&[4, 24], 2.0, &mut rng);
        let y = kron_rotate_rows(&x, &r1, &r2);
        assert!((x.frob_norm() - y.frob_norm()).abs() < 1e-3);
    }
}
