//! Givens rotations: the 2-D building block of ART and URT.
//!
//! Row-vector convention throughout (matching the paper and the JAX graphs):
//! applying `G(i, j; θ)` to a row vector `v` rotates the (i, j) coordinate
//! pair, leaving everything else untouched. A [`GivensChain`] applies k
//! rotations in O(k) per vector — this is what makes URT's n−1-rotation map
//! an O(n) construction (§4.2).

use crate::tensor::Tensor;

/// One plane rotation: coordinates (i, j), angle encoded as (cos, sin).
#[derive(Clone, Copy, Debug)]
pub struct Givens {
    pub i: usize,
    pub j: usize,
    pub c: f32,
    pub s: f32,
}

impl Givens {
    pub fn new(i: usize, j: usize, theta: f32) -> Givens {
        assert_ne!(i, j);
        Givens { i, j, c: theta.cos(), s: theta.sin() }
    }

    /// Apply to a row vector in place: (vi, vj) ← (vi·c − vj·s, vi·s + vj·c).
    ///
    /// This is `v ← v G` with G[i,i]=c, G[i,j]=s, G[j,i]=−s, G[j,j]=c —
    /// the clockwise rotation of the paper's §4.1.
    #[inline]
    pub fn apply_row(&self, v: &mut [f32]) {
        let (vi, vj) = (v[self.i], v[self.j]);
        v[self.i] = vi * self.c - vj * self.s;
        v[self.j] = vi * self.s + vj * self.c;
    }

    /// Dense n×n matrix form.
    pub fn to_matrix(&self, n: usize) -> Tensor {
        let mut m = Tensor::eye(n);
        m.set(self.i, self.i, self.c);
        m.set(self.i, self.j, self.s);
        m.set(self.j, self.i, -self.s);
        m.set(self.j, self.j, self.c);
        m
    }
}

/// The closed-form optimal angle of Lemma 1: for V = (a, b),
/// θ* = atan2(b, a) − π/4 rotates V onto (r/√2, r/√2), minimizing ‖VG‖∞
/// over O(2).
pub fn lemma1_angle(a: f32, b: f32) -> f32 {
    b.atan2(a) - std::f32::consts::FRAC_PI_4
}

/// Apply Lemma 1 to the coordinate pair (i, j) of a profile vector:
/// returns the Givens rotation that balances the pair's energy.
pub fn lemma1_givens(v: &[f32], i: usize, j: usize) -> Givens {
    // The pair (a, b) lives in the (i, j) plane; after rotation both
    // coordinates carry r/√2.
    let theta = lemma1_angle(v[i], v[j]);
    // Rotation within the (i, j) plane: our apply_row treats index order as
    // the plane's (x, y) axes.
    Givens::new(i, j, -theta)
}

/// An ordered product of Givens rotations (applied left-to-right).
#[derive(Clone, Debug, Default)]
pub struct GivensChain {
    pub rotations: Vec<Givens>,
}

impl GivensChain {
    pub fn new() -> GivensChain {
        GivensChain::default()
    }

    pub fn push(&mut self, g: Givens) {
        self.rotations.push(g);
    }

    pub fn len(&self) -> usize {
        self.rotations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rotations.is_empty()
    }

    /// v ← v · G₁G₂…G_k (in place, O(k)).
    pub fn apply_row(&self, v: &mut [f32]) {
        for g in &self.rotations {
            g.apply_row(v);
        }
    }

    /// Inverse application: v ← v · G_kᵀ…G₁ᵀ.
    pub fn apply_row_inverse(&self, v: &mut [f32]) {
        for g in self.rotations.iter().rev() {
            let ginv = Givens { i: g.i, j: g.j, c: g.c, s: -g.s };
            ginv.apply_row(v);
        }
    }

    /// Dense matrix form (product of the chain).
    pub fn to_matrix(&self, n: usize) -> Tensor {
        // Row r of the product = e_r applied through the chain.
        let mut m = Tensor::eye(n);
        for r in 0..n {
            self.apply_row(m.row_mut(r));
        }
        m
    }
}

/// The n−1-rotation map of Ma et al. (2024a): a chain C with
/// v·C = (‖v‖, 0, …, 0). Each step folds coordinate k into coordinate 0.
pub fn map_to_e1(v: &[f32]) -> GivensChain {
    let n = v.len();
    let mut chain = GivensChain::new();
    let mut w = v.to_vec();
    for k in 1..n {
        let (a, b) = (w[0], w[k]);
        let r = (a * a + b * b).sqrt();
        if r < 1e-12 {
            continue;
        }
        // Choose θ with cos = a/r, sin = −b/r so that apply_row sends
        // (a, b) -> (r, 0).
        let g = Givens { i: 0, j: k, c: a / r, s: b / r };
        // verify orientation: (a,b) -> (a*c - b*s, a*s + b*c)
        //   = (a²/r + b²/r, ab/r − ab/r) = (r, 0) with s = −b/r.
        let g = Givens { c: g.c, s: -g.s, ..g };
        g.apply_row(&mut w);
        chain.push(g);
    }
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lemma1_balances_pair() {
        // Lemma 1: VG(θ*) = (r/√2, r/√2).
        for (a, b) in [(3.0f32, 4.0), (-2.0, 0.5), (0.0, 1.0), (5.0, -5.0)] {
            let r = (a * a + b * b).sqrt();
            let mut v = vec![a, b];
            let g = lemma1_givens(&v, 0, 1);
            g.apply_row(&mut v);
            let target = r / 2f32.sqrt();
            assert!((v[0].abs() - target).abs() < 1e-4, "{v:?} vs {target}");
            assert!((v[1].abs() - target).abs() < 1e-4, "{v:?} vs {target}");
            // ∞-norm is minimized (Lemma 1's optimum)
            assert!(v.iter().fold(0f32, |m, x| m.max(x.abs())) <= target + 1e-4);
        }
    }

    #[test]
    fn givens_matrix_is_orthogonal() {
        let g = Givens::new(1, 4, 0.7);
        assert!(g.to_matrix(6).orthogonality_defect() < 1e-6);
    }

    #[test]
    fn chain_matrix_matches_apply() {
        let mut rng = Rng::new(1);
        let mut chain = GivensChain::new();
        for k in 0..10 {
            chain.push(Givens::new(k % 5, 5 + (k % 3), rng.f32() * 3.0));
        }
        let m = chain.to_matrix(8);
        let mut v = rng.normal_vec(8, 1.0);
        let expect = {
            let row = Tensor::from_raw(vec![1, 8], v.clone());
            row.matmul(&m)
        };
        chain.apply_row(&mut v);
        for i in 0..8 {
            assert!((v[i] - expect.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn map_to_e1_works() {
        let mut rng = Rng::new(2);
        for n in [2usize, 5, 17, 64] {
            let v = rng.normal_vec(n, 2.0);
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            let chain = map_to_e1(&v);
            assert!(chain.len() <= n - 1);
            let mut w = v.clone();
            chain.apply_row(&mut w);
            assert!((w[0] - norm).abs() < 1e-3, "n={n}: {} vs {norm}", w[0]);
            for &x in &w[1..] {
                assert!(x.abs() < 1e-3, "n={n}: residual {x}");
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        let mut rng = Rng::new(3);
        let v = rng.normal_vec(12, 1.0);
        let chain = map_to_e1(&v);
        let mut w = v.clone();
        chain.apply_row(&mut w);
        chain.apply_row_inverse(&mut w);
        for i in 0..12 {
            assert!((w[i] - v[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn chain_preserves_norm() {
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(20, 1.5);
        let chain = map_to_e1(&rng.normal_vec(20, 1.0));
        let mut w = v.clone();
        chain.apply_row(&mut w);
        let n0 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let n1 = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n0 - n1).abs() < 1e-3);
    }
}
