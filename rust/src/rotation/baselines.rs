//! Baseline pre-quantization transformations the paper compares against.
//!
//! All baselines emit the same [`SiteRotation`] Kronecker-factor interface
//! as SingleQuant so every method runs through the identical W4A4 runtime
//! graph (DESIGN.md §Substitutions notes where a baseline's original form
//! was dense and is represented here in Kronecker structure):
//!
//! * **SmoothQuant** — channel-wise α-scaling (no rotation; the scale is
//!   folded into producer weights by the pipeline).
//! * **QuaRot** — global incoherence rotation: Hadamard on the power-of-two
//!   axis, seeded random orthogonal on the other.
//! * **QuIP-style** — two-sided random orthogonal incoherence (weight-only
//!   table).
//! * **DuQuant-style** — greedy iterated Givens smoothing + zigzag
//!   permutation + Hadamard.
//! * **SpinQuant** — Cayley SGD + STE over the Kronecker factor pair
//!   (§3.2's optimizer; per-step traces feed Fig. 2, wall-clock feeds
//!   Table 7).
//! * **FlatQuant** — the same learned-Kronecker optimizer; its LCT
//!   (learnable clipping threshold) is handled by the pipeline's clip
//!   search (Table 5).

use anyhow::Result;

use crate::quant::{fake_quant_per_channel, fake_quant_per_token};
use crate::rotation::cayley::{CayleyConfig, CayleyTrace};
use crate::rotation::givens::lemma1_givens;
use crate::rotation::hadamard::hadamard_matrix;
use crate::rotation::kronecker::{kron_factor, kron_rotate_rows, kron_rotate_weight};
use crate::rotation::singlequant::SiteRotation;
use crate::tensor::{decomp, stats, Tensor};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// SmoothQuant
// ---------------------------------------------------------------------------

/// SmoothQuant per-channel scale s_j = max|X_j|^α / max|W_j|^{1−α}
/// (Xiao et al., 2023). Activations are divided by s (folded into the
/// producer), weights multiplied by s.
pub fn smoothquant_scales(act_absmax: &[f32], w_absmax_in: &[f32], alpha: f32) -> Vec<f32> {
    act_absmax
        .iter()
        .zip(w_absmax_in)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha);
            s.clamp(1e-4, 1e4)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// QuaRot / QuIP-style incoherence rotations
// ---------------------------------------------------------------------------

/// QuaRot-style rotation: Hadamard on the n₂ (power-of-two) axis, seeded
/// random orthogonal on the n₁ axis.
pub fn quarot_rotation(n: usize, seed: u64) -> SiteRotation {
    let (n1, n2) = kron_factor(n);
    let mut rng = Rng::new(seed);
    let r1 = if n1 >= 2 { decomp::random_orthogonal(n1, &mut rng) } else { Tensor::eye(n1) };
    let r2 = if n2 >= 2 { hadamard_matrix(n2) } else { Tensor::eye(n2) };
    SiteRotation { r1, r2 }
}

/// QuIP-style two-sided random orthogonal incoherence preprocessing.
pub fn quip_rotation(n: usize, seed: u64) -> SiteRotation {
    let (n1, n2) = kron_factor(n);
    let mut rng = Rng::new(seed ^ 0xAB);
    let r1 = if n1 >= 2 { decomp::random_orthogonal(n1, &mut rng) } else { Tensor::eye(n1) };
    let r2 = if n2 >= 2 { decomp::random_orthogonal(n2, &mut rng) } else { Tensor::eye(n2) };
    SiteRotation { r1, r2 }
}

// ---------------------------------------------------------------------------
// DuQuant-style greedy rotation
// ---------------------------------------------------------------------------

/// Greedy smoothing: `steps` iterations of (argmax, argmin) Lemma-1 Givens
/// on the running profile — DuQuant's greedy outlier redistribution,
/// followed by a zigzag permutation that interleaves large and small
/// channels, then Hadamard mixing on the n₂ axis.
pub fn duquant_rotation(signed_absmax: &[f32], steps: usize, _seed: u64) -> SiteRotation {
    let n = signed_absmax.len();
    let (n1, n2) = kron_factor(n);
    let mo1 = axis_signed_absmax(signed_absmax, n1, n2, true);

    // greedy Givens rounds on the n1 profile
    let mut profile = mo1;
    let mut r1 = Tensor::eye(n1);
    for _ in 0..steps.max(1) {
        let i = stats::argmax_abs(&profile);
        let mut j = stats::argmin_abs(&profile);
        if i == j {
            j = (i + 1) % n1;
        }
        let g = lemma1_givens(&profile, i, j);
        g.apply_row(&mut profile);
        r1 = r1.matmul(&g.to_matrix(n1));
    }
    // zigzag permutation: sort by |profile| and interleave ends
    let order = stats::argsort(&profile.iter().map(|x| x.abs()).collect::<Vec<_>>());
    let mut zig = Vec::with_capacity(n1);
    let (mut lo, mut hi) = (0usize, n1 - 1);
    while lo <= hi {
        zig.push(order[hi]);
        if lo < hi {
            zig.push(order[lo]);
        }
        if hi == 0 {
            break;
        }
        lo += 1;
        hi -= 1;
    }
    let mut perm = Tensor::zeros(&[n1, n1]);
    for (dst, &src) in zig.iter().enumerate() {
        perm.set(src, dst, 1.0);
    }
    let r1 = r1.matmul(&perm);
    let r2 = if n2 >= 2 { hadamard_matrix(n2) } else { Tensor::eye(n2) };
    SiteRotation { r1, r2 }
}

fn axis_signed_absmax(v: &[f32], n1: usize, n2: usize, axis1: bool) -> Vec<f32> {
    let mut out = vec![0.0f32; if axis1 { n1 } else { n2 }];
    for i in 0..n1 {
        for j in 0..n2 {
            let x = v[i * n2 + j];
            let slot = if axis1 { i } else { j };
            if x.abs() > out[slot].abs() {
                out[slot] = x;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// SpinQuant / FlatQuant: learned Kronecker factors (Cayley SGD + STE)
// ---------------------------------------------------------------------------

/// Learned-rotation result: the factors plus the optimization trace
/// (Fig. 2's loss / grad-norm panels come from here and from
/// `cayley::cayley_sgd` on dense site rotations).
pub struct LearnedRotation {
    pub rotation: SiteRotation,
    pub trace: CayleyTrace,
}

/// Cayley SGD + STE over the Kronecker factor pair (R₁, R₂), minimizing the
/// Eq. 8 surrogate on a calibration sample. The Kronecker chain rule
/// contracts the dense Euclidean gradient G ∈ R^{n×n} (viewed as
/// [n1, n2, n1, n2]) against the other factor.
pub fn learned_kron_rotation(
    x: &Tensor,
    w: &Tensor,
    cfg: &CayleyConfig,
    seed: u64,
) -> Result<LearnedRotation> {
    let n = x.cols();
    let (n1, n2) = kron_factor(n);
    let y_ref = x.matmul(w);
    // SpinQuant-style initialization: a random-orthogonal ⊗ Hadamard start
    // (the published method optimizes from a random rotation, not from
    // identity — starting at identity leaves the STE optimizer stuck at
    // the unrotated loss plateau).
    let init = quarot_rotation(n, seed ^ 0x5147);
    let mut r1 = init.r1;
    let mut r2 = init.r2;
    let mut trace = CayleyTrace::default();
    let eye1 = Tensor::eye(n1);
    let eye2 = Tensor::eye(n2);

    for t in 0..cfg.steps {
        let lr = if cfg.decay {
            cfg.lr * (1.0 - t as f32 / cfg.steps as f32).max(0.02)
        } else {
            cfg.lr
        };
        // forward with STE quantizers
        let xr = kron_rotate_rows(x, &r1, &r2);
        let wr = kron_rotate_weight(w, &r1, &r2);
        let a = fake_quant_per_token(&xr, cfg.act_bits, 1.0);
        let bq = fake_quant_per_channel(&wr, cfg.weight_bits, 1.0);
        let e = a.matmul(&bq).sub(&y_ref);
        let loss = 0.5 * e.frob_norm().powi(2) / e.len() as f32;

        // dense Euclidean STE gradient wrt R_full = R1 ⊗ R2
        let g_full = x
            .matmul_tn(&e.matmul_nt(&bq))
            .add(&w.matmul(&a.matmul_tn(&e).transpose()))
            .scale(1.0 / e.len() as f32);

        // contract against the other factor:
        // G1[i,k] = Σ_{j,l} G[(i,j),(k,l)] R2[j,l] ; G2[j,l] = Σ_{i,k} G[(i,j),(k,l)] R1[i,k]
        let mut g1 = Tensor::zeros(&[n1, n1]);
        let mut g2 = Tensor::zeros(&[n2, n2]);
        for i in 0..n1 {
            for j in 0..n2 {
                let grow = g_full.row(i * n2 + j);
                for k in 0..n1 {
                    let mut acc1 = 0.0f32;
                    let r2row = &r2;
                    for l in 0..n2 {
                        let gv = grow[k * n2 + l];
                        acc1 += gv * r2row.at(j, l);
                        let v = g2.at(j, l) + gv * r1.at(i, k);
                        g2.set(j, l, v);
                    }
                    let v = g1.at(i, k) + acc1;
                    g1.set(i, k, v);
                }
            }
        }

        // Cayley step on each factor
        let step = |r: &Tensor, g: &Tensor, eye: &Tensor| -> Result<Tensor> {
            let grt = g.matmul_nt(r);
            let omega = grt.sub(&grt.transpose()).scale(0.5);
            let a_minus = eye.sub(&omega.scale(lr * 0.5));
            let a_plus = eye.add(&omega.scale(lr * 0.5));
            Ok(decomp::inverse(&a_minus)?.matmul(&a_plus).matmul(r))
        };
        let r1_new = step(&r1, &g1, &eye1)?;
        let r2_new = step(&r2, &g2, &eye2)?;
        let gn = (g1.frob_norm().powi(2) + g2.frob_norm().powi(2)).sqrt();
        let sn = (r1_new.sub(&r1).frob_norm().powi(2)
            + r2_new.sub(&r2).frob_norm().powi(2))
        .sqrt();
        trace.loss.push(loss);
        trace.grad_norm.push(gn);
        trace.step_norm.push(sn);
        r1 = r1_new;
        r2 = r2_new;
    }
    Ok(LearnedRotation { rotation: SiteRotation { r1, r2 }, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rel_error;

    fn spiked_x(t: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::randn(&[t, n], 1.0, &mut rng);
        for i in 0..t {
            x.row_mut(i)[2] *= 25.0;
        }
        x
    }

    #[test]
    fn smoothquant_scales_balance() {
        let s = smoothquant_scales(&[100.0, 1.0], &[1.0, 1.0], 0.5);
        assert!(s[0] > 5.0 && (s[1] - 1.0).abs() < 0.5);
    }

    #[test]
    fn quarot_orthogonal_and_smooths() {
        let rot = quarot_rotation(96, 7);
        assert!(rot.defect() < 1e-3);
        let x = spiked_x(32, 96, 1);
        let xr = kron_rotate_rows(&x, &rot.r1, &rot.r2);
        let e0 = rel_error(&x, &fake_quant_per_token(&x, 4, 1.0));
        let e1 = rel_error(&xr, &fake_quant_per_token(&xr, 4, 1.0));
        assert!(e1 < e0, "{e1} !< {e0}");
    }

    #[test]
    fn quip_orthogonal() {
        assert!(quip_rotation(64, 3).defect() < 1e-3);
    }

    #[test]
    fn duquant_orthogonal_and_permutation_valid() {
        let x = spiked_x(16, 96, 2);
        let prof = stats::col_signed_absmax(&x);
        let rot = duquant_rotation(&prof, 8, 5);
        assert!(rot.defect() < 1e-3, "defect {}", rot.defect());
    }

    #[test]
    fn learned_kron_improves_loss_and_stays_orthogonal() {
        let x = spiked_x(48, 24, 3);
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[24, 16], 0.5, &mut rng);
        let cfg = CayleyConfig { steps: 30, lr: 0.5, ..Default::default() };
        let res = learned_kron_rotation(&x, &w, &cfg, 1).unwrap();
        assert!(res.rotation.defect() < 1e-2, "defect {}", res.rotation.defect());
        let first = res.trace.loss[0];
        let best = res.trace.loss.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(best < first, "no improvement: best {best} first {first}");
    }
}
