//! Sylvester–Hadamard matrices and the fast Walsh–Hadamard transform.
//!
//! The `H` factor in Eq. 45 and the QuaRot baseline's global rotation.
//! Algorithm 1 guarantees the Kronecker n₂ factor is a power of two, so a
//! true Hadamard matrix always exists on that axis.

use crate::tensor::Tensor;

/// Normalized Sylvester-Hadamard matrix H_n/√n (n a power of two).
pub fn hadamard_matrix(n: usize) -> Tensor {
    assert!(n.is_power_of_two(), "hadamard dim {n} not a power of two");
    let mut m = Tensor::filled(&[n, n], 1.0);
    // H[i][j] = (-1)^{popcount(i & j)}
    for i in 0..n {
        for j in 0..n {
            if ((i & j).count_ones() & 1) == 1 {
                m.set(i, j, -1.0);
            }
        }
    }
    m.scale(1.0 / (n as f32).sqrt())
}

/// In-place normalized FWHT of a single row (O(n log n)).
pub fn fwht_row(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for x in v {
        *x *= norm;
    }
}

/// FWHT every row of a [T, n] matrix.
pub fn fwht_rows(x: &mut Tensor) {
    let t = x.rows();
    for i in 0..t {
        fwht_row(x.row_mut(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_is_orthogonal_and_symmetric() {
        for n in [2usize, 4, 16, 64] {
            let h = hadamard_matrix(n);
            assert!(h.orthogonality_defect() < 1e-5, "n={n}");
            assert!(h.sub(&h.transpose()).max_abs() < 1e-7, "n={n}");
        }
    }

    #[test]
    fn fwht_matches_matrix() {
        let mut rng = Rng::new(1);
        let n = 32;
        let h = hadamard_matrix(n);
        let v = rng.normal_vec(n, 1.0);
        let expect = Tensor::from_raw(vec![1, n], v.clone()).matmul(&h);
        let mut w = v;
        fwht_row(&mut w);
        for i in 0..n {
            assert!((w[i] - expect.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(16, 1.0);
        let mut w = v.clone();
        fwht_row(&mut w);
        fwht_row(&mut w);
        for i in 0..16 {
            assert!((w[i] - v[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn spike_spreads_flat() {
        // The outlier-smoothing property: a one-hot maps to constant |.|.
        let mut v = vec![0.0f32; 64];
        v[17] = 8.0;
        fwht_row(&mut v);
        for &x in &v {
            assert!((x.abs() - 1.0).abs() < 1e-5);
        }
    }
}
