//! Quantizers: RTN, GPTQ, AWQ-style scaling, clip search, int-packing.
//!
//! Semantics mirror `python/compile/kernels/ref.py` exactly (symmetric
//! absmax grids); the integration tests cross-check the two through the
//! PJRT runtime.

pub mod awq;
pub mod clip;
pub mod gptq;
pub mod pack;
pub mod repack;

use crate::tensor::Tensor;

/// Symmetric signed grid bounds for a bit-width (4 -> [-8, 7]).
pub fn qlevels(bits: u32) -> (f32, f32) {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let qmin = -((1i64 << (bits - 1)) as f32);
    (qmin, qmax)
}

#[inline]
fn quantize_val(x: f32, scale: f32, qmin: f32, qmax: f32) -> f32 {
    (x / scale).round().clamp(qmin, qmax) * scale
}

/// Per-token (row-wise) symmetric absmax fake quantization — the A4 side.
pub fn fake_quant_per_token(x: &Tensor, bits: u32, clip: f32) -> Tensor {
    let (qmin, qmax) = qlevels(bits);
    let (t, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[t, n]);
    for i in 0..t {
        let row = x.row(i);
        let absmax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = (absmax * clip / qmax).max(1e-8);
        for (j, &v) in row.iter().enumerate() {
            out.row_mut(i)[j] = quantize_val(v, scale, qmin, qmax);
        }
    }
    out
}

/// Per-output-channel (column-wise for [in, out] weights) RTN fake quant.
pub fn fake_quant_per_channel(w: &Tensor, bits: u32, clip: f32) -> Tensor {
    let (n, c) = (w.rows(), w.cols());
    let (qmin, qmax) = qlevels(bits);
    let mut scales = vec![0.0f32; c];
    for i in 0..n {
        for (j, &v) in w.row(i).iter().enumerate() {
            scales[j] = scales[j].max(v.abs());
        }
    }
    for s in &mut scales {
        *s = (*s * clip / qmax).max(1e-8);
    }
    let mut out = Tensor::zeros(&[n, c]);
    for i in 0..n {
        for j in 0..c {
            out.row_mut(i)[j] = quantize_val(w.at(i, j), scales[j], qmin, qmax);
        }
    }
    out
}

/// Per-tensor symmetric fake quant (coarsest scheme; used in ablations).
pub fn fake_quant_per_tensor(w: &Tensor, bits: u32, clip: f32) -> Tensor {
    let (qmin, qmax) = qlevels(bits);
    let scale = (w.max_abs() * clip / qmax).max(1e-8);
    w.map(|x| quantize_val(x, scale, qmin, qmax))
}

/// Grouped RTN along the input dimension (GPTQ-g128-style grouping): each
/// output channel's input dim is split into groups of `group` rows with an
/// independent scale.
pub fn fake_quant_grouped(w: &Tensor, bits: u32, group: usize, clip: f32) -> Tensor {
    let (n, c) = (w.rows(), w.cols());
    let (qmin, qmax) = qlevels(bits);
    let mut out = Tensor::zeros(&[n, c]);
    let mut g0 = 0;
    while g0 < n {
        let g1 = (g0 + group).min(n);
        // per-channel scale within the group
        let mut scales = vec![0.0f32; c];
        for i in g0..g1 {
            for (j, &v) in w.row(i).iter().enumerate() {
                scales[j] = scales[j].max(v.abs());
            }
        }
        for s in &mut scales {
            *s = (*s * clip / qmax).max(1e-8);
        }
        for i in g0..g1 {
            for j in 0..c {
                out.row_mut(i)[j] = quantize_val(w.at(i, j), scales[j], qmin, qmax);
            }
        }
        g0 = g1;
    }
    out
}

/// Relative quantization error ‖q − x‖_F / ‖x‖_F.
pub fn rel_error(x: &Tensor, q: &Tensor) -> f32 {
    q.sub(x).frob_norm() / x.frob_norm().max(1e-12)
}

/// Layer-output MSE between the reference X·W and a transformed pair
/// X'·W' (used by scale/clip searches where both sides change).
pub fn layer_mse_ctx(x: &Tensor, w: &Tensor, x_alt: &Tensor, w_alt: &Tensor) -> f32 {
    x.matmul(w).mse(&x_alt.matmul(w_alt))
}

/// Weight quantizer selector used across the experiment matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightQuantizer {
    /// Round-to-nearest, per output channel.
    Rtn,
    /// GPTQ with Hessian-ordered error compensation.
    Gptq,
    /// GPTQ with input-dim grouping (the `-g128` variants; group scaled to
    /// our layer sizes).
    GptqGrouped(usize),
    /// Grouped RTN (used by the weight-only table).
    RtnGrouped(usize),
}

impl WeightQuantizer {
    pub fn label(&self) -> String {
        match self {
            WeightQuantizer::Rtn => "RTN".into(),
            WeightQuantizer::Gptq => "GPTQ".into(),
            WeightQuantizer::GptqGrouped(g) => format!("GPTQ-g{g}"),
            WeightQuantizer::RtnGrouped(g) => format!("RTN-g{g}"),
        }
    }

    /// Input-dim scale-group size, `None` for per-channel quantizers.
    /// Threaded through the quantized package so the native engine packs
    /// grouped checkpoints on their exact grid.
    pub fn group(&self) -> Option<usize> {
        match self {
            WeightQuantizer::GptqGrouped(g) | WeightQuantizer::RtnGrouped(g) => Some(*g),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qlevels_match_paper() {
        assert_eq!(qlevels(4), (-8.0, 7.0));
        assert_eq!(qlevels(3), (-4.0, 3.0));
        assert_eq!(qlevels(8), (-128.0, 127.0));
    }

    #[test]
    fn per_token_on_grid() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[6, 20], 3.0, &mut rng);
        let q = fake_quant_per_token(&x, 4, 1.0);
        for i in 0..6 {
            let absmax = x.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = absmax / 7.0;
            for &v in q.row(i) {
                let k = v / scale;
                assert!((k - k.round()).abs() < 1e-3);
                assert!((-8.0..=7.0).contains(&k.round()));
            }
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[40, 30], 0.5, &mut rng);
        let e2 = rel_error(&w, &fake_quant_per_channel(&w, 2, 1.0));
        let e4 = rel_error(&w, &fake_quant_per_channel(&w, 4, 1.0));
        let e8 = rel_error(&w, &fake_quant_per_channel(&w, 8, 1.0));
        assert!(e2 > e4 && e4 > e8);
    }

    #[test]
    fn grouped_beats_per_channel_with_outlier_rows() {
        // A weight whose magnitude varies strongly along the input dim
        // benefits from input-dim grouping.
        let mut rng = Rng::new(3);
        let mut w = Tensor::randn(&[64, 16], 0.1, &mut rng);
        for j in 0..16 {
            let v = w.at(0, j);
            w.set(0, j, v * 50.0);
        }
        let eg = rel_error(&w, &fake_quant_grouped(&w, 4, 16, 1.0));
        let ec = rel_error(&w, &fake_quant_per_channel(&w, 4, 1.0));
        assert!(eg < ec, "grouped {eg} vs per-channel {ec}");
    }

    #[test]
    fn outliers_inflate_per_token_error() {
        // The paper's core premise: one massive channel wrecks per-token quant.
        let mut rng = Rng::new(4);
        let clean = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let mut spiked = clean.clone();
        for i in 0..16 {
            spiked.row_mut(i)[3] = 40.0;
        }
        let e_clean = rel_error(&clean, &fake_quant_per_token(&clean, 4, 1.0));
        let e_spec = {
            // error on the non-outlier part
            let q = fake_quant_per_token(&spiked, 4, 1.0);
            let mut num = 0.0f32;
            let mut den = 0.0f32;
            for i in 0..16 {
                for j in 0..64 {
                    if j != 3 {
                        num += (q.at(i, j) - spiked.at(i, j)).powi(2);
                        den += spiked.at(i, j).powi(2);
                    }
                }
            }
            (num / den).sqrt()
        };
        assert!(e_spec > 2.0 * e_clean, "spiked {e_spec} vs clean {e_clean}");
    }

    #[test]
    fn clip_below_one_shrinks_scale() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let q1 = fake_quant_per_token(&x, 4, 1.0);
        let q2 = fake_quant_per_token(&x, 4, 0.5);
        assert!(q2.max_abs() <= q1.max_abs() + 1e-6);
    }
}
