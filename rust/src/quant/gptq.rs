//! GPTQ (OPTQ): Hessian-guided weight quantization with error compensation.
//!
//! Frantar et al., ICLR 2023. For a linear layer y = xW with calibration
//! activations X, GPTQ quantizes W column-group by column-group along the
//! input dimension, propagating the rounding error of each input row into
//! the not-yet-quantized rows through the inverse-Hessian Cholesky factor.
//! This is the `GPTQ` weight quantizer of Tables 1/2/B.3; the paper's
//! SingleQuant rows use plain RTN, and the ablation shows RTN+rotations is
//! competitive with GPTQ-based baselines.

use anyhow::{ensure, Result};

use super::qlevels;
use crate::tensor::{decomp, Tensor};

pub struct GptqConfig {
    pub bits: u32,
    /// Input-dim group size for scale recomputation; `None` = one scale per
    /// output channel over the full input dim (classic per-channel).
    pub group: Option<usize>,
    /// Hessian dampening fraction of mean diagonal (1e-2 is the reference
    /// default).
    pub damp: f32,
    pub clip: f32,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { bits: 4, group: None, damp: 0.01, clip: 1.0 }
    }
}

/// Accumulated Hessian H = X^T X over calibration batches.
pub struct Hessian {
    pub h: Tensor,
    pub count: usize,
}

impl Hessian {
    pub fn new(n: usize) -> Hessian {
        Hessian { h: Tensor::zeros(&[n, n]), count: 0 }
    }

    pub fn update(&mut self, x: &Tensor) {
        assert_eq!(x.cols(), self.h.rows());
        self.h = self.h.add(&x.matmul_tn(x));
        self.count += x.rows();
    }
}

/// Quantize `w` ([in, out]) with GPTQ against Hessian `hess` (in-dim sized).
/// Returns the fake-quantized (dequantized f32) weight. In the rotated
/// pipeline, `hess.h` is the sandwiched Rᵀ H R from `kron_sandwich` — the
/// same in-dim basis the rotated weight lives in.
pub fn gptq_quantize(w: &Tensor, hess: &Hessian, cfg: &GptqConfig) -> Result<Tensor> {
    let n = w.rows(); // input dim
    let c = w.cols(); // output dim
    ensure!(
        hess.h.rows() == n && hess.h.cols() == n,
        "GPTQ Hessian shape {:?} does not match the weight input dim {n}",
        hess.h.shape()
    );
    let (qmin, qmax) = qlevels(cfg.bits);

    // Damped Hessian -> inverse -> upper Cholesky (the GPTQ "Hinv" factor).
    let mut h = hess.h.clone();
    let mean_diag: f32 = (0..n).map(|i| h.at(i, i)).sum::<f32>() / n as f32;
    let damp = (cfg.damp * mean_diag).max(1e-6);
    for i in 0..n {
        let v = h.at(i, i) + damp;
        h.set(i, i, v);
    }
    let hinv = decomp::spd_inverse(&h)?;
    let u = decomp::cholesky_upper(&hinv)?; // H^{-1} = U^T U, U upper

    // Work on Wt [C, n]: each row is one output channel across input dims.
    let mut wt = w.transpose();
    let mut q = Tensor::zeros(&[c, n]);

    let group = cfg.group.unwrap_or(n).max(1);
    let mut scales = vec![0.0f32; c];
    for j in 0..n {
        if j % group == 0 {
            // (Re)compute per-channel scales over this input group from the
            // *current* (error-compensated) weights.
            let hi = (j + group).min(n);
            for (ci, s) in scales.iter_mut().enumerate() {
                let mut absmax = 0.0f32;
                for k in j..hi {
                    absmax = absmax.max(wt.at(ci, k).abs());
                }
                *s = (absmax * cfg.clip / qmax).max(1e-8);
            }
        }
        let ujj = u.at(j, j).max(1e-8);
        for ci in 0..c {
            let wv = wt.at(ci, j);
            let qv = (wv / scales[ci]).round().clamp(qmin, qmax) * scales[ci];
            q.set(ci, j, qv);
            let err = (wv - qv) / ujj;
            // Propagate into not-yet-quantized columns.
            let urow = u.row(j);
            let wrow = wt.row_mut(ci);
            for k in (j + 1)..n {
                wrow[k] -= err * urow[k];
            }
        }
    }
    Ok(q.transpose())
}

/// Layer-output MSE proxy: ‖X W − X Wq‖²/len — the objective GPTQ minimizes.
pub fn layer_output_mse(x: &Tensor, w: &Tensor, wq: &Tensor) -> f32 {
    let y = x.matmul(w);
    let yq = x.matmul(wq);
    y.mse(&yq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_per_channel;
    use crate::util::rng::Rng;

    fn setup(n: usize, c: usize, t: usize, seed: u64) -> (Tensor, Tensor, Hessian) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[t, n], 1.0, &mut rng);
        let w = Tensor::randn(&[n, c], 0.5, &mut rng);
        let mut h = Hessian::new(n);
        h.update(&x);
        (x, w, h)
    }

    #[test]
    fn gptq_beats_rtn_on_layer_output() {
        let (x, w, h) = setup(32, 24, 128, 1);
        let q_rtn = fake_quant_per_channel(&w, 4, 1.0);
        let q_gptq = gptq_quantize(&w, &h, &GptqConfig::default()).unwrap();
        let e_rtn = layer_output_mse(&x, &w, &q_rtn);
        let e_gptq = layer_output_mse(&x, &w, &q_gptq);
        assert!(e_gptq < e_rtn, "gptq {e_gptq} vs rtn {e_rtn}");
    }

    #[test]
    fn gptq_outputs_finite_and_close() {
        let (_, w, h) = setup(16, 8, 64, 2);
        let q = gptq_quantize(&w, &h, &GptqConfig::default()).unwrap();
        assert!(q.data().iter().all(|v| v.is_finite()));
        assert!(crate::quant::rel_error(&w, &q) < 0.5);
    }

    #[test]
    fn grouped_gptq_runs() {
        let (x, w, h) = setup(32, 12, 96, 3);
        let cfg = GptqConfig { group: Some(8), ..Default::default() };
        let q = gptq_quantize(&w, &h, &cfg).unwrap();
        let e = layer_output_mse(&x, &w, &q);
        let e_rtn = layer_output_mse(&x, &w, &fake_quant_per_channel(&w, 4, 1.0));
        assert!(e < e_rtn);
    }

    #[test]
    fn high_bits_near_exact() {
        let (_, w, h) = setup(16, 8, 64, 4);
        let cfg = GptqConfig { bits: 8, ..Default::default() };
        let q = gptq_quantize(&w, &h, &cfg).unwrap();
        assert!(crate::quant::rel_error(&w, &q) < 0.02);
    }
}
