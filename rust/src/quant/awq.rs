//! AWQ-style activation-aware weight scaling (Lin et al., 2024).
//!
//! Searches a per-channel scale s = absmax(X)^α over a grid of α, picking
//! the one minimizing the quantized layer-output error; the scale is folded
//! as W ← diag(s) W with the inverse absorbed by the producer (norm gain /
//! `wu` columns), exactly like SmoothQuant's fold but optimized against the
//! weight quantizer instead of a fixed α. Used as the `AWQ` baseline in
//! Tables 4 and B.3.

use crate::quant::{fake_quant_per_channel, layer_mse_ctx};
use crate::tensor::Tensor;

pub struct AwqResult {
    /// Chosen per-input-channel scale (fold x ← x / s, W ← diag(s) W).
    pub scale: Vec<f32>,
    pub alpha: f32,
    pub err: f32,
}

/// Grid-search α over `steps` points in [0, 1].
pub fn awq_search(x_sample: &Tensor, w: &Tensor, bits: u32, steps: usize) -> AwqResult {
    let n = w.rows();
    assert_eq!(x_sample.cols(), n);
    let act_absmax = crate::tensor::stats::col_absmax(x_sample);

    let mut best = AwqResult { scale: vec![1.0; n], alpha: 0.0, err: f32::INFINITY };
    for k in 0..=steps {
        let alpha = k as f32 / steps as f32;
        let scale: Vec<f32> = act_absmax
            .iter()
            .map(|&a| a.max(1e-5).powf(alpha).max(1e-4))
            .collect();
        // scaled weight: diag(s) W ; scaled activations: X / s
        let mut ws = w.clone();
        for i in 0..n {
            let s = scale[i];
            for v in ws.row_mut(i) {
                *v *= s;
            }
        }
        let wq = fake_quant_per_channel(&ws, bits, 1.0);
        // y' = (X/s) (diag(s)W)_q ; compare against X W
        let mut xs = x_sample.clone();
        for r in 0..xs.rows() {
            for (j, v) in xs.row_mut(r).iter_mut().enumerate() {
                *v /= scale[j];
            }
        }
        let err = layer_mse_ctx(x_sample, w, &xs, &wq);
        if err < best.err {
            best = AwqResult { scale, alpha, err };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn awq_improves_over_alpha0_with_outliers() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&[64, 24], 1.0, &mut rng);
        for i in 0..64 {
            x.row_mut(i)[5] *= 30.0; // activation outlier channel
        }
        let w = Tensor::randn(&[24, 16], 0.5, &mut rng);
        let res = awq_search(&x, &w, 4, 10);
        assert!(res.alpha > 0.0, "expected nonzero alpha, got {}", res.alpha);
        assert!(res.err.is_finite());
    }

    #[test]
    fn scales_positive() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[32, 12], 1.0, &mut rng);
        let w = Tensor::randn(&[12, 8], 0.5, &mut rng);
        let res = awq_search(&x, &w, 4, 6);
        assert!(res.scale.iter().all(|&s| s > 0.0));
    }
}
