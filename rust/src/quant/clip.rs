//! Clipping-threshold search.
//!
//! Two searches, both 1-D grid over the clip ratio:
//!
//! * [`search_weight_clip`] — minimize weight-quantization MSE (used inside
//!   the weight pipeline for the harder W3 settings).
//! * [`search_act_clip`]    — FlatQuant-style Learnable Clipping Threshold
//!   (LCT): minimize the *layer-output* error of per-token activation
//!   quantization on a calibration sample. The chosen ratio feeds the
//!   `clip_<site>` runtime parameter of the quantized graphs (Table 5's
//!   "w/ LCT" rows).

use crate::quant::{fake_quant_per_channel, fake_quant_per_token};
use crate::tensor::Tensor;

/// Best weight clip ratio in [lo, 1.0] by quantization MSE.
pub fn search_weight_clip(w: &Tensor, bits: u32, steps: usize, lo: f32) -> f32 {
    let mut best = (1.0f32, f32::INFINITY);
    for k in 0..=steps {
        let clip = lo + (1.0 - lo) * k as f32 / steps as f32;
        let q = fake_quant_per_channel(w, bits, clip);
        let err = q.sub(w).frob_norm();
        if err < best.1 {
            best = (clip, err);
        }
    }
    best.0
}

/// Best activation clip ratio in [lo, 1.0] by layer-output MSE on a sample.
pub fn search_act_clip(x_sample: &Tensor, w: &Tensor, bits: u32, steps: usize,
                       lo: f32) -> f32 {
    let y_ref = x_sample.matmul(w);
    let mut best = (1.0f32, f32::INFINITY);
    for k in 0..=steps {
        let clip = lo + (1.0 - lo) * k as f32 / steps as f32;
        let xq = fake_quant_per_token(x_sample, bits, clip);
        let err = xq.matmul(w).mse(&y_ref);
        if err < best.1 {
            best = (clip, err);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn weight_clip_in_range() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[32, 16], 0.5, &mut rng);
        let c = search_weight_clip(&w, 3, 10, 0.5);
        assert!((0.5..=1.0).contains(&c));
    }

    #[test]
    fn act_clip_returns_grid_optimum() {
        // Heavy log-normal tails: the chosen clip must be at least as good
        // as no clipping under the layer-output objective.
        let mut rng = Rng::new(2);
        let mut x = Tensor::randn(&[48, 32], 1.0, &mut rng);
        for v in x.data_mut() {
            *v = v.signum() * (v.abs() * 2.0).exp();
        }
        let w = Tensor::randn(&[32, 16], 0.5, &mut rng);
        let c = search_act_clip(&x, &w, 4, 20, 0.05);
        assert!((0.05..=1.0).contains(&c));
        let y_ref = x.matmul(&w);
        let err_c = fake_quant_per_token(&x, 4, c).matmul(&w).mse(&y_ref);
        let err_1 = fake_quant_per_token(&x, 4, 1.0).matmul(&w).mse(&y_ref);
        assert!(err_c <= err_1 + 1e-9, "chosen {c}: {err_c} > {err_1}");
    }

    #[test]
    fn act_clip_no_outliers_stays_high() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[48, 32], 1.0, &mut rng);
        let w = Tensor::randn(&[32, 16], 0.5, &mut rng);
        let c = search_act_clip(&x, &w, 4, 20, 0.05);
        assert!(c > 0.6, "unexpected aggressive clip {c}");
    }
}
