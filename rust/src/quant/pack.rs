//! Bit-packed integer weight storage (the runtime memory format).
//!
//! Backs the Table 8 memory measurements: quantized checkpoints store
//! int4/int3 codes packed into bytes plus per-channel f32 scales, and the
//! runtime dequantizes once at load. `nbytes()` is the exact serialized
//! footprint used in the memory accounting.

use anyhow::{bail, Result};

use super::qlevels;
use crate::tensor::Tensor;

/// A [in, out] weight stored as packed signed ints + per-channel scales.
#[derive(Clone, Debug)]
pub struct PackedWeight {
    pub bits: u32,
    pub rows: usize,
    pub cols: usize,
    /// Per-output-channel scale.
    pub scales: Vec<f32>,
    /// Row-major codes, bit-packed little-endian within bytes.
    pub codes: Vec<u8>,
}

impl PackedWeight {
    /// Quantize (per output channel, symmetric) and pack.
    pub fn pack(w: &Tensor, bits: u32) -> Result<PackedWeight> {
        if !(2..=8).contains(&bits) {
            bail!("pack: bits {bits} out of range");
        }
        let (n, c) = (w.rows(), w.cols());
        let (qmin, qmax) = qlevels(bits);
        let mut scales = vec![0.0f32; c];
        for i in 0..n {
            for (j, &v) in w.row(i).iter().enumerate() {
                scales[j] = scales[j].max(v.abs());
            }
        }
        for s in &mut scales {
            *s = (*s / qmax).max(1e-8);
        }
        let total_bits = n * c * bits as usize;
        let mut codes = vec![0u8; total_bits.div_ceil(8)];
        let offset = -qmin as i32; // store unsigned biased codes
        let mut bitpos = 0usize;
        for i in 0..n {
            for j in 0..c {
                let q = (w.at(i, j) / scales[j]).round().clamp(qmin, qmax) as i32;
                let u = (q + offset) as u32;
                write_bits(&mut codes, bitpos, bits, u);
                bitpos += bits as usize;
            }
        }
        Ok(PackedWeight { bits, rows: n, cols: c, scales, codes })
    }

    /// Dequantize back to f32 (value-identical to `fake_quant_per_channel`).
    pub fn unpack(&self) -> Tensor {
        let (qmin, _) = qlevels(self.bits);
        let offset = -qmin as i32;
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let mut bitpos = 0usize;
        for i in 0..self.rows {
            for j in 0..self.cols {
                let u = read_bits(&self.codes, bitpos, self.bits) as i32;
                bitpos += self.bits as usize;
                out.set(i, j, (u - offset) as f32 * self.scales[j]);
            }
        }
        out
    }

    /// Signed code at (row i, col j) — read path for the kernel repack
    /// (`quant::repack::RepackedWeight::from_packed`).
    pub fn code_at(&self, i: usize, j: usize) -> i32 {
        let (qmin, _) = qlevels(self.bits);
        let bitpos = (i * self.cols + j) * self.bits as usize;
        read_bits(&self.codes, bitpos, self.bits) as i32 + qmin as i32
    }

    /// Serialized footprint in bytes (codes + scales + header).
    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4 + 16
    }
}

fn write_bits(buf: &mut [u8], bitpos: usize, bits: u32, val: u32) {
    for b in 0..bits as usize {
        if (val >> b) & 1 == 1 {
            let p = bitpos + b;
            buf[p / 8] |= 1 << (p % 8);
        }
    }
}

fn read_bits(buf: &[u8], bitpos: usize, bits: u32) -> u32 {
    let mut val = 0u32;
    for b in 0..bits as usize {
        let p = bitpos + b;
        if (buf[p / 8] >> (p % 8)) & 1 == 1 {
            val |= 1 << b;
        }
    }
    val
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_per_channel;
    use crate::util::rng::Rng;

    #[test]
    fn pack_roundtrip_matches_fake_quant() {
        let mut rng = Rng::new(1);
        for bits in [3u32, 4, 8] {
            let w = Tensor::randn(&[17, 9], 0.7, &mut rng);
            let packed = PackedWeight::pack(&w, bits).unwrap();
            let deq = packed.unpack();
            let reference = fake_quant_per_channel(&w, bits, 1.0);
            assert!(deq.sub(&reference).max_abs() < 1e-5,
                    "bits {bits}: {}", deq.sub(&reference).max_abs());
        }
    }

    #[test]
    fn int4_is_quarter_of_f32() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[256, 128], 0.5, &mut rng);
        let packed = PackedWeight::pack(&w, 4).unwrap();
        let f32_bytes = 256 * 128 * 4;
        let ratio = f32_bytes as f64 / packed.nbytes() as f64;
        assert!(ratio > 7.0 && ratio < 8.5, "ratio {ratio}"); // ≈8× minus scales
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut buf = vec![0u8; 8];
        write_bits(&mut buf, 5, 4, 0b1011);
        write_bits(&mut buf, 9, 3, 0b101);
        assert_eq!(read_bits(&buf, 5, 4), 0b1011);
        assert_eq!(read_bits(&buf, 9, 3), 0b101);
    }

    #[test]
    fn rejects_bad_bits() {
        let w = Tensor::zeros(&[2, 2]);
        assert!(PackedWeight::pack(&w, 1).is_err());
        assert!(PackedWeight::pack(&w, 9).is_err());
    }
}
