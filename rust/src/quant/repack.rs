//! Kernel-friendly repack of [`PackedWeight`]: the storage format the
//! native CPU matmul executes on directly.
//!
//! [`PackedWeight`] is the *serialized* format: row-major bit-packed codes,
//! optimized for footprint accounting. A dot-product kernel wants the
//! opposite layout — codes **column-major** (one output channel's input dim
//! contiguous), int4 pairs nibble-interleaved in a single byte, and scales
//! grouped along the input dimension so the scale multiply hoists out of
//! the inner loop. [`RepackedWeight`] is that layout; `tensor::kernels::
//! matmul_packed` consumes it with dequantization fused into the k-loop.

use anyhow::{bail, Result};

use super::pack::PackedWeight;
use super::qlevels;
use crate::tensor::Tensor;

/// A `[in, out]` weight stored column-major as signed codes + group scales.
#[derive(Clone, Debug)]
pub struct RepackedWeight {
    pub bits: u32,
    /// Input dimension (k of the matmul).
    pub rows: usize,
    /// Output dimension (columns of the matmul result).
    pub cols: usize,
    /// Scale-group length along the input dimension (`rows` when the
    /// source was per-output-channel quantized).
    pub group: usize,
    /// ceil(rows / group) scale groups per column.
    pub n_groups: usize,
    /// `scales[c * n_groups + g]` — per (column, input-group) scale.
    pub scales: Vec<f32>,
    /// Column-major codes. bits ≤ 4: two codes per byte, nibble-interleaved
    /// (row k even → low nibble of byte k/2, odd → high nibble). bits 5..8:
    /// one sign-extended byte per code. Every column's stride is padded
    /// with zero bytes to a multiple of [`COL_ALIGN`] so vector kernels
    /// may issue full 8-byte loads anywhere in a column without reading
    /// past the buffer.
    pub codes: Vec<u8>,
    /// Bytes per column in `codes` (padded, see `codes`).
    col_stride: usize,
    /// Bias added when storing codes unsigned in nibbles.
    offset: i32,
}

/// Column-stride alignment in bytes: guarantees the SIMD kernels' full
/// 8-byte (and narrower u32) loads are in-bounds at any in-column code
/// offset, and keeps column bases 8-byte separated.
const COL_ALIGN: usize = 8;

impl RepackedWeight {
    fn layout(bits: u32, rows: usize, group: usize) -> Result<(usize, usize, i32)> {
        if !(2..=8).contains(&bits) {
            bail!("repack: bits {bits} out of range");
        }
        if group == 0 {
            bail!("repack: zero group");
        }
        let n_groups = rows.div_ceil(group);
        let used = if bits <= 4 { rows.div_ceil(2) } else { rows };
        let col_stride = used.next_multiple_of(COL_ALIGN);
        let (qmin, _) = qlevels(bits);
        Ok((n_groups, col_stride, -qmin as i32))
    }

    /// Repack a serialized [`PackedWeight`] (per-output-channel scales, so
    /// one scale group spanning the whole input dim).
    pub fn from_packed(p: &PackedWeight) -> Result<RepackedWeight> {
        let (n_groups, col_stride, offset) = Self::layout(p.bits, p.rows, p.rows)?;
        let mut out = RepackedWeight {
            bits: p.bits,
            rows: p.rows,
            cols: p.cols,
            group: p.rows,
            n_groups,
            scales: Vec::with_capacity(p.cols * n_groups),
            codes: vec![0u8; p.cols * col_stride],
            col_stride,
            offset,
        };
        for &s in &p.scales {
            out.scales.push(s);
        }
        for k in 0..p.rows {
            for c in 0..p.cols {
                out.store(k, c, p.code_at(k, c));
            }
        }
        Ok(out)
    }

    /// Quantize a dense weight directly with input-dim scale groups of
    /// `group` rows (`fake_quant_grouped` semantics; `group >= rows` is
    /// plain per-output-channel).
    pub fn pack(w: &Tensor, bits: u32, group: usize) -> Result<RepackedWeight> {
        let (n, c) = (w.rows(), w.cols());
        let group = group.min(n).max(1);
        let (n_groups, col_stride, offset) = Self::layout(bits, n, group)?;
        let (qmin, qmax) = qlevels(bits);
        let mut out = RepackedWeight {
            bits,
            rows: n,
            cols: c,
            group,
            n_groups,
            scales: vec![0.0f32; c * n_groups],
            codes: vec![0u8; c * col_stride],
            col_stride,
            offset,
        };
        for g in 0..n_groups {
            let (k0, k1) = (g * group, ((g + 1) * group).min(n));
            let mut absmax = vec![0.0f32; c];
            for k in k0..k1 {
                for (j, &v) in w.row(k).iter().enumerate() {
                    absmax[j] = absmax[j].max(v.abs());
                }
            }
            for (j, &m) in absmax.iter().enumerate() {
                out.scales[j * n_groups + g] = (m / qmax).max(1e-8);
            }
            for k in k0..k1 {
                for j in 0..c {
                    let s = out.scales[j * n_groups + g];
                    let q = (w.at(k, j) / s).round().clamp(qmin, qmax) as i32;
                    out.store(k, j, q);
                }
            }
        }
        Ok(out)
    }

    #[inline]
    fn store(&mut self, k: usize, c: usize, signed: i32) {
        if self.bits <= 4 {
            let u = (signed + self.offset) as u8; // 0..2^bits-1, fits a nibble
            let byte = &mut self.codes[c * self.col_stride + k / 2];
            if k % 2 == 0 {
                *byte = (*byte & 0xF0) | (u & 0x0F);
            } else {
                *byte = (*byte & 0x0F) | (u << 4);
            }
        } else {
            self.codes[c * self.col_stride + k] = signed as i8 as u8;
        }
    }

    /// Signed code at (input row k, output column c) — test/kernel helper.
    #[inline]
    pub fn code_at(&self, k: usize, c: usize) -> i32 {
        if self.bits <= 4 {
            let byte = self.codes[c * self.col_stride + k / 2];
            let u = if k % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            u as i32 - self.offset
        } else {
            self.codes[c * self.col_stride + k] as i8 as i32
        }
    }

    /// One column's code bytes (contiguous along the input dim).
    #[inline]
    pub fn col_codes(&self, c: usize) -> &[u8] {
        &self.codes[c * self.col_stride..(c + 1) * self.col_stride]
    }

    /// Unsigned-nibble bias (bits ≤ 4 layout).
    #[inline]
    pub fn nibble_offset(&self) -> i32 {
        self.offset
    }

    /// Scales of one column, one per input group.
    #[inline]
    pub fn col_scales(&self, c: usize) -> &[f32] {
        &self.scales[c * self.n_groups..(c + 1) * self.n_groups]
    }

    /// Dense f32 form (reference for the fused kernel).
    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        for k in 0..self.rows {
            let g = k / self.group;
            for c in 0..self.cols {
                let s = self.scales[c * self.n_groups + g];
                out.set(k, c, self.code_at(k, c) as f32 * s);
            }
        }
        out
    }

    /// Resident footprint in bytes (codes + scales + header).
    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4 + 24
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant_grouped, fake_quant_per_channel};
    use crate::util::rng::Rng;

    #[test]
    fn from_packed_preserves_values() {
        let mut rng = Rng::new(1);
        for bits in [2u32, 3, 4, 5, 8] {
            let w = Tensor::randn(&[19, 7], 0.8, &mut rng);
            let p = PackedWeight::pack(&w, bits).unwrap();
            let r = RepackedWeight::from_packed(&p).unwrap();
            let a = p.unpack();
            let b = r.dequantize();
            assert!(a.sub(&b).max_abs() < 1e-6, "bits {bits}");
        }
    }

    #[test]
    fn direct_pack_matches_fake_quant_grouped() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[33, 11], 0.6, &mut rng);
        for (bits, group) in [(4u32, 8usize), (3, 16), (8, 33)] {
            let r = RepackedWeight::pack(&w, bits, group).unwrap();
            let reference = fake_quant_grouped(&w, bits, group, 1.0);
            assert!(r.dequantize().sub(&reference).max_abs() < 1e-5,
                    "bits {bits} group {group}");
        }
    }

    #[test]
    fn whole_column_group_matches_per_channel() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[17, 5], 0.5, &mut rng);
        let r = RepackedWeight::pack(&w, 4, 17).unwrap();
        assert_eq!(r.n_groups, 1);
        let reference = fake_quant_per_channel(&w, 4, 1.0);
        assert!(r.dequantize().sub(&reference).max_abs() < 1e-5);
    }

    #[test]
    fn int4_columns_pack_two_codes_per_byte() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[10, 4], 0.5, &mut rng);
        // 10 int4 rows use 5 bytes, padded to the 8-byte column stride
        let r = RepackedWeight::pack(&w, 4, 10).unwrap();
        assert_eq!(r.col_codes(0).len(), 8);
        // int8 stays one byte per code: 10 used, padded to 16
        let r8 = RepackedWeight::pack(&w, 8, 10).unwrap();
        assert_eq!(r8.col_codes(0).len(), 16);
    }

    #[test]
    fn column_padding_is_zero_and_codes_are_untouched() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[11, 3], 0.5, &mut rng);
        for bits in [4u32, 8] {
            let r = RepackedWeight::pack(&w, bits, 11).unwrap();
            let used = if bits <= 4 { 11usize.div_ceil(2) } else { 11 };
            for c in 0..3 {
                let col = r.col_codes(c);
                assert_eq!(col.len() % 8, 0, "bits {bits}: unaligned stride");
                assert!(col[used..].iter().all(|&b| b == 0),
                        "bits {bits} col {c}: dirty padding");
            }
            // padding must not perturb decode
            let dq = r.dequantize();
            for k in 0..11 {
                for c in 0..3 {
                    let s = r.col_scales(c)[0];
                    assert!((r.code_at(k, c) as f32 * s - dq.at(k, c)).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn odd_row_count_roundtrips() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&[7, 3], 1.0, &mut rng);
        let r = RepackedWeight::pack(&w, 4, 4).unwrap();
        for k in 0..7 {
            for c in 0..3 {
                let g = k / 4;
                let s = r.col_scales(c)[g];
                let got = r.code_at(k, c) as f32 * s;
                assert!((got - r.dequantize().at(k, c)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn rejects_bad_bits() {
        let w = Tensor::zeros(&[2, 2]);
        assert!(RepackedWeight::pack(&w, 1, 2).is_err());
        assert!(RepackedWeight::pack(&w, 9, 2).is_err());
    }
}
