//! Outlier geometry analyses (Fig. 1b): massive/normal outlier detection
//! on calibrated activations and the quantization-space-utilization gain
//! from each rotation construction.

use crate::calib::Calibration;
use crate::rotation::kronecker::kron_rotate_rows;
use crate::rotation::singlequant::SiteRotation;
use crate::tensor::{stats, Tensor};

/// Per-site outlier summary.
#[derive(Clone, Debug)]
pub struct OutlierStats {
    pub site: String,
    /// max |x| / median |x| over channels — MO prominence.
    pub mo_ratio: f32,
    /// Count of channels whose absmax exceeds 8x the channel median absmax.
    pub mo_channels: usize,
    /// Excess kurtosis of the flattened sample.
    pub kurtosis: f32,
    /// Fig. 1b metric before any rotation.
    pub utilization: f32,
}

pub fn site_outlier_stats(cal: &Calibration, key: &str) -> OutlierStats {
    let sc = &cal.sites[key];
    let absmax = sc.absmax();
    let mut sorted = absmax.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2].max(1e-9);
    let maxv = sorted.last().cloned().unwrap_or(0.0);
    let mo_channels = absmax.iter().filter(|&&v| v > 8.0 * median).count();
    OutlierStats {
        site: key.to_string(),
        mo_ratio: maxv / median,
        mo_channels,
        kurtosis: stats::kurtosis(sc.sample.data()),
        utilization: stats::quant_space_utilization(sc.sample.data()),
    }
}

/// Utilization of a site sample after applying a rotation.
pub fn utilization_after(sample: &Tensor, rot: &SiteRotation) -> f32 {
    let rotated = kron_rotate_rows(sample, &rot.r1, &rot.r2);
    stats::quant_space_utilization(rotated.data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rotation::singlequant::{build_site_rotation, SingleQuantConfig, SiteProfile};
    use crate::util::rng::Rng;

    #[test]
    fn rotation_improves_utilization_on_spiked_sample() {
        let mut rng = Rng::new(1);
        let n = 64;
        let mut x = Tensor::randn(&[128, n], 1.0, &mut rng);
        for i in 0..128 {
            // a single massive channel: rare enough that the p99-based
            // utilization metric sees the bulk, not the spike
            x.row_mut(i)[9] = if i % 4 == 0 { 30.0 } else { 8.0 };
        }
        let before = stats::quant_space_utilization(x.data());
        let profile = SiteProfile {
            n,
            signed_absmax: stats::col_signed_absmax(&x),
            median: stats::col_median(&x),
        };
        let rot = build_site_rotation(&profile, &SingleQuantConfig::default());
        let after = utilization_after(&x, &rot);
        assert!(after > 2.0 * before, "{after} vs {before}");
    }
}
