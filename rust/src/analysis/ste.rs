//! The §3.2 experiment: run SpinQuant-style Cayley SGD + STE on real
//! calibration activations and show the Prop. 1/2 signature — persistent
//! loss oscillation and a non-vanishing gradient/update floor — including
//! at 10× the prescribed iteration count (Fig. 2) and across models
//! (Fig. B.1). SingleQuant's closed-form construction is the control: its
//! "trace" is a single deterministic evaluation.

use anyhow::Result;

use crate::calib::Calibration;
use crate::model::ModelConfig;
use crate::rotation::cayley::{cayley_sgd, oscillation_score, CayleyConfig, CayleyTrace};
use crate::tensor::Tensor;

pub struct SteReport {
    pub site: String,
    pub steps: usize,
    pub trace: CayleyTrace,
    /// Mean |Δloss| / mean loss over the trace tail.
    pub loss_oscillation: f32,
    /// Tail-minimum gradient norm (Prop. 2's non-vanishing floor).
    pub grad_floor: f32,
    /// Tail-minimum per-step displacement ‖R_{t+1} − R_t‖_F.
    pub step_floor: f32,
}

/// Run the Cayley+STE study on one calibration site.
pub fn ste_study_site(
    x_sample: &Tensor,
    w: &Tensor,
    steps: usize,
    site: &str,
) -> Result<SteReport> {
    let cfg = CayleyConfig { steps, ..Default::default() };
    let res = cayley_sgd(x_sample, w, &cfg)?;
    let tail = steps / 2;
    let grad_floor = res.trace.grad_norm[tail..]
        .iter()
        .cloned()
        .fold(f32::INFINITY, f32::min);
    let step_floor = res.trace.step_norm[tail..]
        .iter()
        .cloned()
        .fold(f32::INFINITY, f32::min);
    Ok(SteReport {
        site: site.to_string(),
        steps,
        loss_oscillation: oscillation_score(&res.trace.loss),
        grad_floor,
        step_floor,
        trace: res.trace,
    })
}

/// Study the first layer's qkv site of a calibrated model (the figure's
/// representative site) at both the prescribed and 10× step counts.
pub fn ste_study(
    cfg: &ModelConfig,
    calibration: &Calibration,
    weights: &crate::model::Weights,
    base_steps: usize,
) -> Result<Vec<SteReport>> {
    let sc = calibration.site(0, "qkv");
    let p = "l00";
    let wq = weights.get(&format!("{p}.wq"))?;
    let mut out = Vec::new();
    for steps in [base_steps, base_steps * 10] {
        out.push(ste_study_site(&sc.sample, wq, steps, &format!("{}.l00.qkv", cfg.name))?);
    }
    Ok(out)
}

/// Render a sparkline of a trace for terminal figures.
pub fn sparkline(values: &[f32], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-12);
    let stride = (values.len() as f32 / width as f32).max(1.0);
    let mut out = String::new();
    let mut i = 0.0f32;
    while (i as usize) < values.len() && out.chars().count() < width {
        let v = values[i as usize];
        let k = (((v - lo) / span) * 7.0).round() as usize;
        out.push(BARS[k.min(7)]);
        i += stride;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn study_detects_oscillation_on_outlier_site() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&[96, 16], 1.0, &mut rng);
        for i in 0..96 {
            x.row_mut(i)[3] *= 25.0;
        }
        let w = Tensor::randn(&[16, 12], 0.5, &mut rng);
        let rep = ste_study_site(&x, &w, 40, "test").unwrap();
        assert!(rep.grad_floor > 0.0, "grad floor {}", rep.grad_floor);
        assert!(rep.step_floor > 0.0);
        assert_eq!(rep.trace.loss.len(), 40);
    }

    #[test]
    fn sparkline_renders() {
        let v: Vec<f32> = (0..100).map(|i| (i as f32 * 0.3).sin()).collect();
        let s = sparkline(&v, 40);
        assert!(s.chars().count() <= 40 && !s.is_empty());
    }
}
