//! Analyses behind the paper's motivating figures: the STE/Cayley
//! instability study (§3.2, Fig. 2/B.1) and the outlier / quantization-
//! space-utilization geometry (Fig. 1b).

pub mod outliers;
pub mod ste;
