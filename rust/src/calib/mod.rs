//! Calibration: the **single pass** of SingleQuant's title.
//!
//! Runs the Rust reference forward over a handful of calibration sequences
//! and records, per rotation site:
//!
//! * the per-channel signed absmax (ART's massive-outlier profile),
//! * a token reservoir sample (URT medians, clip search, learned-rotation
//!   baselines, quant-error analyses),
//! * the Hessian Xᵀ X (GPTQ).
//!
//! One forward pass feeds every method — closed-form and learned alike — so
//! the Table-7 quantization-time comparison isolates the *transform
//! construction* cost, exactly the paper's framing.
//!
//! Parallelism: sequences are independent forwards, so they fan out over
//! the [`WorkerPool`]; everything order-sensitive (signed-absmax merge,
//! Hessian addition, reservoir RNG draws) happens in a serial reduction
//! that replays tap events in fixed sequence order. The result is
//! bit-identical to the old serial loop for every lane count — see
//! DESIGN.md "Quantization pipeline parallelism".

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::forward::{forward_score, Tap};
use crate::model::{ModelConfig, Weights};
use crate::tensor::pool::{self, WorkerPool};
use crate::tensor::{stats, Tensor};
use crate::util::rng::Rng;

/// Per-site calibration summary.
#[derive(Clone, Debug)]
pub struct SiteCalib {
    pub n: usize,
    pub signed_absmax: Vec<f32>,
    /// Token reservoir, [S, n] with S <= max_sample (materialized from
    /// `rows` once the pass completes).
    pub sample: Tensor,
    rows: Vec<Vec<f32>>,
    /// Accumulated Xᵀ X (only when the weight quantizer needs it — GPTQ;
    /// skipping it is a large fraction of the single-pass cost).
    pub hessian: Tensor,
    pub token_count: usize,
}

impl SiteCalib {
    fn new(n: usize, with_hessian: bool) -> SiteCalib {
        SiteCalib {
            n,
            signed_absmax: vec![0.0; n],
            sample: Tensor::zeros(&[0, n]),
            rows: Vec::new(),
            hessian: if with_hessian {
                Tensor::zeros(&[n, n])
            } else {
                Tensor::zeros(&[0, 0])
            },
            token_count: 0,
        }
    }

    /// Per-channel median over the reservoir (URT's NO profile).
    pub fn median(&self) -> Vec<f32> {
        if self.sample.rows() == 0 {
            return vec![0.0; self.n];
        }
        stats::col_median(&self.sample)
    }

    pub fn absmax(&self) -> Vec<f32> {
        self.signed_absmax.iter().map(|x| x.abs()).collect()
    }
}

/// Full calibration result keyed by `l{i:02}.{site}`.
pub struct Calibration {
    pub sites: BTreeMap<String, SiteCalib>,
    pub n_sequences: usize,
    pub n_tokens: usize,
}

impl Calibration {
    pub fn site(&self, layer: usize, site: &str) -> &SiteCalib {
        &self.sites[&format!("l{layer:02}.{site}")]
    }
}

/// Reservoir row-sampling cap per site.
pub const MAX_SAMPLE: usize = 192;

/// Run the calibration pass over `seqs` (token id sequences).
pub fn run_calibration(
    cfg: &ModelConfig,
    weights: &Weights,
    seqs: &[Vec<u16>],
    seed: u64,
) -> Result<Calibration> {
    run_calibration_opts(cfg, weights, seqs, seed, true)
}

/// Calibration with explicit control over Hessian accumulation (the
/// Xᵀ X products are only consumed by GPTQ and dominate the tap cost).
/// Fans the sequences out over the process-wide worker pool.
pub fn run_calibration_opts(
    cfg: &ModelConfig,
    weights: &Weights,
    seqs: &[Vec<u16>],
    seed: u64,
    with_hessian: bool,
) -> Result<Calibration> {
    run_calibration_pool(cfg, weights, seqs, seed, with_hessian, pool::global())
}

/// One tap firing captured during a calibration forward: the site key
/// plus everything the fixed-order reduction needs — the raw rows (for
/// the reservoir), the per-sequence Gram partial Xᵀ X, and the
/// per-sequence signed-absmax partial.
struct TapEvent {
    key: String,
    x: Tensor,
    gram: Tensor,
    absmax: Vec<f32>,
}

/// The ordered tap-event trace of one calibration sequence.
struct SeqTrace {
    n_tokens: usize,
    events: Vec<TapEvent>,
}

/// Forward one sequence and record its tap events in firing order. Pure
/// function of its inputs — safe to run on any pool lane.
fn trace_sequence(
    cfg: &ModelConfig,
    weights: &Weights,
    seq: &[u16],
    with_hessian: bool,
) -> Result<SeqTrace> {
    let mut events: Vec<TapEvent> = Vec::new();
    let mut tap = |layer: usize, site: &str, x: &Tensor| {
        let mut absmax = vec![0.0f32; x.cols()];
        for i in 0..x.rows() {
            for (j, &v) in x.row(i).iter().enumerate() {
                if v.abs() > absmax[j].abs() {
                    absmax[j] = v;
                }
            }
        }
        let gram = if with_hessian { x.matmul_tn(x) } else { Tensor::zeros(&[0, 0]) };
        events.push(TapEvent {
            key: format!("l{layer:02}.{site}"),
            x: x.clone(),
            gram,
            absmax,
        });
    };
    forward_score(cfg, weights, seq, None, Some(&mut tap as Tap))?;
    Ok(SeqTrace { n_tokens: seq.len(), events })
}

/// Calibration on an explicit pool. Phase 1 traces every sequence in
/// parallel (forwards are independent); phase 2 reduces the traces
/// serially in sequence order, replaying each accumulation in exactly
/// the order the old serial loop performed it:
///
/// * **signed absmax** — the strict-`>` keep-first-max merge of a
///   per-sequence partial equals the row-by-row serial scan;
/// * **Hessian** — each site taps exactly once per sequence (the MoE
///   down-tap is deduplicated in `forward_score`), so adding the
///   per-sequence Gram partials in sequence order reproduces the serial
///   f32 association `((H₀+G₁)+G₂)+…` bit-for-bit;
/// * **reservoir** — the shared RNG's draws interleave across sites in
///   global tap-event order, so the reduction replays rows through the
///   same `below(token_count)` stream the serial loop consumed.
///
/// Hence the result is bit-identical for every lane count.
pub fn run_calibration_pool(
    cfg: &ModelConfig,
    weights: &Weights,
    seqs: &[Vec<u16>],
    seed: u64,
    with_hessian: bool,
    pool: &WorkerPool,
) -> Result<Calibration> {
    let mut sites: BTreeMap<String, SiteCalib> = BTreeMap::new();
    for layer in 0..cfg.n_layers {
        for site in crate::model::config::ROT_SITES {
            let (n, _, _) = cfg.site_dims(site);
            sites.insert(format!("l{layer:02}.{site}"),
                         SiteCalib::new(n, with_hessian));
        }
    }
    // ---- parallel phase: independent per-sequence forwards -------------
    let traces = pool.run_collect(seqs.len(), |i| {
        trace_sequence(cfg, weights, &seqs[i], with_hessian)
    });
    // ---- serial reduction in fixed sequence order ----------------------
    let mut rng = Rng::new(seed);
    let mut n_tokens = 0usize;
    for trace in traces {
        let trace = trace?;
        n_tokens += trace.n_tokens;
        for ev in &trace.events {
            let sc = sites
                .get_mut(&ev.key)
                .ok_or_else(|| anyhow!("calibration tap hit unknown site {}", ev.key))?;
            for (j, &v) in ev.absmax.iter().enumerate() {
                if v.abs() > sc.signed_absmax[j].abs() {
                    sc.signed_absmax[j] = v;
                }
            }
            if with_hessian {
                sc.hessian = sc.hessian.add(&ev.gram);
            }
            // reservoir sample over row buffers (materialized at the end)
            for i in 0..ev.x.rows() {
                sc.token_count += 1;
                if sc.rows.len() < MAX_SAMPLE {
                    sc.rows.push(ev.x.row(i).to_vec());
                } else {
                    let k = rng.below(sc.token_count);
                    if k < MAX_SAMPLE {
                        sc.rows[k] = ev.x.row(i).to_vec();
                    }
                }
            }
        }
    }
    for sc in sites.values_mut() {
        sc.sample = Tensor::from_rows(&sc.rows);
        sc.rows = Vec::new();
    }
    Ok(Calibration { sites, n_sequences: seqs.len(), n_tokens })
}

/// Load calibration sequences from a corpus token stream: `count` windows
/// of length `len`, sampled deterministically.
pub fn calib_sequences(tokens: &[u16], count: usize, len: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let start = rng.below(tokens.len().saturating_sub(len + 1).max(1));
        out.push(tokens[start..(start + len).min(tokens.len())].to_vec());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    #[test]
    fn calibration_covers_all_sites() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let seqs = vec![toks(16, 1), toks(16, 2)];
        let cal = run_calibration(&cfg, &w, &seqs, 7).unwrap();
        assert_eq!(cal.sites.len(), cfg.n_layers * 4);
        assert_eq!(cal.n_tokens, 32);
        let sc = cal.site(0, "qkv");
        assert_eq!(sc.n, cfg.d_model);
        assert!(sc.sample.rows() > 0 && sc.sample.rows() <= MAX_SAMPLE);
        assert!(sc.hessian.frob_norm() > 0.0);
        assert!(sc.signed_absmax.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn down_site_has_ff_width() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let cal = run_calibration(&cfg, &w, &[toks(8, 3)], 7).unwrap();
        assert_eq!(cal.site(1, "down").n, cfg.d_ff);
    }

    #[test]
    fn reservoir_caps() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let seqs: Vec<Vec<u16>> = (0..20).map(|i| toks(16, i)).collect();
        let cal = run_calibration(&cfg, &w, &seqs, 7).unwrap();
        assert_eq!(cal.site(0, "qkv").sample.rows(), MAX_SAMPLE.min(320));
    }

    fn assert_calibs_bit_identical(a: &Calibration, b: &Calibration, label: &str) {
        assert_eq!(a.n_tokens, b.n_tokens, "{label}: n_tokens");
        assert_eq!(a.sites.len(), b.sites.len(), "{label}: site count");
        for (key, sa) in &a.sites {
            let sb = &b.sites[key];
            assert_eq!(sa.token_count, sb.token_count, "{label}: {key} token_count");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&sa.signed_absmax), bits(&sb.signed_absmax),
                       "{label}: {key} signed_absmax");
            assert_eq!(bits(sa.hessian.data()), bits(sb.hessian.data()),
                       "{label}: {key} hessian");
            assert_eq!(bits(sa.sample.data()), bits(sb.sample.data()),
                       "{label}: {key} sample");
        }
    }

    #[test]
    fn pool_calibration_is_lane_count_invariant() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 2);
        // 5 sequences over 3 lanes exercises the remainder chunk
        for n_seqs in [1usize, 2, 5] {
            let seqs: Vec<Vec<u16>> = (0..n_seqs).map(|i| toks(12, i as u64)).collect();
            let serial =
                run_calibration_pool(&cfg, &w, &seqs, 7, true, &crate::tensor::pool::WorkerPool::new(1))
                    .unwrap();
            for lanes in [2usize, 3, 8] {
                let pool = crate::tensor::pool::WorkerPool::new(lanes);
                let par = run_calibration_pool(&cfg, &w, &seqs, 7, true, &pool).unwrap();
                assert_calibs_bit_identical(&serial, &par,
                                            &format!("seqs={n_seqs} lanes={lanes}"));
            }
            // and the global-pool entry point agrees too
            let global = run_calibration_opts(&cfg, &w, &seqs, 7, true).unwrap();
            assert_calibs_bit_identical(&serial, &global, &format!("seqs={n_seqs} global"));
        }
    }

    #[test]
    fn calib_sequences_shape() {
        let toks: Vec<u16> = (0..1000).map(|i| (i % 260) as u16).collect();
        let seqs = calib_sequences(&toks, 5, 64, 1);
        assert_eq!(seqs.len(), 5);
        assert!(seqs.iter().all(|s| s.len() == 64));
    }
}
