//! Calibration: the **single pass** of SingleQuant's title.
//!
//! Runs the Rust reference forward over a handful of calibration sequences
//! and records, per rotation site:
//!
//! * the per-channel signed absmax (ART's massive-outlier profile),
//! * a token reservoir sample (URT medians, clip search, learned-rotation
//!   baselines, quant-error analyses),
//! * the Hessian Xᵀ X (GPTQ).
//!
//! One forward pass feeds every method — closed-form and learned alike — so
//! the Table-7 quantization-time comparison isolates the *transform
//! construction* cost, exactly the paper's framing.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::forward::{forward_score, Tap};
use crate::model::{ModelConfig, Weights};
use crate::tensor::{stats, Tensor};
use crate::util::rng::Rng;

/// Per-site calibration summary.
#[derive(Clone, Debug)]
pub struct SiteCalib {
    pub n: usize,
    pub signed_absmax: Vec<f32>,
    /// Token reservoir, [S, n] with S <= max_sample (materialized from
    /// `rows` once the pass completes).
    pub sample: Tensor,
    rows: Vec<Vec<f32>>,
    /// Accumulated Xᵀ X (only when the weight quantizer needs it — GPTQ;
    /// skipping it is a large fraction of the single-pass cost).
    pub hessian: Tensor,
    pub token_count: usize,
}

impl SiteCalib {
    fn new(n: usize, with_hessian: bool) -> SiteCalib {
        SiteCalib {
            n,
            signed_absmax: vec![0.0; n],
            sample: Tensor::zeros(&[0, n]),
            rows: Vec::new(),
            hessian: if with_hessian {
                Tensor::zeros(&[n, n])
            } else {
                Tensor::zeros(&[0, 0])
            },
            token_count: 0,
        }
    }

    /// Per-channel median over the reservoir (URT's NO profile).
    pub fn median(&self) -> Vec<f32> {
        if self.sample.rows() == 0 {
            return vec![0.0; self.n];
        }
        stats::col_median(&self.sample)
    }

    pub fn absmax(&self) -> Vec<f32> {
        self.signed_absmax.iter().map(|x| x.abs()).collect()
    }
}

/// Full calibration result keyed by `l{i:02}.{site}`.
pub struct Calibration {
    pub sites: BTreeMap<String, SiteCalib>,
    pub n_sequences: usize,
    pub n_tokens: usize,
}

impl Calibration {
    pub fn site(&self, layer: usize, site: &str) -> &SiteCalib {
        &self.sites[&format!("l{layer:02}.{site}")]
    }
}

/// Reservoir row-sampling cap per site.
pub const MAX_SAMPLE: usize = 192;

/// Run the calibration pass over `seqs` (token id sequences).
pub fn run_calibration(
    cfg: &ModelConfig,
    weights: &Weights,
    seqs: &[Vec<u16>],
    seed: u64,
) -> Result<Calibration> {
    run_calibration_opts(cfg, weights, seqs, seed, true)
}

/// Calibration with explicit control over Hessian accumulation (the
/// Xᵀ X products are only consumed by GPTQ and dominate the tap cost).
pub fn run_calibration_opts(
    cfg: &ModelConfig,
    weights: &Weights,
    seqs: &[Vec<u16>],
    seed: u64,
    with_hessian: bool,
) -> Result<Calibration> {
    let mut sites: BTreeMap<String, SiteCalib> = BTreeMap::new();
    for layer in 0..cfg.n_layers {
        for site in crate::model::config::ROT_SITES {
            let (n, _, _) = cfg.site_dims(site);
            sites.insert(format!("l{layer:02}.{site}"),
                         SiteCalib::new(n, with_hessian));
        }
    }
    let mut rng = Rng::new(seed);
    let mut n_tokens = 0usize;
    for seq in seqs {
        n_tokens += seq.len();
        let mut tap = |layer: usize, site: &str, x: &Tensor| {
            let sc = sites.get_mut(&format!("l{layer:02}.{site}")).unwrap();
            // signed absmax
            for i in 0..x.rows() {
                for (j, &v) in x.row(i).iter().enumerate() {
                    if v.abs() > sc.signed_absmax[j].abs() {
                        sc.signed_absmax[j] = v;
                    }
                }
            }
            if with_hessian {
                sc.hessian = sc.hessian.add(&x.matmul_tn(x));
            }
            // reservoir sample over row buffers (materialized at the end)
            for i in 0..x.rows() {
                sc.token_count += 1;
                if sc.rows.len() < MAX_SAMPLE {
                    sc.rows.push(x.row(i).to_vec());
                } else {
                    let k = rng.below(sc.token_count);
                    if k < MAX_SAMPLE {
                        sc.rows[k] = x.row(i).to_vec();
                    }
                }
            }
        };
        forward_score(cfg, weights, seq, None, Some(&mut tap as Tap))?;
    }
    for sc in sites.values_mut() {
        sc.sample = Tensor::from_rows(&sc.rows);
        sc.rows = Vec::new();
    }
    Ok(Calibration { sites, n_sequences: seqs.len(), n_tokens })
}

/// Load calibration sequences from a corpus token stream: `count` windows
/// of length `len`, sampled deterministically.
pub fn calib_sequences(tokens: &[u16], count: usize, len: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let start = rng.below(tokens.len().saturating_sub(len + 1).max(1));
        out.push(tokens[start..(start + len).min(tokens.len())].to_vec());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    #[test]
    fn calibration_covers_all_sites() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let seqs = vec![toks(16, 1), toks(16, 2)];
        let cal = run_calibration(&cfg, &w, &seqs, 7).unwrap();
        assert_eq!(cal.sites.len(), cfg.n_layers * 4);
        assert_eq!(cal.n_tokens, 32);
        let sc = cal.site(0, "qkv");
        assert_eq!(sc.n, cfg.d_model);
        assert!(sc.sample.rows() > 0 && sc.sample.rows() <= MAX_SAMPLE);
        assert!(sc.hessian.frob_norm() > 0.0);
        assert!(sc.signed_absmax.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn down_site_has_ff_width() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let cal = run_calibration(&cfg, &w, &[toks(8, 3)], 7).unwrap();
        assert_eq!(cal.site(1, "down").n, cfg.d_ff);
    }

    #[test]
    fn reservoir_caps() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let seqs: Vec<Vec<u16>> = (0..20).map(|i| toks(16, i)).collect();
        let cal = run_calibration(&cfg, &w, &seqs, 7).unwrap();
        assert_eq!(cal.site(0, "qkv").sample.rows(), MAX_SAMPLE.min(320));
    }

    #[test]
    fn calib_sequences_shape() {
        let toks: Vec<u16> = (0..1000).map(|i| (i % 260) as u16).collect();
        let seqs = calib_sequences(&toks, 5, 64, 1);
        assert_eq!(seqs.len(), 5);
        assert!(seqs.iter().all(|s| s.len() == 64));
    }
}
