//! Byte-level tokenizer — the Rust twin of `python/compile/data.py`'s
//! encode/decode (ids 0..255 = bytes, then BOS/EOS/PAD).

pub const BOS: u16 = 256;
pub const EOS: u16 = 257;
pub const PAD: u16 = 258;
pub const VOCAB_SIZE: usize = 260;

pub fn encode(text: &str) -> Vec<u16> {
    text.bytes().map(|b| b as u16).collect()
}

pub fn encode_with(text: &str, bos: bool, eos: bool) -> Vec<u16> {
    let mut out = Vec::with_capacity(text.len() + 2);
    if bos {
        out.push(BOS);
    }
    out.extend(text.bytes().map(|b| b as u16));
    if eos {
        out.push(EOS);
    }
    out
}

pub fn decode(ids: &[u16]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| i < 256)
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Incremental decode for token streaming: feed one token, get the text
/// that became *complete* with it. Multi-byte UTF-8 sequences buffer in
/// `pending` until their last byte arrives (so concatenated deltas equal
/// the batch `decode` of the same tokens, instead of one U+FFFD per
/// byte); invalid sequences flush lossily. Special tokens produce "".
pub fn decode_stream(pending: &mut Vec<u8>, tok: u16) -> String {
    if tok >= 256 {
        return String::new();
    }
    pending.push(tok as u8);
    match std::str::from_utf8(pending) {
        Ok(s) => {
            let out = s.to_string();
            pending.clear();
            out
        }
        Err(e) if e.error_len().is_none() => {
            // incomplete trailing sequence: flush any valid prefix, keep
            // the tail (at most 3 bytes) for the next token
            let valid = e.valid_up_to();
            if valid == 0 {
                return String::new();
            }
            let out = String::from_utf8_lossy(&pending[..valid]).into_owned();
            pending.drain(..valid);
            out
        }
        Err(_) => {
            // invalid byte: flush everything lossily rather than stall
            let out = String::from_utf8_lossy(pending).into_owned();
            pending.clear();
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "the weaving master zorbal kept a red heron .";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_stripped_on_decode() {
        let ids = encode_with("ab", true, true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(decode(&ids), "ab");
    }

    #[test]
    fn non_ascii_lossy_safe() {
        let s = "héllo";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn stream_decode_reassembles_multibyte() {
        // "é" is two byte-tokens; the delta must arrive whole, not as
        // two replacement chars
        let mut pending = Vec::new();
        let deltas: Vec<String> = encode("héllo")
            .into_iter()
            .map(|t| decode_stream(&mut pending, t))
            .collect();
        assert_eq!(deltas.concat(), "héllo");
        assert_eq!(deltas[1], "", "first byte of é buffers");
        assert_eq!(deltas[2], "é", "second byte completes it");
        assert!(pending.is_empty());
    }

    #[test]
    fn stream_decode_flushes_invalid_and_skips_specials() {
        let mut pending = Vec::new();
        // 0xC4 is a 2-byte leader; 0xC5 is not a valid continuation
        assert_eq!(decode_stream(&mut pending, 0xC4), "");
        assert_eq!(decode_stream(&mut pending, 0xC5), "\u{FFFD}\u{FFFD}");
        assert!(pending.is_empty());
        assert_eq!(decode_stream(&mut pending, EOS), "");
        assert_eq!(decode_stream(&mut pending, b'a' as u16), "a");
    }
}
