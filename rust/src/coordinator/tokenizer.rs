//! Byte-level tokenizer — the Rust twin of `python/compile/data.py`'s
//! encode/decode (ids 0..255 = bytes, then BOS/EOS/PAD).

pub const BOS: u16 = 256;
pub const EOS: u16 = 257;
pub const PAD: u16 = 258;
pub const VOCAB_SIZE: usize = 260;

pub fn encode(text: &str) -> Vec<u16> {
    text.bytes().map(|b| b as u16).collect()
}

pub fn encode_with(text: &str, bos: bool, eos: bool) -> Vec<u16> {
    let mut out = Vec::with_capacity(text.len() + 2);
    if bos {
        out.push(BOS);
    }
    out.extend(text.bytes().map(|b| b as u16));
    if eos {
        out.push(EOS);
    }
    out
}

pub fn decode(ids: &[u16]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| i < 256)
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "the weaving master zorbal kept a red heron .";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_stripped_on_decode() {
        let ids = encode_with("ab", true, true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(decode(&ids), "ab");
    }

    #[test]
    fn non_ascii_lossy_safe() {
        let s = "héllo";
        assert_eq!(decode(&encode(s)), s);
    }
}
