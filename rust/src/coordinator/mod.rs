//! The serving coordinator (Layer 3): request admission, continuous
//! batching over the fixed-shape prefill/decode graphs, per-slot KV
//! management, and serving metrics.
//!
//! Architecture follows the vLLM-router shape scaled to this testbed: a
//! FIFO admission queue feeds a fixed-width slot table; newcomers are
//! prefilled as a padded batch and join the decode wave in place (per-slot
//! positions — the decode graph takes `pos: [B]`), finished requests retire
//! their slot immediately. Python is never on this path.
//!
//! The batcher drives an abstract [`ServeBackend`] (PJRT graphs in
//! production via `runtime::RunnerBackend`, a deterministic synthetic
//! model in tests) and emits a per-token [`TokenEvent`] stream that the
//! HTTP front-end (`crate::server`) turns into SSE. See `DESIGN.md`.

pub mod backend;
pub mod batcher;
pub mod events;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod tokenizer;

pub use backend::{BackendLimits, ServeBackend, SyntheticBackend};
pub use batcher::{AdmissionError, ServeConfig, ServeEngine};
pub use events::{FinishReason, TokenEvent};
pub use request::{Request, Response};
pub use sampler::{sample, token_rng};
