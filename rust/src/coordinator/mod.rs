//! The serving coordinator (Layer 3): request admission, continuous
//! batching over the fixed-shape prefill/decode graphs, per-slot KV
//! management, and serving metrics.
//!
//! Architecture follows the vLLM-router shape scaled to this testbed: a
//! FIFO admission queue feeds a fixed-width slot table; newcomers are
//! prefilled as a padded batch and join the decode wave in place (per-slot
//! positions — the decode graph takes `pos: [B]`), finished requests retire
//! their slot immediately. Python is never on this path.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod tokenizer;

pub use batcher::{ServeConfig, ServeEngine};
pub use request::{Request, Response};
