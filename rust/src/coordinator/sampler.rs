//! Token sampling, factored out of the batcher so every decode path —
//! the scheduler's prefill/decode ticks and the speculative-decode
//! accept loop (`crate::spec`) — draws tokens through one function with
//! one deterministic RNG scheme.
//!
//! The RNG is *positional*: [`token_rng`] derives an independent stream
//! from `(engine seed, request id, token index)`, so the token sampled
//! at index `i` of request `r` is a pure function of the logits row and
//! those three values. Nothing depends on draw order, scheduler
//! interleaving, or how many other requests were sampled first. That is
//! what makes preemption replay exact for sampled requests (a requeued
//! request re-samples index `i` with the same stream it would have used
//! the first time) and what lets speculative decoding promise
//! bit-identical output: the verifier samples the same indices from the
//! same rows, so accepting a draft token is indistinguishable from
//! having decoded it sequentially.

use super::request::MIN_TEMPERATURE;
use super::tokenizer::{BOS, EOS, PAD};
use crate::util::rng::Rng;

/// The RNG stream for one `(seed, request, token index)` coordinate.
/// SplitMix64-style finalizing multiplies spread the three inputs over
/// the whole seed space so adjacent indices and ids decorrelate; `Rng`
/// then runs its own SplitMix init on top.
pub fn token_rng(seed: u64, request_id: u64, token_index: usize) -> Rng {
    let mut h = seed ^ 0x9e37_79b9_7f4a_7c15;
    for x in [request_id, token_index as u64] {
        h = (h ^ x.wrapping_mul(0xff51_afd7_ed55_8ccd).rotate_left(31))
            .wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    }
    Rng::new(h)
}

/// Sample a token id from one logits row. Greedy is NaN/−inf-proof:
/// non-finite entries are skipped, ties resolve to the lowest index,
/// and a row with no finite logit deterministically returns EOS
/// (ending the request) instead of silently emitting token 0.
/// PAD and BOS are never sampled: PAD doubles as the in-band
/// inactive-slot sentinel of the decode wave (a sampled PAD would
/// desync per-slot KV state), and BOS is not a generable token.
/// Temperatures arrive pre-clamped from admission.
pub fn sample(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> u16 {
    let masked = |i: usize| i == PAD as usize || i == BOS as usize;
    match temperature {
        None => {
            let mut best: Option<(usize, f32)> = None;
            for (i, &x) in logits.iter().enumerate() {
                if x.is_finite() && !masked(i) && best.map_or(true, |(_, bv)| x > bv) {
                    best = Some((i, x));
                }
            }
            best.map(|(i, _)| i as u16).unwrap_or(EOS)
        }
        Some(t) => {
            debug_assert!(
                t >= MIN_TEMPERATURE,
                "temperature must be clamped at admission"
            );
            let maxv = logits
                .iter()
                .enumerate()
                .filter(|(i, x)| x.is_finite() && !masked(*i))
                .fold(f32::NEG_INFINITY, |m, (_, &x)| m.max(x));
            if !maxv.is_finite() {
                return EOS;
            }
            let probs: Vec<f32> = logits
                .iter()
                .enumerate()
                .map(|(i, &x)| {
                    if x.is_finite() && !masked(i) {
                        ((x - maxv) / t).exp()
                    } else {
                        0.0
                    }
                })
                .collect();
            let total: f32 = probs.iter().sum();
            if !total.is_finite() || total <= 0.0 {
                return EOS;
            }
            let mut u = rng.f32() * total;
            for (i, &p) in probs.iter().enumerate() {
                u -= p;
                if u <= 0.0 {
                    return i as u16;
                }
            }
            // float subtraction is not the exact inverse of the sum:
            // fall back to the last index that actually has mass, never
            // a masked (zero-probability) one
            probs
                .iter()
                .rposition(|&p| p > 0.0)
                .map(|i| i as u16)
                .unwrap_or(EOS)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sample_guards_nonfinite() {
        let mut rng = Rng::new(0);
        // all-NaN and all -inf rows end the request deterministically
        assert_eq!(sample(&mut rng, &[f32::NAN; 4], None), EOS);
        assert_eq!(sample(&mut rng, &[f32::NEG_INFINITY; 4], None), EOS);
        assert_eq!(sample(&mut rng, &[f32::NAN; 4], Some(0.5)), EOS);
        // NaN entries are skipped, not compared
        assert_eq!(sample(&mut rng, &[f32::NAN, 1.0, 2.0, f32::NAN], None), 2);
        // ties resolve to the lowest index (deterministic)
        assert_eq!(sample(&mut rng, &[3.0, 3.0, 1.0], None), 0);
        // +inf in the temperature path is masked rather than poisoning exp()
        let t = sample(&mut rng, &[0.0, f32::INFINITY, 1.0], Some(1.0));
        assert!(t == 0 || t == 2);
    }

    #[test]
    fn sample_never_emits_pad_or_bos() {
        // PAD is the in-band inactive-slot sentinel of the decode wave: a
        // sampled PAD would desync per-slot backend KV state. BOS is not
        // generable either. EOS remains a legal (terminating) sample.
        let mut rng = Rng::new(0);
        let mut logits = vec![0.0f32; 260];
        logits[PAD as usize] = 10.0;
        logits[BOS as usize] = 9.0;
        logits[42] = 5.0;
        assert_eq!(sample(&mut rng, &logits, None), 42);
        for _ in 0..50 {
            let t = sample(&mut rng, &logits, Some(0.7));
            assert!(t != PAD && t != BOS, "sampled special token {t}");
        }
        // a row where only PAD/BOS are finite must end the request
        let mut only_special = vec![f32::NAN; 260];
        only_special[PAD as usize] = 1.0;
        only_special[BOS as usize] = 2.0;
        assert_eq!(sample(&mut rng, &only_special, None), EOS);
        assert_eq!(sample(&mut rng, &only_special, Some(1.0)), EOS);
    }

    #[test]
    fn token_rng_is_positional_and_decorrelated() {
        // same coordinates -> same stream, bit for bit
        let a: Vec<u64> = (0..4).map(|_| token_rng(7, 3, 5).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]));
        // any coordinate change -> a different stream
        let base = token_rng(7, 3, 5).next_u64();
        assert_ne!(base, token_rng(8, 3, 5).next_u64(), "seed must matter");
        assert_ne!(base, token_rng(7, 4, 5).next_u64(), "request id must matter");
        assert_ne!(base, token_rng(7, 3, 6).next_u64(), "token index must matter");
        // adjacent indices give usable, non-degenerate f32 draws
        for idx in 0..32 {
            let u = token_rng(1, 1, idx).f32();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn sampling_is_independent_of_draw_history() {
        // the property the batcher's replay correctness rests on: the
        // token at (seed, id, index) does not depend on what else was
        // sampled before it
        let mut logits = vec![0.0f32; 64];
        for (i, l) in logits.iter_mut().enumerate() {
            *l = (i % 7) as f32 * 0.3;
        }
        let direct = sample(&mut token_rng(11, 2, 9), &logits, Some(0.8));
        // simulate a busy scheduler: many unrelated draws first
        for id in 0..20u64 {
            for idx in 0..20usize {
                sample(&mut token_rng(11, id, idx), &logits, Some(0.8));
            }
        }
        let replayed = sample(&mut token_rng(11, 2, 9), &logits, Some(0.8));
        assert_eq!(direct, replayed);
    }
}
