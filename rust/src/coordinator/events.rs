//! Per-token event stream emitted by the continuous batcher.
//!
//! The scheduler no longer returns only finished [`Response`]s: every tick
//! yields a sequence of [`TokenEvent`]s, one per state transition of each
//! in-flight request. Streaming consumers (the SSE path in
//! `crate::server`) subscribe per request via an `mpsc::Sender` handed to
//! [`crate::coordinator::ServeEngine::submit_streaming`]; batch consumers
//! collect the terminal events.

use super::request::Response;

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The model emitted EOS.
    Eos,
    /// Hit `max_new_tokens`, the engine cap, or the KV-cache horizon.
    Length,
    /// The per-request deadline passed; the response holds partial output.
    Deadline,
    /// The subscriber dropped its receiver (client disconnect).
    Cancelled,
}

impl FinishReason {
    /// OpenAI-compatible wire label.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "stop",
            FinishReason::Length => "length",
            FinishReason::Deadline => "deadline",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// One scheduler-observable state transition of a request.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// Admitted to a slot; prefill for this request starts this tick.
    Started { id: u64 },
    /// One generated token. `index` counts from 0 per request and is
    /// strictly increasing; EOS is never surfaced as a `Token` event.
    Token { id: u64, index: usize, token: u16, text: String },
    /// Terminal: generation finished (possibly with partial output on
    /// deadline/cancel). Exactly one `Done` or `Failed` per request.
    Done { id: u64, reason: FinishReason, response: Response },
    /// Terminal: the request never produced a response (validation or
    /// backend failure).
    Failed { id: u64, error: String },
}

impl TokenEvent {
    pub fn id(&self) -> u64 {
        match self {
            TokenEvent::Started { id }
            | TokenEvent::Token { id, .. }
            | TokenEvent::Done { id, .. }
            | TokenEvent::Failed { id, .. } => *id,
        }
    }

    /// True for `Done`/`Failed` — no further events follow for this id.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TokenEvent::Done { .. } | TokenEvent::Failed { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Eos.as_str(), "stop");
        assert_eq!(FinishReason::Length.as_str(), "length");
    }

    #[test]
    fn terminality() {
        assert!(!TokenEvent::Started { id: 1 }.is_terminal());
        assert!(TokenEvent::Failed { id: 1, error: "x".into() }.is_terminal());
        assert_eq!(TokenEvent::Started { id: 9 }.id(), 9);
    }
}
