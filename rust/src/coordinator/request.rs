//! Request/response types for the serving path.

use std::time::Instant;

use super::tokenizer;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt_tokens: Vec<u16>,
    pub max_new_tokens: usize,
    /// Greedy when None; otherwise softmax temperature.
    pub temperature: Option<f32>,
}

impl Request {
    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt_tokens: tokenizer::encode(text),
            max_new_tokens,
            temperature: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub text: String,
    /// Time to first token (prefill completion), seconds.
    pub ttft_s: f64,
    /// Total request latency, seconds.
    pub latency_s: f64,
    pub prompt_len: usize,
}

/// Internal per-slot record while a request is in flight.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    pub admitted: Instant,
    pub first_token: Option<Instant>,
    pub generated: Vec<u16>,
    /// Index at which the *next* token will be written into the KV cache.
    pub pos: usize,
    pub last_token: u16,
}
