//! Request/response types for the serving path.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use super::events::{FinishReason, TokenEvent};
use super::tokenizer;
use crate::util::clock;

/// Sampling temperatures are clamped into this range once, at admission
/// (`ServeEngine::try_submit`/`submit`), never per sample call.
pub const MIN_TEMPERATURE: f32 = 1e-3;
pub const MAX_TEMPERATURE: f32 = 1e3;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt_tokens: Vec<u16>,
    pub max_new_tokens: usize,
    /// Greedy when None; otherwise softmax temperature.
    pub temperature: Option<f32>,
    /// Absolute wall-clock cutoff. Once passed, a queued request is
    /// rejected and an in-flight one retires with partial output and
    /// [`FinishReason::Deadline`].
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, prompt_tokens: Vec<u16>) -> Request {
        Request {
            id,
            prompt_tokens,
            max_new_tokens: 16,
            temperature: None,
            deadline: None,
        }
    }

    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Request {
        Request::new(id, tokenizer::encode(text)).with_max_new(max_new_tokens)
    }

    pub fn with_max_new(mut self, n: usize) -> Request {
        self.max_new_tokens = n;
        self
    }

    /// Set the sampling temperature (`t <= 0`, NaN, and inf mean greedy).
    pub fn with_temperature(mut self, t: f32) -> Request {
        self.temperature = Some(t);
        self
    }

    pub fn with_deadline_in(mut self, d: Duration) -> Request {
        self.deadline = Some(clock::now() + d);
        self
    }

    /// Normalize the sampling temperature into the supported range.
    /// Called exactly once per request at admission so the sampler's hot
    /// path never re-clamps.
    pub(crate) fn normalize(&mut self) {
        self.temperature = match self.temperature {
            Some(t) if t.is_finite() && t > 0.0 => {
                Some(t.clamp(MIN_TEMPERATURE, MAX_TEMPERATURE))
            }
            _ => None,
        };
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub text: String,
    /// Time to first token (prefill completion), seconds.
    pub ttft_s: f64,
    /// Total request latency from admission, seconds.
    pub latency_s: f64,
    pub prompt_len: usize,
    /// Why generation stopped.
    pub finish: FinishReason,
}

/// Internal per-slot record while a request is in flight.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    /// When the request entered the admission queue.
    pub enqueued: Instant,
    /// When it was admitted to a slot.
    pub admitted: Instant,
    pub first_token: Option<Instant>,
    pub generated: Vec<u16>,
    /// Index at which the *next* token will be written into the KV cache.
    pub pos: usize,
    pub last_token: u16,
    /// Per-token event subscriber; None for batch-mode requests.
    pub sink: Option<Sender<TokenEvent>>,
    /// Set when the subscriber hung up; the slot retires next check.
    pub cancelled: bool,
    /// Bytes of an incomplete UTF-8 sequence awaiting their tail, so
    /// streamed text deltas reassemble multi-byte chars (see
    /// `tokenizer::decode_stream`).
    pub utf8_pending: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temperature_normalizes_once() {
        let mut r = Request::new(0, vec![1]).with_temperature(0.0);
        r.normalize();
        assert_eq!(r.temperature, None, "t=0 means greedy");

        let mut r = Request::new(0, vec![1]).with_temperature(f32::NAN);
        r.normalize();
        assert_eq!(r.temperature, None, "NaN means greedy");

        let mut r = Request::new(0, vec![1]).with_temperature(1e-9);
        r.normalize();
        assert_eq!(r.temperature, Some(MIN_TEMPERATURE));

        let mut r = Request::new(0, vec![1]).with_temperature(0.8);
        r.normalize();
        assert_eq!(r.temperature, Some(0.8));
    }

    #[test]
    fn builder_defaults() {
        let r = Request::from_text(3, "ab", 7);
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt_tokens.len(), 2);
        assert_eq!(r.max_new_tokens, 7);
        assert!(r.deadline.is_none());
    }
}
