//! Continuous batcher: the serving scheduler.
//!
//! A fixed-width slot table (the lowered batch size) runs one decode wave
//! per tick; whenever slots free up and requests wait, the newcomers are
//! prefilled together as a padded batch and join the wave in place. Mixed
//! prompt lengths are handled by the per-slot `pos` vector of the decode
//! graph and by reading each prompt's logits at its true last index from
//! the full prefill logits.
//!
//! The scheduler emits a per-token [`TokenEvent`] stream (see
//! `coordinator::events`): each `step()` returns every state transition of
//! the tick, and requests submitted with a sink get the same events pushed
//! over their channel — the contract the HTTP front-end (`crate::server`)
//! streams SSE from. Admission is bounded ([`ServeEngine::try_submit`]),
//! per-request deadlines cut work off with partial output, and a dropped
//! sink cancels its request and frees the slot in the same tick.
//!
//! The model itself sits behind [`ServeBackend`], so this file knows
//! nothing about PJRT: production uses `runtime::RunnerBackend`, tests use
//! the deterministic `SyntheticBackend`.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::Sender;
use std::time::Instant;

use anyhow::Result;

use super::backend::{BackendLimits, KvPoolStatus, ServeBackend};
use super::events::{FinishReason, TokenEvent};
use super::metrics::ServeMetrics;
use super::request::{InFlight, Request, Response};
use super::sampler::{sample, token_rng};
use super::tokenizer::{decode as tok_decode, decode_stream, BOS, EOS, PAD};
use crate::spec::DraftModel;
use crate::tensor::Tensor;
use crate::util::clock;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Hard cap on generation length (cache capacity guard applies too).
    pub max_new_cap: usize,
    pub seed: u64,
    /// Queued-request bound enforced by [`ServeEngine::try_submit`];
    /// the legacy `submit` path (batch drivers pre-queueing a whole
    /// trace) is exempt.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_new_cap: 48, seed: 7, queue_cap: 256 }
    }
}

/// Why `try_submit` refused a request (the HTTP layer maps `QueueFull`
/// and `KvBudget` to 429 and the rest to 400).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    QueueFull { cap: usize },
    InvalidPrompt { len: usize, max: usize },
    /// Prompt contains a token the backend cannot ingest: out of vocab
    /// range, or PAD — which doubles as the in-band inactive-slot
    /// sentinel of the prefill/decode waves, so letting it through would
    /// truncate the prompt and desync per-slot KV state.
    InvalidToken { token: u16 },
    /// The request's worst-case KV demand (prompt + capped generation,
    /// clamped to `max_seq`) exceeds the *entire* page pool: it could
    /// never run, no matter how long it waits, so it is refused up
    /// front (429 — a client retry against a bigger replica can serve
    /// it, waiting here cannot).
    KvBudget { needed_pages: usize, pool_pages: usize },
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::QueueFull { cap } => {
                write!(f, "admission queue full (cap {cap})")
            }
            AdmissionError::InvalidPrompt { len, max } => {
                write!(f, "prompt length {len} out of range (1..={max})")
            }
            AdmissionError::InvalidToken { token } => {
                write!(f, "prompt token {token} not ingestible (PAD or out of vocab)")
            }
            AdmissionError::KvBudget { needed_pages, pool_pages } => {
                write!(
                    f,
                    "request needs {needed_pages} KV page(s) worst-case but the pool \
                     has only {pool_pages}"
                )
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// A submitted request waiting for a slot. A preempted request comes
/// back here with its already-generated tokens in `resumed`: on
/// re-admission the backend prefills `prompt ++ resumed` (exact replay,
/// by the bit-identity of the cached decode path) and generation
/// continues where it stopped — the tokens are not re-emitted.
struct Queued {
    req: Request,
    sink: Option<Sender<TokenEvent>>,
    enqueued: Instant,
    resumed: Vec<u16>,
    /// Carried streaming state: bytes of a UTF-8 sequence cut by
    /// preemption mid-character.
    utf8_pending: Vec<u8>,
}

pub struct ServeEngine {
    backend: Box<dyn ServeBackend>,
    limits: BackendLimits,
    cfg: ServeConfig,
    queue: VecDeque<Queued>,
    slots: Vec<Option<InFlight>>,
    pub metrics: ServeMetrics,
    started: Option<Instant>,
    /// Speculative decoding, when enabled: the draft model proposing
    /// tokens and the per-wave burst length `k` (see `crate::spec`).
    spec: Option<Speculation>,
}

/// Speculative-decoding state attached to the engine by
/// [`ServeEngine::enable_speculation`].
struct Speculation {
    draft: Box<dyn DraftModel>,
    k: usize,
}

/// Push an event to a slot's subscriber (marking it cancelled on a dropped
/// receiver) and to the tick's event list.
fn emit(slot: &mut InFlight, events: &mut Vec<TokenEvent>, ev: TokenEvent) {
    if let Some(sink) = &slot.sink {
        if sink.send(ev.clone()).is_err() {
            slot.cancelled = true;
            slot.sink = None;
        }
    }
    events.push(ev);
}

/// Same for requests that never reached a slot.
fn emit_unslotted(
    sink: &Option<Sender<TokenEvent>>,
    events: &mut Vec<TokenEvent>,
    ev: TokenEvent,
) {
    if let Some(s) = sink {
        let _ = s.send(ev.clone());
    }
    events.push(ev);
}

impl ServeEngine {
    pub fn new(backend: Box<dyn ServeBackend>, cfg: ServeConfig) -> ServeEngine {
        let limits = backend.limits();
        let mut metrics = ServeMetrics::default();
        metrics.kernel_backend = backend.kernel_label().to_string();
        ServeEngine {
            slots: (0..limits.batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics,
            backend,
            limits,
            cfg,
            started: None,
            spec: None,
        }
    }

    /// Turn on speculative decoding: each decode wave proposes up to `k`
    /// draft tokens per slot, verifies them in one multi-row backend
    /// call, and accepts the longest exact prefix — output stays
    /// bit-identical to non-speculative decode (greedy and sampled; see
    /// `crate::spec` for the argument). Requires a backend that
    /// implements the burst API; `k` of 0 disables. The config is
    /// deliberately *not* part of [`ServeConfig`]: speculation is an
    /// engine capability toggled after construction, like the backend
    /// choice itself.
    pub fn enable_speculation(&mut self, k: usize, draft: Box<dyn DraftModel>) {
        assert!(
            self.backend.supports_speculative() || k == 0,
            "backend {} has no burst decode path",
            self.backend.kernel_label()
        );
        self.metrics.spec_draft = if k == 0 { String::new() } else { draft.label().to_string() };
        self.spec = (k > 0).then(|| Speculation { draft, k });
    }

    /// Static shape limits of the underlying serving graphs.
    pub fn limits(&self) -> BackendLimits {
        self.limits
    }

    /// The bounded-admission queue capacity (`try_submit`'s limit).
    pub fn queue_cap(&self) -> usize {
        self.cfg.queue_cap
    }

    /// Unbounded enqueue for batch drivers. Prefer [`try_submit`] on any
    /// path fed by external traffic.
    ///
    /// [`try_submit`]: ServeEngine::try_submit
    pub fn submit(&mut self, mut req: Request) {
        req.normalize();
        self.queue.push_back(Queued {
            req,
            sink: None,
            enqueued: clock::now(),
            resumed: Vec::new(),
            utf8_pending: Vec::new(),
        });
    }

    /// Unbounded enqueue with a per-token event subscriber.
    pub fn submit_streaming(&mut self, mut req: Request, sink: Sender<TokenEvent>) {
        req.normalize();
        self.queue.push_back(Queued {
            req,
            sink: Some(sink),
            enqueued: clock::now(),
            resumed: Vec::new(),
            utf8_pending: Vec::new(),
        });
    }

    /// Worst-case page demand of a request: prompt plus the capped
    /// generation length, clamped to the cache horizon (`finish_reason`
    /// stops generation at `max_seq` regardless). Replay does not change
    /// this — resumed tokens count against the same cap.
    fn kv_worst_pages(&self, req: &Request, pool: &KvPoolStatus) -> usize {
        let cap = req.max_new_tokens.min(self.cfg.max_new_cap);
        let worst = (req.prompt_tokens.len() + cap).min(self.limits.max_seq);
        pool.pages_for(worst)
    }

    /// A prompt token the backends cannot ingest: PAD (the in-band
    /// inactive-slot sentinel) or anything outside the vocab.
    fn bad_prompt_token(&self, req: &Request) -> Option<u16> {
        req.prompt_tokens
            .iter()
            .copied()
            .find(|&t| t == PAD || t as usize >= self.limits.vocab_size)
    }

    /// Bounded admission: validates the prompt against graph limits and
    /// enforces `queue_cap`. Also normalizes the sampling temperature —
    /// the single clamp point; the sampler never re-clamps.
    pub fn try_submit(
        &mut self,
        mut req: Request,
        sink: Option<Sender<TokenEvent>>,
    ) -> std::result::Result<(), AdmissionError> {
        let plen = req.prompt_tokens.len();
        let max = self.limits.score_seq;
        if plen == 0 || plen > max {
            self.metrics.failed += 1;
            return Err(AdmissionError::InvalidPrompt { len: plen, max });
        }
        if let Some(token) = self.bad_prompt_token(&req) {
            self.metrics.failed += 1;
            return Err(AdmissionError::InvalidToken { token });
        }
        if self.queue.len() >= self.cfg.queue_cap {
            self.metrics.rejected += 1;
            return Err(AdmissionError::QueueFull { cap: self.cfg.queue_cap });
        }
        if let Some(pool) = self.backend.kv_pool() {
            let needed = self.kv_worst_pages(&req, &pool);
            if needed > pool.pages_total {
                self.metrics.kv_rejected += 1;
                return Err(AdmissionError::KvBudget {
                    needed_pages: needed,
                    pool_pages: pool.pages_total,
                });
            }
        }
        req.normalize();
        self.queue.push_back(Queued {
            req,
            sink,
            enqueued: clock::now(),
            resumed: Vec::new(),
            utf8_pending: Vec::new(),
        });
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.active() > 0
    }

    /// One scheduler tick: expire stale queue entries, admit + prefill
    /// newcomers, sweep deadlines/cancellations, run one decode wave, and
    /// retire finished slots (freeing their capacity within this tick).
    /// Returns every event of the tick in emission order.
    pub fn step(&mut self) -> Result<Vec<TokenEvent>> {
        if self.started.is_none() {
            self.started = Some(clock::now());
        }
        let mut events = Vec::new();

        // ---- expire queued requests whose deadline already passed ---------
        let now = clock::now();
        if self
            .queue
            .iter()
            .any(|q| q.req.deadline.map_or(false, |d| d <= now))
        {
            let mut kept = VecDeque::with_capacity(self.queue.len());
            while let Some(q) = self.queue.pop_front() {
                if q.req.deadline.map_or(false, |d| d <= now) {
                    // counts as completed too: a Done/Response is delivered,
                    // so completed must reconcile with responses sent
                    self.metrics.completed += 1;
                    self.metrics.timeouts += 1;
                    let response = Response {
                        id: q.req.id,
                        tokens: Vec::new(),
                        text: String::new(),
                        ttft_s: 0.0,
                        latency_s: now.duration_since(q.enqueued).as_secs_f64(),
                        prompt_len: q.req.prompt_tokens.len(),
                        finish: FinishReason::Deadline,
                    };
                    let id = q.req.id;
                    emit_unslotted(&q.sink, &mut events, TokenEvent::Done {
                        id,
                        reason: FinishReason::Deadline,
                        response,
                    });
                } else {
                    kept.push_back(q);
                }
            }
            self.queue = kept;
        }

        // ---- admission + prefill ------------------------------------------
        let free: Vec<usize> = (0..self.limits.batch)
            .filter(|&i| self.slots[i].is_none())
            .collect();
        if !free.is_empty() && !self.queue.is_empty() {
            let t = self.limits.score_seq;
            let mut tokens = vec![PAD as i32; self.limits.batch * t];
            let mut admitted: Vec<usize> = Vec::new();
            'slots: for &slot in &free {
                // pop until a valid request is found; invalid ones fail
                // loudly instead of poisoning the whole tick
                let q = loop {
                    let Some(q) = self.queue.pop_front() else { break 'slots };
                    let plen = q.req.prompt_tokens.len();
                    // invalid requests fail loudly instead of poisoning
                    // the whole tick (same wording as the HTTP 400 path,
                    // by construction)
                    let err = if plen == 0 || plen > t {
                        Some(AdmissionError::InvalidPrompt { len: plen, max: t })
                    } else if let Some(token) = self.bad_prompt_token(&q.req) {
                        Some(AdmissionError::InvalidToken { token })
                    } else if let Some(pool) = self.backend.kv_pool() {
                        // a request whose worst case exceeds the whole
                        // pool can never run (legacy `submit` path; the
                        // bounded path refuses it in `try_submit`)
                        let needed = self.kv_worst_pages(&q.req, &pool);
                        (needed > pool.pages_total).then(|| {
                            self.metrics.kv_rejected += 1;
                            AdmissionError::KvBudget {
                                needed_pages: needed,
                                pool_pages: pool.pages_total,
                            }
                        })
                    } else {
                        None
                    };
                    if let Some(err) = err {
                        self.metrics.failed += 1;
                        let id = q.req.id;
                        emit_unslotted(&q.sink, &mut events, TokenEvent::Failed {
                            id,
                            error: err.to_string(),
                        });
                        continue;
                    }
                    // eager page reservation for the (replayed) prompt:
                    // if the pool cannot hold it right now, the head of
                    // the queue waits for retirements to free pages —
                    // deliberate head-of-line blocking, so an old large
                    // request is not starved by younger small ones
                    let plen_total = q.req.prompt_tokens.len() + q.resumed.len();
                    if !self.backend.kv_reserve(slot, plen_total) {
                        self.queue.push_front(q);
                        break 'slots;
                    }
                    break q;
                };
                let fresh = q.resumed.is_empty();
                for (j, &tok) in
                    q.req.prompt_tokens.iter().chain(q.resumed.iter()).enumerate()
                {
                    tokens[slot * t + j] = tok as i32;
                }
                let now = clock::now();
                if fresh {
                    self.metrics
                        .queue_wait
                        .record(now.duration_since(q.enqueued).as_secs_f64());
                }
                let mut inf = InFlight {
                    enqueued: q.enqueued,
                    admitted: now,
                    first_token: None,
                    generated: q.resumed,
                    pos: 0,
                    last_token: PAD,
                    sink: q.sink,
                    cancelled: false,
                    utf8_pending: q.utf8_pending,
                    req: q.req,
                };
                if fresh {
                    // a replayed request already announced itself
                    let id = inf.req.id;
                    emit(&mut inf, &mut events, TokenEvent::Started { id });
                }
                self.slots[slot] = Some(inf);
                admitted.push(slot);
            }
            if !admitted.is_empty() {
                let t0 = clock::now();
                let logits = self.backend.prefill(&tokens, &admitted)?;
                let dt = t0.elapsed().as_secs_f64();
                self.metrics.prefill_call.record(dt);
                self.metrics.prefill_seconds += dt;
                self.metrics.prefill_calls += 1;
                let v = self.limits.vocab_size;
                let seed = self.cfg.seed;
                for &slot in &admitted {
                    let Some(inf) = self.slots[slot].as_mut() else { continue };
                    // replayed tokens are part of the prefill, so the
                    // next token is sampled at the combined last index —
                    // and, by the positional RNG, with the exact stream
                    // a never-preempted run would have used there
                    let plen = inf.req.prompt_tokens.len() + inf.generated.len();
                    let temperature = inf.req.temperature;
                    let id = inf.req.id;
                    let index = inf.generated.len();
                    let row = row3(&logits, slot, plen - 1, v);
                    let tok = sample(&mut token_rng(seed, id, index), row, temperature);
                    inf.first_token = Some(clock::now());
                    inf.generated.push(tok);
                    inf.last_token = tok;
                    inf.pos = plen;
                    self.metrics.prefill_tokens += plen;
                    self.metrics.generated_tokens += 1;
                    if tok != EOS {
                        let text = decode_stream(&mut inf.utf8_pending, tok);
                        let ev = TokenEvent::Token { id, index, token: tok, text };
                        emit(inf, &mut events, ev);
                    }
                }
                // retire single-token completions immediately
                let now = clock::now();
                for &slot in &admitted {
                    self.maybe_retire(slot, now, &mut events);
                }
            }
        }

        // ---- deadline / cancel sweep (before burning a decode wave) -------
        let now = clock::now();
        for slot in 0..self.limits.batch {
            if self.slots[slot].is_some() {
                self.maybe_retire(slot, now, &mut events);
            }
        }

        // ---- KV reservation + preemption (paged backends) ------------------
        // Every active slot needs room for the position the decode wave
        // will append. Reserve oldest-first; when the pool runs dry,
        // evict the lowest-priority (youngest) slot and requeue it with
        // its generated tokens — pool pressure surfaces as preemption or
        // admission backpressure, never as a backend step error.
        if self.backend.kv_pool().is_some() && self.active() > 0 {
            let mut order: Vec<usize> = (0..self.limits.batch)
                .filter(|&i| self.slots[i].is_some())
                .collect();
            // `Option<Instant>` orders None first, and the filter above
            // guarantees Some — no panicking accessor needed
            order.sort_by_key(|&i| self.slots[i].as_ref().map(|inf| inf.enqueued));
            for &slot in &order {
                while self.slots[slot].is_some() && !self.backend.kv_reserve(slot, 1) {
                    // an active slot always exists here (this one is);
                    // if the victim search still comes up empty, stop
                    // evicting rather than aborting the engine
                    let Some(victim) = self.pick_victim() else { break };
                    self.preempt(victim, &mut events);
                    // if `slot` itself was the victim the loop exits via
                    // the is_some() guard
                }
            }
        }

        // ---- decode wave ---------------------------------------------------
        if self.active() > 0 {
            if self.spec.is_some() {
                self.spec_decode_wave(&mut events)?;
            } else {
                self.decode_wave(&mut events)?;
            }
            // retirement frees capacity within the same tick
            let now = clock::now();
            for i in 0..self.limits.batch {
                if self.slots[i].is_some() {
                    self.maybe_retire(i, now, &mut events);
                }
            }
        }

        if let Some(pool) = self.backend.kv_pool() {
            self.metrics.kv_pages_total = pool.pages_total;
            self.metrics.kv_pages_used = pool.pages_used();
        }
        self.metrics.pool_queue_depth = crate::tensor::pool::global_queue_depth();
        self.metrics.wall_s = self.started.map_or(0.0, |t| t.elapsed().as_secs_f64());
        Ok(events)
    }

    /// The plain decode wave: one position per active slot per tick.
    fn decode_wave(&mut self, events: &mut Vec<TokenEvent>) -> Result<()> {
        let b = self.limits.batch;
        let mut toks = vec![PAD as i32; b];
        let mut pos = vec![0i32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(inf) = s {
                toks[i] = inf.last_token as i32;
                pos[i] = inf.pos as i32;
            }
        }
        let t0 = clock::now();
        let logits = self.backend.decode(&toks, &pos)?;
        let wave = t0.elapsed().as_secs_f64();
        self.metrics.decode_step.record(wave);
        self.metrics.decode_seconds += wave;
        self.metrics.decode_steps += 1;
        let v = self.limits.vocab_size;
        let seed = self.cfg.seed;
        for i in 0..b {
            if let Some(inf) = self.slots[i].as_mut() {
                let row = &logits.data()[i * v..(i + 1) * v];
                let index = inf.generated.len();
                let tok =
                    sample(&mut token_rng(seed, inf.req.id, index), row, inf.req.temperature);
                inf.generated.push(tok);
                inf.last_token = tok;
                inf.pos += 1;
                self.metrics.generated_tokens += 1;
                self.metrics.decode_tokens += 1;
                self.metrics.per_token.record(wave);
                if tok != EOS {
                    let id = inf.req.id;
                    let text = decode_stream(&mut inf.utf8_pending, tok);
                    let ev = TokenEvent::Token { id, index, token: tok, text };
                    emit(inf, events, ev);
                }
            }
        }
        Ok(())
    }

    /// The speculative decode wave: the draft proposes up to `k` tokens
    /// per slot, the target model verifies each slot's whole burst
    /// (`[last_token, d1..dk]`) in one multi-row backend call, and the
    /// accept loop keeps the longest exact prefix.
    ///
    /// Exactness: row `i` of a verified burst is bit-identical to the
    /// row sequential decode would produce after the same tokens (the
    /// `step_rows` property pinned in `model::native`), and each token
    /// is sampled from its row with the positional RNG stream of its
    /// index — so every *emitted* token equals the non-speculative
    /// run's, whatever the draft proposed. A rejected suffix rolls back
    /// through `kv_truncate`, restoring the slot to exactly the
    /// accepted prefix; under pool pressure the backend degrades a
    /// slot's burst to length 1 (plain decode) rather than erroring,
    /// preserving the batcher's reserve/preempt guarantees.
    fn spec_decode_wave(&mut self, events: &mut Vec<TokenEvent>) -> Result<()> {
        let b = self.limits.batch;
        let k = match &self.spec {
            Some(spec) => spec.k,
            // only reachable with speculation enabled; degrade to the
            // plain wave rather than panicking if that ever changes
            None => return self.decode_wave(events),
        };
        let mut bursts: Vec<Vec<u16>> = vec![Vec::new(); b];
        let mut pos = vec![0i32; b];
        for i in 0..b {
            let Some(inf) = self.slots[i].as_ref() else { continue };
            pos[i] = inf.pos as i32;
            // clamp so the emitted prefix cannot pass the generation cap
            // and the appended rows cannot outgrow the cache horizon
            let cap = inf.req.max_new_tokens.min(self.cfg.max_new_cap);
            let cap_room = cap.saturating_sub(inf.generated.len() + 1);
            let seq_room = self.limits.max_seq.saturating_sub(inf.pos + 1);
            let want = k.min(cap_room).min(seq_room);
            let mut burst = vec![inf.last_token];
            if want > 0 {
                let ctx: Vec<u16> = inf
                    .req
                    .prompt_tokens
                    .iter()
                    .chain(inf.generated.iter())
                    .copied()
                    .collect();
                if let Some(spec) = self.spec.as_mut() {
                    for d in spec.draft.propose(i, &ctx, want).into_iter().take(want) {
                        // a token the verifier could never accept (the
                        // sampler masks PAD/BOS) or the model cannot
                        // ingest ends the proposal run; nothing can
                        // follow EOS
                        if d == PAD || d == BOS || d as usize >= self.limits.vocab_size {
                            break;
                        }
                        burst.push(d);
                        if d == EOS {
                            break;
                        }
                    }
                }
            }
            bursts[i] = burst;
        }

        let t0 = clock::now();
        let results = self.backend.decode_burst(&bursts, &pos)?;
        let wave = t0.elapsed().as_secs_f64();
        self.metrics.decode_step.record(wave);
        self.metrics.decode_seconds += wave;
        self.metrics.decode_steps += 1;
        let seed = self.cfg.seed;
        for i in 0..b {
            let Some(rows) = &results[i] else { continue };
            let Some(inf) = self.slots[i].as_mut() else { continue };
            let l = rows.rows();
            debug_assert!(
                l >= 1 && l <= bursts[i].len(),
                "burst result rows out of range"
            );
            let mut emitted = 0usize;
            for r in 0..l {
                let row = rows.row(r);
                let index = inf.generated.len();
                let tok =
                    sample(&mut token_rng(seed, inf.req.id, index), row, inf.req.temperature);
                inf.generated.push(tok);
                inf.last_token = tok;
                emitted += 1;
                self.metrics.generated_tokens += 1;
                self.metrics.decode_tokens += 1;
                self.metrics.per_token.record(wave);
                if tok == EOS {
                    break;
                }
                let id = inf.req.id;
                let text = decode_stream(&mut inf.utf8_pending, tok);
                let ev = TokenEvent::Token { id, index, token: tok, text };
                emit(inf, events, ev);
                // the draft token at r+1 was verified iff the sampled
                // token equals it; a mismatch ends the accepted prefix
                if r + 1 >= l || tok != bursts[i][r + 1] {
                    break;
                }
            }
            let new_pos = inf.pos + emitted;
            inf.pos = new_pos;
            self.metrics.spec_proposed += (l - 1) as u64;
            self.metrics.spec_accepted += (emitted - 1) as u64;
            self.metrics.spec_wave_len.record(emitted as f64);
            if emitted < l {
                // drop the rejected rows: the cache must hold exactly
                // the tokens before the new pending last_token
                self.backend.kv_truncate(i, new_pos);
            }
        }
        Ok(())
    }

    /// The slot to evict under pool pressure: lowest priority = latest
    /// `enqueued` (ties to the highest index). The caller may receive
    /// the very slot it is reserving for — preempting it is still
    /// correct (it requeues at the front and re-admits first).
    fn pick_victim(&self) -> Option<usize> {
        (0..self.limits.batch)
            .filter_map(|i| self.slots[i].as_ref().map(|inf| (inf.enqueued, i)))
            .max()
            .map(|(_, i)| i)
    }

    /// Evict `slot` to relieve KV pressure. Replayable requests (prompt
    /// + generated still fits the prefill window) requeue at the *front*
    /// with their tokens saved — re-admission prefills `prompt ++
    /// generated`, which the bit-exact cached path replays identically.
    /// A request that outgrew the window finishes gracefully with the
    /// partial output instead.
    fn preempt(&mut self, slot: usize, events: &mut Vec<TokenEvent>) {
        // preempting an empty slot is a scheduler bug, but never worth
        // an engine abort — there is simply nothing to evict
        let Some(inf) = self.slots[slot].take() else { return };
        self.metrics.preemptions += 1;
        let plen_total = inf.req.prompt_tokens.len() + inf.generated.len();
        if plen_total > self.limits.score_seq {
            self.slots[slot] = Some(inf);
            self.retire(slot, FinishReason::Length, events);
            return;
        }
        self.backend.retire(slot);
        if let Some(spec) = &mut self.spec {
            spec.draft.retire(slot);
        }
        self.queue.push_front(Queued {
            req: inf.req,
            sink: inf.sink,
            // keep the original arrival time: the replay outranks every
            // younger request at the next admission
            enqueued: inf.enqueued,
            resumed: inf.generated,
            utf8_pending: inf.utf8_pending,
        });
    }

    fn finish_reason(&self, slot: usize, now: Instant) -> Option<FinishReason> {
        let inf = self.slots[slot].as_ref()?;
        if inf.cancelled {
            return Some(FinishReason::Cancelled);
        }
        if inf.last_token == EOS {
            return Some(FinishReason::Eos);
        }
        let cap = inf.req.max_new_tokens.min(self.cfg.max_new_cap);
        if inf.generated.len() >= cap || inf.pos + 1 >= self.limits.max_seq {
            return Some(FinishReason::Length);
        }
        if inf.req.deadline.map_or(false, |d| d <= now) {
            return Some(FinishReason::Deadline);
        }
        None
    }

    fn maybe_retire(
        &mut self,
        slot: usize,
        now: Instant,
        events: &mut Vec<TokenEvent>,
    ) -> bool {
        match self.finish_reason(slot, now) {
            Some(reason) => {
                self.retire(slot, reason, events);
                true
            }
            None => false,
        }
    }

    fn retire(&mut self, slot: usize, reason: FinishReason, events: &mut Vec<TokenEvent>) {
        // retiring an already-empty slot is a no-op, not a panic
        let Some(inf) = self.slots[slot].take() else { return };
        self.backend.retire(slot);
        if let Some(spec) = &mut self.spec {
            spec.draft.retire(slot);
        }
        let now = clock::now();
        let ttft = inf
            .first_token
            .map(|t| t.duration_since(inf.admitted).as_secs_f64())
            .unwrap_or(0.0);
        let latency = now.duration_since(inf.admitted).as_secs_f64();
        self.metrics.ttft.record(ttft);
        self.metrics.latency.record(latency);
        self.metrics.completed += 1;
        match reason {
            FinishReason::Deadline => self.metrics.timeouts += 1,
            FinishReason::Cancelled => self.metrics.cancelled += 1,
            _ => {}
        }
        let mut tokens = inf.generated;
        if tokens.last() == Some(&EOS) {
            tokens.pop();
        }
        let response = Response {
            id: inf.req.id,
            text: tok_decode(&tokens),
            tokens,
            ttft_s: ttft,
            latency_s: latency,
            prompt_len: inf.req.prompt_tokens.len(),
            finish: reason,
        };
        let ev = TokenEvent::Done { id: inf.req.id, reason, response };
        if !inf.cancelled {
            if let Some(sink) = &inf.sink {
                let _ = sink.send(ev.clone());
            }
        }
        events.push(ev);
    }

    /// Fail every queued and in-flight request (backend fault recovery /
    /// hard shutdown). Slots and queue end up empty.
    pub fn abort_all(&mut self, error: &str) -> Vec<TokenEvent> {
        let mut events = Vec::new();
        for slot in 0..self.limits.batch {
            if let Some(inf) = self.slots[slot].take() {
                self.backend.retire(slot);
                if let Some(spec) = &mut self.spec {
                    spec.draft.retire(slot);
                }
                self.metrics.failed += 1;
                let id = inf.req.id;
                emit_unslotted(&inf.sink, &mut events, TokenEvent::Failed {
                    id,
                    error: error.to_string(),
                });
            }
        }
        while let Some(q) = self.queue.pop_front() {
            self.metrics.failed += 1;
            let id = q.req.id;
            emit_unslotted(&q.sink, &mut events, TokenEvent::Failed {
                id,
                error: error.to_string(),
            });
        }
        events
    }

    /// Drive until queue and slots drain; collect finished responses.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            for ev in self.step()? {
                if let TokenEvent::Done { response, .. } = ev {
                    out.push(response);
                }
            }
        }
        Ok(out)
    }

    /// Convenience: one-shot generation.
    pub fn generate(&mut self, id: u64, prompt: &str, max_new: usize) -> Result<Response> {
        self.submit(Request::from_text(id, prompt, max_new));
        let mut responses = self.run_to_completion()?;
        responses
            .pop()
            .ok_or_else(|| anyhow::anyhow!("no response produced"))
    }
}

fn row3<'a>(t: &'a Tensor, i: usize, j: usize, v: usize) -> &'a [f32] {
    let rows = match t.shape() {
        [_, rows, _] => *rows,
        s => {
            debug_assert!(false, "prefill logits must be rank 3, got {s:?}");
            j + 1
        }
    };
    let base = (i * rows + j) * v;
    &t.data()[base..base + v]
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;
    use std::time::Duration;

    use super::*;
    use crate::coordinator::backend::SyntheticBackend;

    fn engine(batch: usize) -> ServeEngine {
        ServeEngine::new(
            Box::new(SyntheticBackend::new(batch).with_seq(32, 64)),
            ServeConfig { max_new_cap: 16, seed: 1, queue_cap: 8 },
        )
    }

    #[test]
    fn retirement_frees_capacity_same_tick() {
        let mut e = engine(1);
        e.submit(Request::new(0, vec![5, 6, 7]).with_max_new(3));
        e.submit(Request::new(1, vec![9]).with_max_new(1));

        // tick 1 = admit + prefill token 1 + decode-wave token 2
        e.step().unwrap();
        assert_eq!(e.active(), 1);
        assert_eq!(e.pending(), 1);
        let evs = e.step().unwrap(); // token 3 -> finished
        assert!(evs.iter().any(|ev| ev.is_terminal() && ev.id() == 0));
        assert_eq!(e.active(), 0, "slot must free in the finishing tick");
        assert_eq!(e.pending(), 1);

        // single-token request: admitted, prefilled, and retired in one tick
        let evs = e.step().unwrap();
        assert!(evs.iter().any(|ev| ev.is_terminal() && ev.id() == 1));
        assert_eq!(e.active(), 0);
        assert_eq!(e.pending(), 0);
    }

    #[test]
    fn per_token_events_arrive_in_order() {
        let mut e = engine(2);
        let prompts: Vec<Vec<u16>> = vec![vec![1, 2, 3], vec![40], vec![7, 7]];
        let mut rxs = Vec::new();
        for (id, p) in prompts.iter().enumerate() {
            let (tx, rx) = channel();
            e.submit_streaming(Request::new(id as u64, p.clone()).with_max_new(5), tx);
            rxs.push(rx);
        }
        while e.has_work() {
            e.step().unwrap();
        }
        for (id, (rx, prompt)) in rxs.iter().zip(&prompts).enumerate() {
            let evs: Vec<TokenEvent> = rx.try_iter().collect();
            assert!(
                matches!(evs[0], TokenEvent::Started { .. }),
                "req {id}: first event must be Started"
            );
            let mut want_tok = SyntheticBackend::first_token(prompt);
            let mut want_index = 0usize;
            for ev in &evs[1..evs.len() - 1] {
                match ev {
                    TokenEvent::Token { index, token, .. } => {
                        assert_eq!(*index, want_index, "req {id}: index order");
                        assert_eq!(*token, want_tok, "req {id}: token progression");
                        want_index += 1;
                        want_tok = SyntheticBackend::next_token(want_tok);
                    }
                    other => panic!("req {id}: unexpected mid-stream event {other:?}"),
                }
            }
            assert_eq!(want_index, 5, "req {id}: all 5 tokens streamed");
            match evs.last().unwrap() {
                TokenEvent::Done { reason, response, .. } => {
                    assert_eq!(*reason, FinishReason::Length);
                    assert_eq!(response.tokens.len(), 5);
                }
                other => panic!("req {id}: last event {other:?}"),
            }
        }
    }

    #[test]
    fn bounded_admission_rejects_overflow() {
        let mut e = ServeEngine::new(
            Box::new(SyntheticBackend::new(1).with_seq(32, 64)),
            ServeConfig { max_new_cap: 4, seed: 1, queue_cap: 2 },
        );
        assert!(e.try_submit(Request::new(0, vec![1]), None).is_ok());
        assert!(e.try_submit(Request::new(1, vec![2]), None).is_ok());
        assert_eq!(
            e.try_submit(Request::new(2, vec![3]), None),
            Err(AdmissionError::QueueFull { cap: 2 })
        );
        assert_eq!(e.metrics.rejected, 1);

        assert_eq!(
            e.try_submit(Request::new(3, Vec::new()), None),
            Err(AdmissionError::InvalidPrompt { len: 0, max: 32 })
        );
        let long = vec![1u16; 33];
        assert!(matches!(
            e.try_submit(Request::new(4, long), None),
            Err(AdmissionError::InvalidPrompt { len: 33, .. })
        ));
    }

    #[test]
    fn admission_rejects_uningestible_tokens() {
        let mut e = engine(1);
        // PAD in the prompt would be truncated by in-band-sentinel
        // backends and desync per-slot KV state
        assert_eq!(
            e.try_submit(Request::new(0, vec![1, PAD, 2]), None),
            Err(AdmissionError::InvalidToken { token: PAD })
        );
        let over = e.limits().vocab_size as u16;
        assert_eq!(
            e.try_submit(Request::new(1, vec![over]), None),
            Err(AdmissionError::InvalidToken { token: over })
        );
        // the legacy unbounded submit path fails it at admit time
        e.submit(Request::new(2, vec![PAD]).with_max_new(4));
        let evs = e.step().unwrap();
        assert!(matches!(evs.first(), Some(TokenEvent::Failed { .. })));
        assert_eq!(e.active(), 0);
        assert_eq!(e.pending(), 0);
    }

    #[test]
    fn queued_deadline_expires_without_serving() {
        let mut e = engine(1);
        // deadline already in the past
        let mut req = Request::new(0, vec![1, 2]).with_max_new(4);
        req.deadline = Some(clock::now() - Duration::from_millis(1));
        let (tx, rx) = channel();
        e.submit_streaming(req, tx);
        let evs = e.step().unwrap();
        assert!(matches!(
            evs.first(),
            Some(TokenEvent::Done { reason: FinishReason::Deadline, .. })
        ));
        assert_eq!(e.metrics.timeouts, 1);
        assert_eq!(e.active(), 0);
        assert_eq!(e.pending(), 0);
        let got: Vec<TokenEvent> = rx.try_iter().collect();
        assert_eq!(got.len(), 1);
    }

    #[test]
    fn inflight_deadline_returns_partial_output() {
        let mut e = ServeEngine::new(
            Box::new(
                SyntheticBackend::new(1)
                    .with_seq(32, 64)
                    .with_delay(Duration::from_millis(5)),
            ),
            ServeConfig { max_new_cap: 16, seed: 1, queue_cap: 8 },
        );
        e.submit(
            Request::new(0, vec![3, 4])
                .with_max_new(16)
                .with_deadline_in(Duration::from_millis(1)),
        );
        let mut done = None;
        for _ in 0..4 {
            for ev in e.step().unwrap() {
                if let TokenEvent::Done { reason, response, .. } = ev {
                    done = Some((reason, response));
                }
            }
            if done.is_some() {
                break;
            }
        }
        let (reason, response) = done.expect("request must finish via deadline");
        assert_eq!(reason, FinishReason::Deadline);
        assert!(response.tokens.len() < 16, "deadline cut generation short");
        assert!(e.metrics.timeouts >= 1);
        assert_eq!(e.active(), 0);
    }

    #[test]
    fn dropped_sink_cancels_and_frees_slot() {
        let mut e = engine(1);
        let (tx, rx) = channel();
        e.submit_streaming(Request::new(0, vec![8, 9]).with_max_new(16), tx);
        e.step().unwrap(); // admitted + first token
        assert_eq!(e.active(), 1);
        drop(rx); // client disconnects
        let evs = e.step().unwrap(); // send fails -> cancelled -> retired
        assert!(evs.iter().any(|ev| matches!(
            ev,
            TokenEvent::Done { reason: FinishReason::Cancelled, .. }
        )));
        assert_eq!(e.active(), 0, "cancelled slot must free in the same tick");
        assert_eq!(e.metrics.cancelled, 1);
    }

    #[test]
    fn speculative_engine_matches_plain_engine_on_synthetic() {
        // With a draft that happens to predict the synthetic chain, the
        // speculative engine must retire the same responses as the plain
        // one — greedy, where the synthetic token calculator is
        // value-exact — while accepting drafts (fewer decode steps).
        let run = |spec: bool| {
            let mut e = ServeEngine::new(
                Box::new(SyntheticBackend::new(2).with_seq(32, 64)),
                ServeConfig { max_new_cap: 16, seed: 1, queue_cap: 8 },
            );
            if spec {
                e.enable_speculation(4, Box::new(crate::spec::NgramDraft::new(2)));
            }
            for id in 0..3u64 {
                // a repetitive prompt gives the n-gram draft material
                let prompt = vec![7u16, 8, 9, 7, 8, 9, 7, 8];
                e.try_submit(Request::new(id, prompt).with_max_new(10), None)
                    .unwrap();
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            let stats = (e.metrics.decode_steps, e.metrics.spec_accepted);
            (out.into_iter().map(|r| (r.tokens, r.finish)).collect::<Vec<_>>(), stats)
        };
        let (plain, _) = run(false);
        let (spec, (steps, accepted)) = run(true);
        assert_eq!(spec, plain, "speculation must not change output");
        // the synthetic chain increments by one, so prompt-lookup drafts
        // are mostly wrong — but the engine must still be exact; at
        // least the machinery ran
        assert!(steps >= 1);
        let _ = accepted; // acceptance depends on the prompt's chain
    }

    #[test]
    fn generate_follows_synthetic_progression() {
        let mut e = engine(1);
        let resp = e.generate(0, "ab", 4).unwrap();
        let first = SyntheticBackend::first_token(&[97, 98]);
        let mut want = vec![first];
        for _ in 1..4 {
            want.push(SyntheticBackend::next_token(*want.last().unwrap()));
        }
        assert_eq!(resp.tokens, want);
        assert_eq!(resp.finish, FinishReason::Length);
        assert!(resp.latency_s >= resp.ttft_s);
    }

    #[test]
    fn kv_budget_rejects_impossible_requests() {
        let mut e = ServeEngine::new(
            Box::new(SyntheticBackend::new(1).with_seq(32, 64).with_kv_pool(4, 4)),
            ServeConfig { max_new_cap: 16, seed: 1, queue_cap: 8 },
        );
        // worst case 8 prompt + 16 capped new = 24 tokens -> 6 pages > 4:
        // could never run on this pool, refused up front
        assert_eq!(
            e.try_submit(Request::new(0, vec![1; 8]).with_max_new(16), None),
            Err(AdmissionError::KvBudget { needed_pages: 6, pool_pages: 4 })
        );
        assert_eq!(e.metrics.kv_rejected, 1);
        // the legacy unbounded submit path fails it at admit time instead
        e.submit(Request::new(1, vec![1; 8]).with_max_new(16));
        let evs = e.step().unwrap();
        match evs.first() {
            Some(TokenEvent::Failed { error, .. }) => {
                assert!(error.contains("KV page"), "unexpected error {error:?}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(e.metrics.kv_rejected, 2);
        // a request that fits runs to completion and returns its pages
        assert!(e
            .try_submit(Request::new(2, vec![1, 2]).with_max_new(4), None)
            .is_ok());
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(e.metrics.kv_pages_total, 4);
        assert_eq!(e.metrics.kv_pages_used, 0, "retirement frees the pages");
    }

    #[test]
    fn pool_pressure_preempts_and_replays_to_completion() {
        // 3 pages x 4 tokens = 12 positions, but two 4-token prompts each
        // generating 8 need 2 x 12 = 24 at peak: the pool admits both and
        // must preempt the younger slot, requeue it with its generated
        // tokens, and replay it once the older request retires.
        let mut e = ServeEngine::new(
            Box::new(SyntheticBackend::new(2).with_seq(32, 64).with_kv_pool(4, 3)),
            ServeConfig { max_new_cap: 16, seed: 1, queue_cap: 8 },
        );
        let mut rxs = Vec::new();
        for id in 0..2u64 {
            let (tx, rx) = channel();
            let prompt = vec![10 + id as u16; 4];
            e.try_submit(Request::new(id, prompt).with_max_new(8), Some(tx))
                .unwrap();
            rxs.push(rx);
        }
        let mut done = 0;
        let mut ticks = 0;
        while e.has_work() {
            ticks += 1;
            assert!(ticks < 100, "pool pressure must not livelock");
            for ev in e.step().expect("pool pressure must never error a step") {
                if let TokenEvent::Done { reason, response, .. } = ev {
                    assert_eq!(reason, FinishReason::Length);
                    assert_eq!(response.tokens.len(), 8);
                    done += 1;
                }
            }
        }
        assert_eq!(done, 2, "every request completes despite preemption");
        assert!(e.metrics.preemptions >= 1, "3 pages cannot hold both slots");
        assert_eq!(e.metrics.kv_pages_used, 0, "all pages returned");
        // each subscriber saw exactly one Started and a gapless token
        // index sequence: replay must not re-emit delivered tokens
        for (id, rx) in rxs.iter().enumerate() {
            let evs: Vec<TokenEvent> = rx.try_iter().collect();
            let starts = evs
                .iter()
                .filter(|ev| matches!(ev, TokenEvent::Started { .. }))
                .count();
            assert_eq!(starts, 1, "req {id}: replay must not re-announce");
            let idxs: Vec<usize> = evs
                .iter()
                .filter_map(|ev| match ev {
                    TokenEvent::Token { index, .. } => Some(*index),
                    _ => None,
                })
                .collect();
            assert_eq!(idxs, (0..8).collect::<Vec<_>>(),
                       "req {id}: token stream has gaps or repeats");
        }
    }

    #[test]
    fn abort_all_fails_everything() {
        let mut e = engine(2);
        e.submit(Request::new(0, vec![1]).with_max_new(8));
        e.submit(Request::new(1, vec![2]).with_max_new(8));
        e.submit(Request::new(2, vec![3]).with_max_new(8));
        e.step().unwrap(); // two admitted, one queued
        let evs = e.abort_all("backend lost");
        assert_eq!(evs.len(), 3);
        assert!(evs.iter().all(|ev| matches!(ev, TokenEvent::Failed { .. })));
        assert_eq!(e.active(), 0);
        assert_eq!(e.pending(), 0);
    }
}
