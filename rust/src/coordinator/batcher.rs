//! Continuous batcher: the serving scheduler.
//!
//! A fixed-width slot table (the lowered batch size) runs one decode wave
//! per tick; whenever slots free up and requests wait, the newcomers are
//! prefilled together as a padded batch and join the wave in place. Mixed
//! prompt lengths are handled by the per-slot `pos` vector of the decode
//! graph and by reading each prompt's logits at its true last index from
//! the full prefill logits.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::metrics::ServeMetrics;
use super::request::{InFlight, Request, Response};
use super::tokenizer::{decode as tok_decode, EOS, PAD};
use crate::runtime::{KvCache, ModelRunner};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Slot count; must be one of the lowered serve batch sizes.
    pub batch: usize,
    /// Hard cap on generation length (cache capacity guard applies too).
    pub max_new_cap: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch: 4, max_new_cap: 48, seed: 7 }
    }
}

pub struct ServeEngine {
    runner: Arc<ModelRunner>,
    cfg: ServeConfig,
    queue: VecDeque<Request>,
    slots: Vec<Option<InFlight>>,
    kv: KvCache,
    pub metrics: ServeMetrics,
    rng: Rng,
    started: Option<Instant>,
}

impl ServeEngine {
    pub fn new(runner: Arc<ModelRunner>, cfg: ServeConfig) -> ServeEngine {
        let kv = runner.empty_kv(cfg.batch);
        ServeEngine {
            slots: (0..cfg.batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            kv,
            metrics: ServeMetrics::default(),
            rng: Rng::new(cfg.seed),
            runner,
            cfg,
            started: None,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn sample(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> u16 {
        match temperature {
            None => {
                let mut best = 0usize;
                for (i, &v) in logits.iter().enumerate() {
                    if v > logits[best] {
                        best = i;
                    }
                }
                best as u16
            }
            Some(t) => {
                let t = t.max(1e-3);
                let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let probs: Vec<f32> =
                    logits.iter().map(|&v| ((v - maxv) / t).exp()).collect();
                let total: f32 = probs.iter().sum();
                let mut u = rng.f32() * total;
                for (i, &p) in probs.iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return i as u16;
                    }
                }
                (probs.len() - 1) as u16
            }
        }
    }

    /// One scheduler tick: admit + prefill newcomers, one decode wave.
    /// Returns the responses completed during this tick.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let mut done = Vec::new();

        // ---- admission + prefill -------------------------------------------
        let free: Vec<usize> = (0..self.cfg.batch)
            .filter(|&i| self.slots[i].is_none())
            .collect();
        if !free.is_empty() && !self.queue.is_empty() {
            let t = self.runner.cfg.score_seq;
            let mut tokens = vec![PAD as i32; self.cfg.batch * t];
            let mut admitted: Vec<usize> = Vec::new();
            for &slot in &free {
                let Some(req) = self.queue.pop_front() else { break };
                if req.prompt_tokens.is_empty() || req.prompt_tokens.len() > t {
                    bail!("request {}: prompt length {} out of range (1..={t})",
                          req.id, req.prompt_tokens.len());
                }
                for (j, &tok) in req.prompt_tokens.iter().enumerate() {
                    tokens[slot * t + j] = tok as i32;
                }
                self.slots[slot] = Some(InFlight {
                    req,
                    admitted: Instant::now(),
                    first_token: None,
                    generated: Vec::new(),
                    pos: 0,
                    last_token: PAD,
                });
                admitted.push(slot);
            }
            if !admitted.is_empty() {
                let t0 = Instant::now();
                let (logits, mut fresh_kv) = self.runner.prefill(self.cfg.batch, &tokens)?;
                self.metrics.prefill_call.record(t0.elapsed().as_secs_f64());
                self.metrics.prefill_calls += 1;
                let v = self.runner.cfg.vocab_size;
                for &slot in &admitted {
                    self.kv.copy_slot_from(&self.runner.cfg, &mut fresh_kv, slot)?;
                    let inf = self.slots[slot].as_mut().unwrap();
                    let plen = inf.req.prompt_tokens.len();
                    self.metrics.prefill_tokens += plen;
                    let row = row3(&logits, slot, plen - 1, v);
                    let tok = Self::sample(&mut self.rng, row, inf.req.temperature);
                    inf.first_token = Some(Instant::now());
                    inf.generated.push(tok);
                    inf.last_token = tok;
                    inf.pos = plen;
                    self.metrics.generated_tokens += 1;
                }
                // retire single-token completions immediately
                for &slot in &admitted {
                    if self.slot_finished(slot) {
                        done.push(self.retire(slot));
                    }
                }
            }
        }

        // ---- decode wave -----------------------------------------------------
        if self.active() > 0 {
            let b = self.cfg.batch;
            let mut toks = vec![PAD as i32; b];
            let mut pos = vec![0i32; b];
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(inf) = s {
                    toks[i] = inf.last_token as i32;
                    pos[i] = inf.pos as i32;
                }
            }
            let t0 = Instant::now();
            let logits = self.runner.decode(&mut self.kv, &toks, &pos)?;
            self.metrics.decode_step.record(t0.elapsed().as_secs_f64());
            self.metrics.decode_steps += 1;
            let v = self.runner.cfg.vocab_size;
            for i in 0..b {
                if let Some(inf) = self.slots[i].as_mut() {
                    let row = &logits.data()[i * v..(i + 1) * v];
                    let tok = Self::sample(&mut self.rng, row, inf.req.temperature);
                    inf.generated.push(tok);
                    inf.last_token = tok;
                    inf.pos += 1;
                    self.metrics.generated_tokens += 1;
                }
            }
            for i in 0..b {
                if self.slots[i].is_some() && self.slot_finished(i) {
                    done.push(self.retire(i));
                }
            }
        }

        self.metrics.wall_s = self.started.unwrap().elapsed().as_secs_f64();
        Ok(done)
    }

    fn slot_finished(&self, slot: usize) -> bool {
        let inf = self.slots[slot].as_ref().unwrap();
        let cap = inf.req.max_new_tokens.min(self.cfg.max_new_cap);
        inf.last_token == EOS
            || inf.generated.len() >= cap
            || inf.pos + 1 >= self.runner.cfg.max_seq
    }

    fn retire(&mut self, slot: usize) -> Response {
        let inf = self.slots[slot].take().unwrap();
        let now = Instant::now();
        let ttft = inf
            .first_token
            .map(|t| t.duration_since(inf.admitted).as_secs_f64())
            .unwrap_or(0.0);
        let latency = now.duration_since(inf.admitted).as_secs_f64();
        self.metrics.ttft.record(ttft);
        self.metrics.latency.record(latency);
        self.metrics.completed += 1;
        let mut tokens = inf.generated;
        if tokens.last() == Some(&EOS) {
            tokens.pop();
        }
        Response {
            id: inf.req.id,
            text: tok_decode(&tokens),
            tokens,
            ttft_s: ttft,
            latency_s: latency,
            prompt_len: inf.req.prompt_tokens.len(),
        }
    }

    /// Drive until queue and slots drain.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 || self.active() > 0 {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Convenience: one-shot generation.
    pub fn generate(&mut self, id: u64, prompt: &str, max_new: usize) -> Result<Response> {
        self.submit(Request::from_text(id, prompt, max_new));
        let mut responses = self.run_to_completion()?;
        responses
            .pop()
            .ok_or_else(|| anyhow::anyhow!("no response produced"))
    }
}

fn row3<'a>(t: &'a Tensor, i: usize, j: usize, v: usize) -> &'a [f32] {
    let rows = t.shape()[1];
    let base = (i * rows + j) * v;
    &t.data()[base..base + v]
}
