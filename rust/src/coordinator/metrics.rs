//! Serving metrics: latency histograms + throughput counters (the Fig. 3
//! measurement surface).

/// Samples kept per histogram. The scheduler snapshots (clones) its
/// metrics every tick and a serving process records one sample per token,
/// so storage must stay bounded: beyond this window the ring overwrites
/// the oldest sample. `count()` stays cumulative; quantiles describe the
/// most recent `WINDOW` observations.
const WINDOW: usize = 4096;

#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    /// Ring cursor once `samples` reaches `WINDOW`.
    next: usize,
    /// Lifetime observation count.
    total: usize,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        if self.samples.len() < WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
        }
        self.next = (self.next + 1) % WINDOW;
        self.total += 1;
    }

    pub fn count(&self) -> usize {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub ttft: Histogram,
    pub latency: Histogram,
    pub decode_step: Histogram,
    pub prefill_call: Histogram,
    /// Decode-wave latency attributed per generated token (the "per-token
    /// latency" surface of the HTTP front-end).
    pub per_token: Histogram,
    /// Time spent in the admission queue before landing in a slot.
    pub queue_wait: Histogram,
    pub completed: usize,
    pub generated_tokens: usize,
    /// Tokens sampled from decode waves specifically (first tokens come
    /// from prefill logits and are excluded — see
    /// [`decode_only_tokens_per_s`](Self::decode_only_tokens_per_s)).
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    /// Requests refused by bounded admission (HTTP 429).
    pub rejected: usize,
    /// Requests refused because their worst-case KV demand exceeds the
    /// whole page pool (HTTP 429).
    pub kv_rejected: usize,
    /// Slots evicted under KV pool pressure (requeued with saved tokens,
    /// or finished with partial output when no longer replayable).
    pub preemptions: usize,
    /// Paged-KV pool gauges (zero when the backend has no page pool),
    /// refreshed every scheduler tick.
    pub kv_pages_total: usize,
    pub kv_pages_used: usize,
    /// Requests cut off by their deadline (queued or in flight).
    pub timeouts: usize,
    /// Requests whose subscriber disconnected mid-generation.
    pub cancelled: usize,
    /// Requests that failed validation or died with the backend.
    pub failed: usize,
    pub wall_s: f64,
    /// Cumulative wall time spent inside backend prefill calls.
    pub prefill_seconds: f64,
    /// Cumulative wall time spent inside backend decode waves.
    pub decode_seconds: f64,
    /// Compute-kernel path the backend selected ("scalar"/"avx2"/"neon",
    /// "n/a" for kernel-less backends; empty until an engine stamps it).
    pub kernel_backend: String,
    /// Unfinished chunks of the worker pool's in-flight job, sampled
    /// every scheduler tick (0 = pool idle or never started).
    pub pool_queue_depth: usize,
    /// Draft model label when speculative decoding is enabled
    /// ("ngram"/"native"; empty = speculation off).
    pub spec_draft: String,
    /// Draft tokens submitted to verification waves (excludes the
    /// pending last token, which every wave decodes regardless).
    pub spec_proposed: u64,
    /// Draft tokens the verifier accepted (exact-prefix matches); the
    /// ratio to `spec_proposed` is the acceptance rate.
    pub spec_accepted: u64,
    /// Tokens emitted per speculative wave (1 = no draft token
    /// survived, k+1 = the whole burst was accepted).
    pub spec_wave_len: Histogram,
}

impl ServeMetrics {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall_s
    }

    /// Decode throughput over time actually spent decoding (excludes
    /// prefill time, prefill-sampled first tokens, queue idle, and
    /// scheduler overhead) — the kernel-level tokens/sec the native
    /// backend is tuned against.
    pub fn decode_only_tokens_per_s(&self) -> f64 {
        if self.decode_seconds <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / self.decode_seconds
    }

    /// Fraction of backend time spent prefilling (vs decoding).
    pub fn prefill_time_fraction(&self) -> f64 {
        let total = self.prefill_seconds + self.decode_seconds;
        if total <= 0.0 {
            return 0.0;
        }
        self.prefill_seconds / total
    }

    /// Live KV pool utilization in [0, 1]; 0 when there is no pool.
    pub fn kv_utilization(&self) -> f64 {
        if self.kv_pages_total == 0 {
            return 0.0;
        }
        self.kv_pages_used as f64 / self.kv_pages_total as f64
    }

    /// Fraction of proposed draft tokens the verifier accepted, in
    /// [0, 1]; 0 before any speculative wave ran.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    pub fn summary(&self) -> String {
        let spec = if self.spec_draft.is_empty() {
            String::new()
        } else {
            format!(
                " spec[{}] proposed={} accepted={} rate={:.0}% wave_len p50={:.1}",
                self.spec_draft,
                self.spec_proposed,
                self.spec_accepted,
                self.spec_acceptance_rate() * 100.0,
                self.spec_wave_len.percentile(50.0),
            )
        };
        format!(
            "completed={} gen_tokens={} wall={:.2}s throughput={:.1} tok/s \
             decode_tput={:.1} tok/s prefill/decode split={:.0}%/{:.0}% \
             ttft p50={:.1}ms p95={:.1}ms latency p50={:.1}ms decode_step p50={:.2}ms \
             per_token p50={:.2}ms p95={:.2}ms rejected={} timeouts={} cancelled={} \
             kv_pages={}/{} preemptions={} kv_rejected={} kernel={}{}",
            self.completed,
            self.generated_tokens,
            self.wall_s,
            self.decode_tokens_per_s(),
            self.decode_only_tokens_per_s(),
            self.prefill_time_fraction() * 100.0,
            (1.0 - self.prefill_time_fraction()) * 100.0,
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(95.0) * 1e3,
            self.latency.percentile(50.0) * 1e3,
            self.decode_step.percentile(50.0) * 1e3,
            self.per_token.percentile(50.0) * 1e3,
            self.per_token.percentile(95.0) * 1e3,
            self.rejected,
            self.timeouts,
            self.cancelled,
            self.kv_pages_used,
            self.kv_pages_total,
            self.preemptions,
            self.kv_rejected,
            if self.kernel_backend.is_empty() { "?" } else { &self.kernel_backend },
            spec,
        )
    }

    /// Render the Prometheus text exposition format served by the HTTP
    /// front-end's `GET /metrics`. Quantiles are exported as gauges
    /// (recomputed per scrape), counters as `_total` counters.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::with_capacity(2048);
        let counter = |o: &mut String, name: &str, help: &str, v: f64| {
            let _ = writeln!(o, "# HELP {name} {help}");
            let _ = writeln!(o, "# TYPE {name} counter");
            let _ = writeln!(o, "{name} {v}");
        };
        counter(&mut o, "singlequant_requests_completed_total",
                "Requests retired with a response.", self.completed as f64);
        counter(&mut o, "singlequant_requests_rejected_total",
                "Requests refused by bounded admission (429).", self.rejected as f64);
        counter(&mut o, "singlequant_requests_timeout_total",
                "Requests cut off by their deadline.", self.timeouts as f64);
        counter(&mut o, "singlequant_requests_cancelled_total",
                "Requests cancelled by client disconnect.", self.cancelled as f64);
        counter(&mut o, "singlequant_requests_failed_total",
                "Requests failed by validation or backend errors.", self.failed as f64);
        counter(&mut o, "singlequant_tokens_generated_total",
                "Tokens sampled across all requests.", self.generated_tokens as f64);
        counter(&mut o, "singlequant_decode_tokens_total",
                "Tokens sampled from decode waves (excludes prefill-sampled \
                 first tokens).", self.decode_tokens as f64);
        counter(&mut o, "singlequant_prefill_tokens_total",
                "Prompt tokens prefilled.", self.prefill_tokens as f64);
        counter(&mut o, "singlequant_decode_steps_total",
                "Decode waves executed.", self.decode_steps as f64);
        counter(&mut o, "singlequant_prefill_calls_total",
                "Prefill batches executed.", self.prefill_calls as f64);
        counter(&mut o, "singlequant_preemptions_total",
                "Slots evicted under KV pool pressure.", self.preemptions as f64);
        counter(&mut o, "singlequant_kv_admission_rejected_total",
                "Requests refused because their worst-case KV demand exceeds \
                 the page pool (429).", self.kv_rejected as f64);
        counter(&mut o, "singlequant_spec_proposed_total",
                "Draft tokens submitted to speculative verification waves.",
                self.spec_proposed as f64);
        counter(&mut o, "singlequant_spec_accepted_total",
                "Draft tokens the verifier accepted (exact-prefix matches).",
                self.spec_accepted as f64);

        let gauge = |o: &mut String, name: &str, help: &str, v: f64| {
            let _ = writeln!(o, "# HELP {name} {help}");
            let _ = writeln!(o, "# TYPE {name} gauge");
            let _ = writeln!(o, "{name} {v}");
        };
        gauge(&mut o, "singlequant_kv_pages_total",
              "Pages in the KV block pool (0 = contiguous KV, no pool).",
              self.kv_pages_total as f64);
        gauge(&mut o, "singlequant_kv_pages_used",
              "KV pool pages currently held by slots.",
              self.kv_pages_used as f64);
        gauge(&mut o, "singlequant_kv_pool_utilization",
              "Used fraction of the KV page pool.", self.kv_utilization());
        gauge(&mut o, "singlequant_pool_queue_depth",
              "Unfinished chunks of the worker pool's in-flight job.",
              self.pool_queue_depth as f64);
        gauge(&mut o, "singlequant_spec_acceptance_rate",
              "Fraction of proposed draft tokens accepted by the verifier.",
              self.spec_acceptance_rate());
        if !self.spec_draft.is_empty() {
            // info-style gauge: the label carries the draft model kind
            let _ = writeln!(o, "# HELP singlequant_spec_draft \
                                 Active speculative draft model (info gauge).");
            let _ = writeln!(o, "# TYPE singlequant_spec_draft gauge");
            let _ = writeln!(o, "singlequant_spec_draft{{draft=\"{}\"}} 1",
                             self.spec_draft);
        }
        if !self.kernel_backend.is_empty() {
            // info-style gauge: the label carries the selected path
            let _ = writeln!(o, "# HELP singlequant_kernel_backend \
                                 Selected compute kernel (info gauge).");
            let _ = writeln!(o, "# TYPE singlequant_kernel_backend gauge");
            let _ = writeln!(o, "singlequant_kernel_backend{{kernel=\"{}\"}} 1",
                             self.kernel_backend);
        }

        let quantiles = |o: &mut String, name: &str, help: &str, h: &Histogram| {
            let _ = writeln!(o, "# HELP {name} {help}");
            let _ = writeln!(o, "# TYPE {name} gauge");
            for (label, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                let _ = writeln!(
                    o, "{name}{{quantile=\"{label}\"}} {}", h.percentile(p)
                );
            }
            let _ = writeln!(o, "{name}_count {}", h.count());
        };
        quantiles(&mut o, "singlequant_ttft_seconds",
                  "Time to first token.", &self.ttft);
        quantiles(&mut o, "singlequant_per_token_seconds",
                  "Decode latency per generated token.", &self.per_token);
        quantiles(&mut o, "singlequant_latency_seconds",
                  "Total request latency.", &self.latency);
        quantiles(&mut o, "singlequant_queue_wait_seconds",
                  "Admission-queue wait.", &self.queue_wait);
        quantiles(&mut o, "singlequant_decode_wave_seconds",
                  "Backend decode wave duration (one step across all \
                   active slots).", &self.decode_step);
        quantiles(&mut o, "singlequant_spec_wave_len",
                  "Tokens emitted per speculative wave (1 = no draft \
                   token survived).", &self.spec_wave_len);

        counter(&mut o, "singlequant_prefill_seconds_total",
                "Wall time inside backend prefill calls.", self.prefill_seconds);
        counter(&mut o, "singlequant_decode_seconds_total",
                "Wall time inside backend decode waves.", self.decode_seconds);

        let _ = writeln!(o, "# HELP singlequant_throughput_tokens_per_second \
                             Decode throughput over the engine lifetime.");
        let _ = writeln!(o, "# TYPE singlequant_throughput_tokens_per_second gauge");
        let _ = writeln!(o, "singlequant_throughput_tokens_per_second {}",
                         self.decode_tokens_per_s());
        let _ = writeln!(o, "# HELP singlequant_decode_tokens_per_second \
                             Tokens per second of time spent decoding.");
        let _ = writeln!(o, "# TYPE singlequant_decode_tokens_per_second gauge");
        let _ = writeln!(o, "singlequant_decode_tokens_per_second {}",
                         self.decode_only_tokens_per_s());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn histogram_window_bounds_memory() {
        let mut h = Histogram::default();
        for i in 0..(WINDOW * 2 + 10) {
            h.record(i as f64);
        }
        assert_eq!(h.count(), WINDOW * 2 + 10, "count stays cumulative");
        assert_eq!(h.samples.len(), WINDOW, "storage is bounded");
        // quantiles describe the most recent window only
        assert!(h.percentile(0.0) >= WINDOW as f64);
    }

    #[test]
    fn decode_split_metrics() {
        let mut m = ServeMetrics::default();
        m.generated_tokens = 112;
        m.decode_tokens = 100;
        m.prefill_seconds = 1.0;
        m.decode_seconds = 4.0;
        assert!((m.decode_only_tokens_per_s() - 25.0).abs() < 1e-9);
        assert!((m.prefill_time_fraction() - 0.2).abs() < 1e-9);
        let text = m.prometheus();
        assert!(text.contains("singlequant_prefill_seconds_total 1"));
        assert!(text.contains("singlequant_decode_seconds_total 4"));
        assert!(text.contains("singlequant_decode_tokens_per_second 25"));
        // zero decode time must not divide by zero
        assert_eq!(ServeMetrics::default().decode_only_tokens_per_s(), 0.0);
        assert_eq!(ServeMetrics::default().prefill_time_fraction(), 0.0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut m = ServeMetrics::default();
        m.completed = 3;
        m.rejected = 1;
        m.generated_tokens = 40;
        m.ttft.record(0.010);
        m.ttft.record(0.030);
        m.per_token.record(0.002);
        m.kv_pages_total = 8;
        m.kv_pages_used = 2;
        m.preemptions = 5;
        m.kv_rejected = 4;
        m.kernel_backend = "avx2".to_string();
        m.pool_queue_depth = 3;
        m.decode_step.record(0.004);
        let text = m.prometheus();
        assert!(text.contains("singlequant_kernel_backend{kernel=\"avx2\"} 1"));
        assert!(text.contains("singlequant_pool_queue_depth 3"));
        assert!(text.contains("singlequant_decode_wave_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("singlequant_requests_completed_total 3"));
        assert!(text.contains("singlequant_requests_rejected_total 1"));
        assert!(text.contains("singlequant_kv_pages_total 8"));
        assert!(text.contains("singlequant_kv_pages_used 2"));
        assert!(text.contains("singlequant_kv_pool_utilization 0.25"));
        assert!(text.contains("singlequant_preemptions_total 5"));
        assert!(text.contains("singlequant_kv_admission_rejected_total 4"));
        assert!(text.contains("# TYPE singlequant_kv_pages_used gauge"));
        assert!(text.contains("singlequant_ttft_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("singlequant_per_token_seconds{quantile=\"0.95\"}"));
        assert!(text.contains("# TYPE singlequant_tokens_generated_total counter"));
        // every non-comment line is "name[{labels}] value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }

    #[test]
    fn spec_metrics_exposition() {
        let mut m = ServeMetrics::default();
        // speculation off: counters still exported (always-present series
        // are easier to alert on), info gauge and summary section absent
        let off = m.prometheus();
        assert!(off.contains("singlequant_spec_proposed_total 0"));
        assert!(off.contains("singlequant_spec_acceptance_rate 0"));
        assert!(!off.contains("singlequant_spec_draft{"));
        assert!(!m.summary().contains("spec["));
        assert_eq!(m.spec_acceptance_rate(), 0.0, "no division by zero");

        m.spec_draft = "ngram".to_string();
        m.spec_proposed = 8;
        m.spec_accepted = 6;
        m.spec_wave_len.record(4.0);
        m.spec_wave_len.record(1.0);
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-9);
        let text = m.prometheus();
        assert!(text.contains("singlequant_spec_proposed_total 8"));
        assert!(text.contains("singlequant_spec_accepted_total 6"));
        assert!(text.contains("singlequant_spec_acceptance_rate 0.75"));
        assert!(text.contains("singlequant_spec_draft{draft=\"ngram\"} 1"));
        assert!(text.contains("singlequant_spec_wave_len{quantile=\"0.5\"}"));
        assert!(text.contains("singlequant_spec_wave_len_count 2"));
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
        let s = m.summary();
        assert!(s.contains("spec[ngram] proposed=8 accepted=6 rate=75%"), "{s}");
    }

    #[test]
    fn kernel_info_gauge_absent_until_stamped() {
        let m = ServeMetrics::default();
        assert!(!m.prometheus().contains("singlequant_kernel_backend"));
        assert!(m.summary().contains("kernel=?"));
        let mut m2 = ServeMetrics::default();
        m2.kernel_backend = "scalar".to_string();
        assert!(m2.summary().contains("kernel=scalar"));
    }
}
