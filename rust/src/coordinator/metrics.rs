//! Serving metrics: latency histograms + throughput counters (the Fig. 3
//! measurement surface).

#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub ttft: Histogram,
    pub latency: Histogram,
    pub decode_step: Histogram,
    pub prefill_call: Histogram,
    pub completed: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall_s
    }

    pub fn summary(&self) -> String {
        format!(
            "completed={} gen_tokens={} wall={:.2}s throughput={:.1} tok/s \
             ttft p50={:.1}ms p95={:.1}ms latency p50={:.1}ms decode_step p50={:.2}ms",
            self.completed,
            self.generated_tokens,
            self.wall_s,
            self.decode_tokens_per_s(),
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(95.0) * 1e3,
            self.latency.percentile(50.0) * 1e3,
            self.decode_step.percentile(50.0) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }
}
