//! The decode backend the continuous batcher schedules over.
//!
//! [`ServeBackend`] abstracts the fixed-shape prefill/decode graphs so the
//! scheduler is independent of PJRT: production uses
//! `crate::runtime::RunnerBackend` (AOT HLO graphs + device-resident KV),
//! while tests, the HTTP integration suite, and the load generator use the
//! deterministic [`SyntheticBackend`] — no artifacts, no XLA.

use std::time::Duration;

use anyhow::Result;

use super::tokenizer::{PAD, VOCAB_SIZE};
use crate::tensor::Tensor;

/// Static shape limits of a backend's lowered serving graphs.
#[derive(Clone, Copy, Debug)]
pub struct BackendLimits {
    /// Slot count (the lowered serve batch size).
    pub batch: usize,
    /// Prefill width: max admissible prompt length.
    pub score_seq: usize,
    pub vocab_size: usize,
    /// KV-cache horizon: prompt + generation must stay below this.
    pub max_seq: usize,
}

/// Snapshot of a backend's paged KV pool, read by the batcher's
/// admission gate and exported as gauges. `None` from
/// [`ServeBackend::kv_pool`] means the backend has no KV budget (its
/// caches are sized for the worst case) and the gate is a no-op.
#[derive(Clone, Copy, Debug)]
pub struct KvPoolStatus {
    /// Positions per page.
    pub page_tokens: usize,
    pub pages_total: usize,
    pub pages_free: usize,
}

impl KvPoolStatus {
    pub fn pages_used(&self) -> usize {
        self.pages_total - self.pages_free
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }
}

/// A model the batcher can drive: one padded prefill per admission wave,
/// one decode step per tick. Implementations own their KV state; the
/// scheduler only tracks per-slot positions.
pub trait ServeBackend: Send {
    fn limits(&self) -> BackendLimits;

    /// Prefill a right-padded `[batch * score_seq]` token matrix (PAD in
    /// unused cells) and merge the KV rows of `admitted` slots into the
    /// live cache. Returns the full prefill logits `[batch, score_seq,
    /// vocab]`; the scheduler reads each admitted prompt's logits at its
    /// true last index.
    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor>;

    /// One decode wave at per-slot positions (`tokens`/`positions` are
    /// `[batch]`, PAD/0 in inactive slots). Returns logits `[batch,
    /// vocab]` and advances the KV cache in place.
    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor>;

    /// A slot finished (EOS/length/deadline/cancel/abort): drop any
    /// per-slot backend state — e.g. the native backend frees the slot's
    /// KV rows here (returning its pages to the pool in paged mode).
    /// Backends whose per-slot state is overwritten on the next prefill
    /// (the fixed-shape PJRT cache, the synthetic model) keep the default
    /// no-op.
    fn retire(&mut self, _slot: usize) {}

    /// Paged-KV pool status; `None` disables KV admission gating.
    fn kv_pool(&self) -> Option<KvPoolStatus> {
        None
    }

    /// Reserve KV capacity for `extra` more positions in `slot` ahead of
    /// the prefill/decode that will write them. Returns `false` when the
    /// pool cannot cover the reservation *right now* (nothing is
    /// allocated in that case); backends without a KV budget always
    /// succeed. The batcher reserves prompt pages at admission and one
    /// position per slot before each decode wave, so pool exhaustion
    /// surfaces here — as admission backpressure or preemption — and
    /// never as a step error.
    fn kv_reserve(&mut self, _slot: usize, _extra: usize) -> bool {
        true
    }

    /// Which compute kernel the backend executes on ("scalar" / "avx2" /
    /// "neon" for the native backend); surfaces on `/metrics` and in the
    /// shutdown summary. Backends without CPU kernels report "n/a".
    fn kernel_label(&self) -> &'static str {
        "n/a"
    }

    /// Speculative burst decode. `bursts[slot]` is `[pending_last_token,
    /// draft_1..draft_k]` (empty = inactive slot) starting at
    /// `positions[slot]`; the backend consumes the burst and returns that
    /// slot's logits rows `[L', vocab]` — row `i` the next-token
    /// distribution after the first `i + 1` burst tokens, bit-identical
    /// to calling [`decode`] `i + 1` times with those tokens. `L'` may be
    /// *less* than the submitted burst length: a backend under KV pool
    /// pressure degrades a slot to `L' = 1` (a plain decode step, covered
    /// by the batcher's pre-wave one-position reservation) instead of
    /// erroring, so speculation never breaks the reserve/preempt
    /// contract. The scheduler rolls rejected rows back with
    /// [`kv_truncate`]. Only meaningful when [`supports_speculative`]
    /// returns true; the default refuses.
    ///
    /// [`decode`]: ServeBackend::decode
    /// [`kv_truncate`]: ServeBackend::kv_truncate
    /// [`supports_speculative`]: ServeBackend::supports_speculative
    fn decode_burst(
        &mut self,
        bursts: &[Vec<u16>],
        positions: &[i32],
    ) -> Result<Vec<Option<Tensor>>> {
        let _ = (bursts, positions);
        anyhow::bail!("backend has no speculative burst decode path")
    }

    /// Roll `slot`'s KV cache back to exactly `n` committed positions,
    /// dropping rejected speculative rows (paged backends return the
    /// freed pages to the pool). Only meaningful when
    /// [`supports_speculative`] returns true.
    ///
    /// [`supports_speculative`]: ServeBackend::supports_speculative
    fn kv_truncate(&mut self, _slot: usize, _n: usize) {}

    /// Whether [`decode_burst`]/[`kv_truncate`] are implemented — the
    /// gate for `ServeEngine::enable_speculation`.
    ///
    /// [`decode_burst`]: ServeBackend::decode_burst
    /// [`kv_truncate`]: ServeBackend::kv_truncate
    fn supports_speculative(&self) -> bool {
        false
    }
}

/// Deterministic model-free backend: the "token calculator".
///
/// Greedy sampling over it yields, for a prompt `p`, first token
/// `(sum(p) + len(p) - 1) mod 256` and then each next token
/// `(prev + 1) mod 256` — prompt-dependent, slot-isolated, and trivially
/// checkable by tests. An optional per-call delay simulates model latency
/// so overload/backpressure behavior can be exercised deterministically.
pub struct SyntheticBackend {
    limits: BackendLimits,
    step_delay: Duration,
    pool: Option<SynthKvPool>,
}

/// Book-keeping-only KV pool (no storage): tracks pages per slot with
/// the same all-or-nothing reserve semantics as `kv::BlockPool`, so
/// batcher admission/preemption logic is testable without a model.
struct SynthKvPool {
    page_tokens: usize,
    pages_total: usize,
    pages_free: usize,
    slot_pages: Vec<usize>,
    slot_pos: Vec<usize>,
}

impl SynthKvPool {
    fn reserve(&mut self, slot: usize, extra: usize) -> bool {
        let needed = (self.slot_pos[slot] + extra).div_ceil(self.page_tokens);
        let missing = needed.saturating_sub(self.slot_pages[slot]);
        if missing > self.pages_free {
            return false;
        }
        self.pages_free -= missing;
        self.slot_pages[slot] += missing;
        true
    }

    fn release(&mut self, slot: usize) {
        self.pages_free += self.slot_pages[slot];
        self.slot_pages[slot] = 0;
        self.slot_pos[slot] = 0;
    }

    /// Roll `slot` back to `n` positions, returning whole pages past the
    /// one holding position `n - 1` — same accounting as
    /// `kv::PageTable::truncate`.
    fn truncate(&mut self, slot: usize, n: usize) {
        debug_assert!(n <= self.slot_pos[slot], "truncate beyond slot position");
        let keep = n.div_ceil(self.page_tokens);
        let dropped = self.slot_pages[slot].saturating_sub(keep);
        self.slot_pages[slot] -= dropped;
        self.pages_free += dropped;
        self.slot_pos[slot] = n;
    }
}

impl SyntheticBackend {
    pub fn new(batch: usize) -> SyntheticBackend {
        SyntheticBackend {
            limits: BackendLimits {
                batch,
                score_seq: 96,
                vocab_size: VOCAB_SIZE,
                max_seq: 160,
            },
            step_delay: Duration::ZERO,
            pool: None,
        }
    }

    /// Attach a book-keeping KV pool so the batcher's admission gate and
    /// preemption path run against this backend.
    pub fn with_kv_pool(mut self, page_tokens: usize, pages: usize) -> SyntheticBackend {
        let batch = self.limits.batch;
        self.pool = Some(SynthKvPool {
            page_tokens,
            pages_total: pages,
            pages_free: pages,
            slot_pages: vec![0; batch],
            slot_pos: vec![0; batch],
        });
        self
    }

    /// Simulated per-call latency (applied to prefill and decode alike).
    pub fn with_delay(mut self, d: Duration) -> SyntheticBackend {
        self.step_delay = d;
        self
    }

    pub fn with_seq(mut self, score_seq: usize, max_seq: usize) -> SyntheticBackend {
        self.limits.score_seq = score_seq;
        self.limits.max_seq = max_seq;
        self
    }

    /// The token this backend emits after seeing `prev`.
    pub fn next_token(prev: u16) -> u16 {
        (prev + 1) % 256
    }

    /// The first token this backend emits for a prompt.
    pub fn first_token(prompt: &[u16]) -> u16 {
        let sum: u32 = prompt.iter().map(|&t| t as u32).sum();
        ((sum + prompt.len() as u32 - 1) % 256) as u16
    }
}

impl ServeBackend for SyntheticBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let BackendLimits { batch, score_seq: t, vocab_size: v, .. } = self.limits;
        anyhow::ensure!(tokens.len() == batch * t, "prefill shape mismatch");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        if let Some(pool) = &mut self.pool {
            // strict accounting: the batcher must have reserved prompt
            // pages at admission; a shortfall here is a scheduler bug
            for &slot in admitted {
                let plen = tokens[slot * t..(slot + 1) * t]
                    .iter()
                    .take_while(|&&tok| tok != PAD as i32)
                    .count();
                anyhow::ensure!(pool.reserve(slot, plen),
                                "prefill without a KV reservation in slot {slot}");
                pool.slot_pos[slot] = plen;
            }
        }
        let mut logits = Tensor::zeros(&[batch, t, v]);
        for slot in 0..batch {
            let mut sum: u32 = 0;
            for p in 0..t {
                let tok = tokens[slot * t + p];
                if tok == PAD as i32 {
                    continue;
                }
                sum += tok as u32;
                let arg = ((sum + p as u32) % 256) as usize;
                logits.data_mut()[(slot * t + p) * v + arg] = 1.0;
            }
        }
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        anyhow::ensure!(tokens.len() == batch && positions.len() == batch,
                        "decode shape mismatch");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut logits = Tensor::zeros(&[batch, v]);
        for slot in 0..batch {
            let tok = tokens[slot];
            if tok == PAD as i32 {
                continue;
            }
            if let Some(pool) = &mut self.pool {
                anyhow::ensure!(pool.reserve(slot, 1),
                                "decode without a KV reservation in slot {slot}");
                pool.slot_pos[slot] += 1;
            }
            let arg = Self::next_token(tok as u16) as usize;
            logits.data_mut()[slot * v + arg] = 1.0;
        }
        Ok(logits)
    }

    fn retire(&mut self, slot: usize) {
        if let Some(pool) = &mut self.pool {
            pool.release(slot);
        }
    }

    fn kv_pool(&self) -> Option<KvPoolStatus> {
        self.pool.as_ref().map(|p| KvPoolStatus {
            page_tokens: p.page_tokens,
            pages_total: p.pages_total,
            pages_free: p.pages_free,
        })
    }

    fn kv_reserve(&mut self, slot: usize, extra: usize) -> bool {
        match &mut self.pool {
            Some(pool) => pool.reserve(slot, extra),
            None => true,
        }
    }

    fn decode_burst(
        &mut self,
        bursts: &[Vec<u16>],
        positions: &[i32],
    ) -> Result<Vec<Option<Tensor>>> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        anyhow::ensure!(bursts.len() == batch && positions.len() == batch,
                        "burst shape mismatch");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::with_capacity(batch);
        for slot in 0..batch {
            if bursts[slot].is_empty() {
                out.push(None);
                continue;
            }
            // opportunistic burst reservation: degrade to a plain
            // single-token step under pool pressure — the batcher's
            // pre-wave reservation guarantees that one position
            let mut l = bursts[slot].len();
            if let Some(pool) = &mut self.pool {
                if l > 1 && !pool.reserve(slot, l) {
                    l = 1;
                }
                anyhow::ensure!(pool.reserve(slot, l),
                                "burst decode without a KV reservation in slot {slot}");
                pool.slot_pos[slot] += l;
            }
            // row i = the token that follows bursts[slot][i] — exactly
            // what `decode` would return fed the same tokens one by one
            let mut rows = Tensor::zeros(&[l, v]);
            for (i, &tok) in bursts[slot][..l].iter().enumerate() {
                let arg = Self::next_token(tok) as usize;
                rows.data_mut()[i * v + arg] = 1.0;
            }
            out.push(Some(rows));
        }
        Ok(out)
    }

    fn kv_truncate(&mut self, slot: usize, n: usize) {
        if let Some(pool) = &mut self.pool {
            pool.truncate(slot, n);
        }
    }

    fn supports_speculative(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_argmax_matches_first_token() {
        let mut be = SyntheticBackend::new(2).with_seq(8, 16);
        let prompt: Vec<u16> = vec![10, 20, 30];
        let mut tokens = vec![PAD as i32; 2 * 8];
        for (j, &t) in prompt.iter().enumerate() {
            tokens[j] = t as i32;
        }
        let logits = be.prefill(&tokens, &[0]).unwrap();
        let v = be.limits().vocab_size;
        let row = &logits.data()[(prompt.len() - 1) * v..prompt.len() * v];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(arg as u16, SyntheticBackend::first_token(&prompt));
    }

    #[test]
    fn burst_rows_match_sequential_decode() {
        let mut be = SyntheticBackend::new(2).with_seq(8, 16);
        let bursts = vec![vec![41u16, 42, 43], Vec::new()];
        let out = be.decode_burst(&bursts, &[5, 0]).unwrap();
        assert!(out[1].is_none(), "empty burst = inactive slot");
        let rows = out[0].as_ref().unwrap();
        let v = be.limits().vocab_size;
        assert_eq!(rows.shape(), &[3, v]);
        for (i, &tok) in bursts[0].iter().enumerate() {
            let row = &rows.data()[i * v..(i + 1) * v];
            let arg = row.iter().position(|&x| x == 1.0).unwrap();
            assert_eq!(arg as u16, SyntheticBackend::next_token(tok),
                       "row {i} must match one-at-a-time decode");
        }
    }

    #[test]
    fn burst_degrades_to_single_step_under_pool_pressure() {
        let mut be = SyntheticBackend::new(1).with_seq(8, 16).with_kv_pool(2, 2);
        // the prefill path: two prompt positions, then the batcher's
        // pre-wave single-position reservation
        assert!(be.kv_reserve(0, 2));
        be.pool.as_mut().unwrap().slot_pos[0] = 2;
        assert!(be.kv_reserve(0, 1));
        // a 3-token burst would need a third page: degraded to one row
        let out = be.decode_burst(&[vec![10, 11, 12]], &[2]).unwrap();
        assert_eq!(out[0].as_ref().unwrap().shape()[0], 1,
                   "pool pressure degrades the burst, never errors");
        let p = be.pool.as_ref().unwrap();
        assert_eq!((p.slot_pos[0], p.pages_free), (3, 0));
        // speculative rollback returns whole freed pages to the pool
        be.kv_truncate(0, 2);
        let p = be.pool.as_ref().unwrap();
        assert_eq!((p.slot_pos[0], p.pages_free), (2, 1));
    }

    #[test]
    fn decode_increments() {
        let mut be = SyntheticBackend::new(1);
        let logits = be.decode(&[41], &[5]).unwrap();
        let v = be.limits().vocab_size;
        let arg = logits.data()[..v]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(arg, 42);
    }
}
