//! The decode backend the continuous batcher schedules over.
//!
//! [`ServeBackend`] abstracts the fixed-shape prefill/decode graphs so the
//! scheduler is independent of PJRT: production uses
//! `crate::runtime::RunnerBackend` (AOT HLO graphs + device-resident KV),
//! while tests, the HTTP integration suite, and the load generator use the
//! deterministic [`SyntheticBackend`] — no artifacts, no XLA.

use std::time::Duration;

use anyhow::Result;

use super::tokenizer::{PAD, VOCAB_SIZE};
use crate::tensor::Tensor;

/// Static shape limits of a backend's lowered serving graphs.
#[derive(Clone, Copy, Debug)]
pub struct BackendLimits {
    /// Slot count (the lowered serve batch size).
    pub batch: usize,
    /// Prefill width: max admissible prompt length.
    pub score_seq: usize,
    pub vocab_size: usize,
    /// KV-cache horizon: prompt + generation must stay below this.
    pub max_seq: usize,
}

/// Snapshot of a backend's paged KV pool, read by the batcher's
/// admission gate and exported as gauges. `None` from
/// [`ServeBackend::kv_pool`] means the backend has no KV budget (its
/// caches are sized for the worst case) and the gate is a no-op.
#[derive(Clone, Copy, Debug)]
pub struct KvPoolStatus {
    /// Positions per page.
    pub page_tokens: usize,
    pub pages_total: usize,
    pub pages_free: usize,
}

impl KvPoolStatus {
    pub fn pages_used(&self) -> usize {
        self.pages_total - self.pages_free
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }
}

/// A model the batcher can drive: one padded prefill per admission wave,
/// one decode step per tick. Implementations own their KV state; the
/// scheduler only tracks per-slot positions.
pub trait ServeBackend: Send {
    fn limits(&self) -> BackendLimits;

    /// Prefill a right-padded `[batch * score_seq]` token matrix (PAD in
    /// unused cells) and merge the KV rows of `admitted` slots into the
    /// live cache. Returns the full prefill logits `[batch, score_seq,
    /// vocab]`; the scheduler reads each admitted prompt's logits at its
    /// true last index.
    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor>;

    /// One decode wave at per-slot positions (`tokens`/`positions` are
    /// `[batch]`, PAD/0 in inactive slots). Returns logits `[batch,
    /// vocab]` and advances the KV cache in place.
    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor>;

    /// A slot finished (EOS/length/deadline/cancel/abort): drop any
    /// per-slot backend state — e.g. the native backend frees the slot's
    /// KV rows here (returning its pages to the pool in paged mode).
    /// Backends whose per-slot state is overwritten on the next prefill
    /// (the fixed-shape PJRT cache, the synthetic model) keep the default
    /// no-op.
    fn retire(&mut self, _slot: usize) {}

    /// Paged-KV pool status; `None` disables KV admission gating.
    fn kv_pool(&self) -> Option<KvPoolStatus> {
        None
    }

    /// Reserve KV capacity for `extra` more positions in `slot` ahead of
    /// the prefill/decode that will write them. Returns `false` when the
    /// pool cannot cover the reservation *right now* (nothing is
    /// allocated in that case); backends without a KV budget always
    /// succeed. The batcher reserves prompt pages at admission and one
    /// position per slot before each decode wave, so pool exhaustion
    /// surfaces here — as admission backpressure or preemption — and
    /// never as a step error.
    fn kv_reserve(&mut self, _slot: usize, _extra: usize) -> bool {
        true
    }

    /// Which compute kernel the backend executes on ("scalar" / "avx2" /
    /// "neon" for the native backend); surfaces on `/metrics` and in the
    /// shutdown summary. Backends without CPU kernels report "n/a".
    fn kernel_label(&self) -> &'static str {
        "n/a"
    }
}

/// Deterministic model-free backend: the "token calculator".
///
/// Greedy sampling over it yields, for a prompt `p`, first token
/// `(sum(p) + len(p) - 1) mod 256` and then each next token
/// `(prev + 1) mod 256` — prompt-dependent, slot-isolated, and trivially
/// checkable by tests. An optional per-call delay simulates model latency
/// so overload/backpressure behavior can be exercised deterministically.
pub struct SyntheticBackend {
    limits: BackendLimits,
    step_delay: Duration,
    pool: Option<SynthKvPool>,
}

/// Book-keeping-only KV pool (no storage): tracks pages per slot with
/// the same all-or-nothing reserve semantics as `kv::BlockPool`, so
/// batcher admission/preemption logic is testable without a model.
struct SynthKvPool {
    page_tokens: usize,
    pages_total: usize,
    pages_free: usize,
    slot_pages: Vec<usize>,
    slot_pos: Vec<usize>,
}

impl SynthKvPool {
    fn reserve(&mut self, slot: usize, extra: usize) -> bool {
        let needed = (self.slot_pos[slot] + extra).div_ceil(self.page_tokens);
        let missing = needed.saturating_sub(self.slot_pages[slot]);
        if missing > self.pages_free {
            return false;
        }
        self.pages_free -= missing;
        self.slot_pages[slot] += missing;
        true
    }

    fn release(&mut self, slot: usize) {
        self.pages_free += self.slot_pages[slot];
        self.slot_pages[slot] = 0;
        self.slot_pos[slot] = 0;
    }
}

impl SyntheticBackend {
    pub fn new(batch: usize) -> SyntheticBackend {
        SyntheticBackend {
            limits: BackendLimits {
                batch,
                score_seq: 96,
                vocab_size: VOCAB_SIZE,
                max_seq: 160,
            },
            step_delay: Duration::ZERO,
            pool: None,
        }
    }

    /// Attach a book-keeping KV pool so the batcher's admission gate and
    /// preemption path run against this backend.
    pub fn with_kv_pool(mut self, page_tokens: usize, pages: usize) -> SyntheticBackend {
        let batch = self.limits.batch;
        self.pool = Some(SynthKvPool {
            page_tokens,
            pages_total: pages,
            pages_free: pages,
            slot_pages: vec![0; batch],
            slot_pos: vec![0; batch],
        });
        self
    }

    /// Simulated per-call latency (applied to prefill and decode alike).
    pub fn with_delay(mut self, d: Duration) -> SyntheticBackend {
        self.step_delay = d;
        self
    }

    pub fn with_seq(mut self, score_seq: usize, max_seq: usize) -> SyntheticBackend {
        self.limits.score_seq = score_seq;
        self.limits.max_seq = max_seq;
        self
    }

    /// The token this backend emits after seeing `prev`.
    pub fn next_token(prev: u16) -> u16 {
        (prev + 1) % 256
    }

    /// The first token this backend emits for a prompt.
    pub fn first_token(prompt: &[u16]) -> u16 {
        let sum: u32 = prompt.iter().map(|&t| t as u32).sum();
        ((sum + prompt.len() as u32 - 1) % 256) as u16
    }
}

impl ServeBackend for SyntheticBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let BackendLimits { batch, score_seq: t, vocab_size: v, .. } = self.limits;
        anyhow::ensure!(tokens.len() == batch * t, "prefill shape mismatch");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        if let Some(pool) = &mut self.pool {
            // strict accounting: the batcher must have reserved prompt
            // pages at admission; a shortfall here is a scheduler bug
            for &slot in admitted {
                let plen = tokens[slot * t..(slot + 1) * t]
                    .iter()
                    .take_while(|&&tok| tok != PAD as i32)
                    .count();
                anyhow::ensure!(pool.reserve(slot, plen),
                                "prefill without a KV reservation in slot {slot}");
                pool.slot_pos[slot] = plen;
            }
        }
        let mut logits = Tensor::zeros(&[batch, t, v]);
        for slot in 0..batch {
            let mut sum: u32 = 0;
            for p in 0..t {
                let tok = tokens[slot * t + p];
                if tok == PAD as i32 {
                    continue;
                }
                sum += tok as u32;
                let arg = ((sum + p as u32) % 256) as usize;
                logits.data_mut()[(slot * t + p) * v + arg] = 1.0;
            }
        }
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        anyhow::ensure!(tokens.len() == batch && positions.len() == batch,
                        "decode shape mismatch");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut logits = Tensor::zeros(&[batch, v]);
        for slot in 0..batch {
            let tok = tokens[slot];
            if tok == PAD as i32 {
                continue;
            }
            if let Some(pool) = &mut self.pool {
                anyhow::ensure!(pool.reserve(slot, 1),
                                "decode without a KV reservation in slot {slot}");
                pool.slot_pos[slot] += 1;
            }
            let arg = Self::next_token(tok as u16) as usize;
            logits.data_mut()[slot * v + arg] = 1.0;
        }
        Ok(logits)
    }

    fn retire(&mut self, slot: usize) {
        if let Some(pool) = &mut self.pool {
            pool.release(slot);
        }
    }

    fn kv_pool(&self) -> Option<KvPoolStatus> {
        self.pool.as_ref().map(|p| KvPoolStatus {
            page_tokens: p.page_tokens,
            pages_total: p.pages_total,
            pages_free: p.pages_free,
        })
    }

    fn kv_reserve(&mut self, slot: usize, extra: usize) -> bool {
        match &mut self.pool {
            Some(pool) => pool.reserve(slot, extra),
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_argmax_matches_first_token() {
        let mut be = SyntheticBackend::new(2).with_seq(8, 16);
        let prompt: Vec<u16> = vec![10, 20, 30];
        let mut tokens = vec![PAD as i32; 2 * 8];
        for (j, &t) in prompt.iter().enumerate() {
            tokens[j] = t as i32;
        }
        let logits = be.prefill(&tokens, &[0]).unwrap();
        let v = be.limits().vocab_size;
        let row = &logits.data()[(prompt.len() - 1) * v..prompt.len() * v];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(arg as u16, SyntheticBackend::first_token(&prompt));
    }

    #[test]
    fn decode_increments() {
        let mut be = SyntheticBackend::new(1);
        let logits = be.decode(&[41], &[5]).unwrap();
        let v = be.limits().vocab_size;
        let arg = logits.data()[..v]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(arg, 42);
    }
}
