//! [`ServeBackend`] over [`NativeModel`]: pure-CPU serving of packed
//! quantized checkpoints — no PJRT, no XLA stub, no artifacts on disk.
//!
//! Two KV layouts behind one backend:
//!
//! * **Contiguous** (default): one growable [`SlotKv`] per batcher slot,
//!   each able to reach `max_seq` rows — simple, but the memory budget
//!   must assume every slot hits the worst case.
//! * **Paged** (`with_paged_kv`): one shared [`BlockPool`] plus a
//!   [`PageTable`] per slot. Memory follows the live token count, the
//!   batcher reads the pool through [`ServeBackend::kv_pool`] /
//!   [`ServeBackend::kv_reserve`] to gate admission and trigger
//!   preemption, and `retire(slot)` returns the slot's pages to the free
//!   list. Reads are bit-identical to the contiguous layout (pinned by
//!   the property tests in `model::native`).
//!
//! `decode` runs active slots as a **parallel wave** over the worker
//! pool: a serial pre-pass validates positions and reserves KV capacity
//! (all-or-nothing, so failure leaves every slot replayable), the
//! parallel phase gives each slot a read-only base view plus a
//! [`WaveOverlay`] for its fresh rows, and a serial ascending-slot
//! write-back commits. Per-slot results are bit-equal to the serial
//! walk: each slot reads exactly the committed rows plus its own
//! buffered ones, and the kernels are thread-count invariant.

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::backend::{BackendLimits, KvPoolStatus, ServeBackend};
use crate::coordinator::tokenizer::PAD;
use crate::kv::{BlockPool, KvCache, KvRows, PageTable, PagedReader, PagedSlot, SlotKv,
                WaveOverlay, WaveRows};
use crate::model::NativeModel;
use crate::tensor::pool::{self, SendPtr};
use crate::tensor::simd;
use crate::tensor::Tensor;

enum KvSlots {
    Contig(Vec<SlotKv>),
    Paged { pool: BlockPool, tables: Vec<PageTable> },
}

pub struct NativeBackend {
    model: NativeModel,
    kv: KvSlots,
    limits: BackendLimits,
}

impl NativeBackend {
    pub fn new(model: NativeModel, batch: usize) -> NativeBackend {
        let limits = Self::limits_for(&model, batch);
        let slots = (0..batch).map(|_| model.new_kv()).collect();
        NativeBackend { model, kv: KvSlots::Contig(slots), limits }
    }

    /// Paged-KV backend: `pool_pages` pages of `page_tokens` positions
    /// shared by all `batch` slots. `pool_pages = 0` auto-sizes the pool
    /// to the contiguous worst case (`batch × ⌈max_seq / page_tokens⌉`),
    /// which can never reject or preempt — pass an explicit smaller pool
    /// to actually overcommit.
    pub fn with_paged_kv(
        model: NativeModel,
        batch: usize,
        page_tokens: usize,
        pool_pages: usize,
    ) -> NativeBackend {
        let limits = Self::limits_for(&model, batch);
        let pages = if pool_pages == 0 {
            batch * model.cfg.max_seq.div_ceil(page_tokens)
        } else {
            pool_pages
        };
        let pool = BlockPool::new(model.cfg.n_layers, model.cfg.d_model,
                                  page_tokens, pages);
        let tables = (0..batch).map(|_| PageTable::new()).collect();
        NativeBackend { model, kv: KvSlots::Paged { pool, tables }, limits }
    }

    fn limits_for(model: &NativeModel, batch: usize) -> BackendLimits {
        BackendLimits {
            batch,
            score_seq: model.cfg.score_seq,
            vocab_size: model.cfg.vocab_size,
            max_seq: model.cfg.max_seq,
        }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Resident KV bytes: rows held by contiguous slots, or used pages
    /// (the arena is allocated up front; this reports the live share).
    pub fn kv_nbytes(&self) -> usize {
        match &self.kv {
            KvSlots::Contig(slots) => slots.iter().map(|s| s.nbytes()).sum(),
            KvSlots::Paged { pool, .. } => pool.pages_used() * pool.page_nbytes(),
        }
    }

    /// Shared wave driver behind `decode` and `decode_burst`: parallel
    /// burst phase over read-only base views, all-or-nothing error
    /// scan, serial ascending-slot commit. Callers have already
    /// validated positions and reserved KV capacity for every burst.
    fn wave_and_commit(
        &mut self,
        active: &[usize],
        bursts: &[Vec<u16>],
    ) -> Result<Vec<Option<Tensor>>> {
        let batch = self.limits.batch;
        let model = &self.model;
        let mut waves = match &self.kv {
            KvSlots::Contig(slots) => run_wave(model, active, bursts, batch, |slot| {
                let base = &slots[slot];
                (base, base.pos)
            }),
            KvSlots::Paged { pool, tables } => {
                run_wave(model, active, bursts, batch, |slot| {
                    let table = &tables[slot];
                    (PagedReader { pool, table }, table.pos())
                })
            }
        };

        // any slot failure aborts the wave before a single row commits —
        // the scheduler tears down in-flight work on decode errors, and
        // partially-advanced siblings would only confuse the post-mortem
        for &slot in active {
            if !matches!(waves[slot], Some(Ok(_))) {
                return Err(match waves[slot].take() {
                    Some(Err(e)) => e,
                    _ => anyhow!("decode wave dropped slot {slot}"),
                });
            }
        }

        // serial ascending-slot write-back
        let mut out: Vec<Option<Tensor>> = (0..batch).map(|_| None).collect();
        for &slot in active {
            // the scan above guarantees Some(Ok); an engine abort is
            // never the right answer on the serving path, so a broken
            // invariant surfaces as a wave error instead
            let (rows_t, rows) = match waves[slot].take() {
                Some(Ok(x)) => x,
                _ => return Err(anyhow!("decode wave lost slot {slot} after scan")),
            };
            match &mut self.kv {
                KvSlots::Contig(slots) => rows.commit(&mut slots[slot]),
                KvSlots::Paged { pool, tables } => {
                    let mut view = PagedSlot { pool, table: &mut tables[slot] };
                    rows.commit(&mut view)
                }
            }
            .map_err(anyhow::Error::new)?;
            out[slot] = Some(rows_t);
        }
        Ok(out)
    }

    fn slot_pos(&self, slot: usize) -> usize {
        match &self.kv {
            KvSlots::Contig(slots) => slots[slot].pos,
            KvSlots::Paged { tables, .. } => tables[slot].pos(),
        }
    }

    /// Audit builds: page conservation inside the pool, plus the
    /// backend-level law that the slot tables collectively hold exactly
    /// the pages the pool says are out. Runs after every prefill,
    /// decode/spec wave, truncate, and retire.
    #[cfg(feature = "audit")]
    fn audit_kv(&self) {
        if let KvSlots::Paged { pool, tables } = &self.kv {
            pool.audit_conservation();
            let held: usize = tables.iter().map(|t| t.n_pages()).sum();
            assert_eq!(
                held,
                pool.pages_used(),
                "audit: slot tables hold {held} pages but the pool has {} out",
                pool.pages_used()
            );
        }
    }
}

/// Parallel phase of a decode wave: every active slot steps its burst
/// (one token on the plain path, `k+1` on the speculative one) against
/// a read-only view of its committed cache, buffering the new K/V rows
/// in a slot-private [`WaveOverlay`]. Slots are dispatched across the
/// worker pool; matmuls issued inside a multi-slot wave run inline on
/// the claiming worker (the pool's nested-call rule), and a single-slot
/// wave keeps full intra-matmul parallelism — either way each slot's
/// numbers are identical to the serial slot walk, and the multi-row
/// burst rows are bit-equal to feeding the burst one token at a time
/// (pinned by the rollback property tests in `model::native`).
fn run_wave<B, F>(
    model: &NativeModel,
    active: &[usize],
    bursts: &[Vec<u16>],
    batch: usize,
    base_of: F,
) -> Vec<Option<Result<(Tensor, WaveRows)>>>
where
    B: KvRows + Sync,
    F: Fn(usize) -> (B, usize) + Sync,
{
    let (n_layers, d) = (model.cfg.n_layers, model.cfg.d_model);
    let mut out: Vec<Option<Result<(Tensor, WaveRows)>>> =
        (0..batch).map(|_| None).collect();
    let cells = SendPtr::new(out.as_mut_ptr());
    pool::global().run(active.len(), |i| {
        let slot = active[i];
        let (base, base_pos) = base_of(slot);
        let mut overlay = WaveOverlay::new(base, base_pos, n_layers, d);
        let res = model
            .step_rows(&mut overlay, &bursts[slot])
            .map(|rows| (rows, overlay.into_rows()));
        // SAFETY: each chunk writes only its own slot's cell, and `out`
        // outlives the job (`run` blocks until every chunk completes).
        unsafe { *cells.get().add(slot) = Some(res) };
    });
    out
}

impl ServeBackend for NativeBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let BackendLimits { batch, score_seq: t, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch * t, "prefill shape mismatch");
        let mut logits = Tensor::zeros(&[batch, t, v]);
        for &slot in admitted {
            ensure!(slot < batch, "slot {slot} out of range");
            let row = &tokens[slot * t..(slot + 1) * t];
            let prompt: Vec<u16> = row
                .iter()
                .take_while(|&&tok| tok != PAD as i32)
                .map(|&tok| tok as u16)
                .collect();
            ensure!(!prompt.is_empty(), "empty prompt in slot {slot}");
            let lg = match &mut self.kv {
                KvSlots::Contig(slots) => {
                    slots[slot].reset();
                    self.model.prefill(&mut slots[slot], &prompt)?
                }
                KvSlots::Paged { pool, tables } => {
                    let table = &mut tables[slot];
                    if table.pos() != 0 {
                        table.release(pool);
                    }
                    let mut view = PagedSlot { pool, table };
                    self.model.prefill(&mut view, &prompt)?
                }
            };
            for p in 0..prompt.len() {
                let base = (slot * t + p) * v;
                logits.data_mut()[base..base + v].copy_from_slice(lg.row(p));
            }
        }
        #[cfg(feature = "audit")]
        self.audit_kv();
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch && positions.len() == batch,
                "decode shape mismatch");
        let mut logits = Tensor::zeros(&[batch, v]);
        let active: Vec<usize> =
            (0..batch).filter(|&s| tokens[s] != PAD as i32).collect();
        if active.is_empty() {
            return Ok(logits);
        }

        // serial pre-pass: position checks, then KV reservation for every
        // active slot before any state changes (the batcher pre-reserves,
        // making this a no-op there; direct callers get PoolExhausted
        // here with all slots still replayable)
        for &slot in &active {
            let pos = self.slot_pos(slot);
            ensure!(pos == positions[slot] as usize,
                    "slot {slot}: cache holds {pos} positions but scheduler is at {}",
                    positions[slot]);
        }
        if let KvSlots::Paged { pool, tables } = &mut self.kv {
            for &slot in &active {
                tables[slot].reserve(pool, 1).map_err(anyhow::Error::new)?;
            }
        }

        let bursts: Vec<Vec<u16>> = tokens
            .iter()
            .map(|&tok| {
                if tok == PAD as i32 { Vec::new() } else { vec![tok as u16] }
            })
            .collect();
        let rows = self.wave_and_commit(&active, &bursts)?;
        for &slot in &active {
            // wave_and_commit fills every active slot; treat a hole as
            // a wave error rather than aborting the engine
            let Some(rows_t) = rows[slot].as_ref() else {
                return Err(anyhow!("decode wave returned no rows for slot {slot}"));
            };
            logits.data_mut()[slot * v..(slot + 1) * v]
                .copy_from_slice(rows_t.row(0));
        }
        #[cfg(feature = "audit")]
        self.audit_kv();
        Ok(logits)
    }

    /// Speculative verification wave: each active slot steps its whole
    /// burst in one `step_rows` call, returning one logits row per
    /// burst token. Reservation is opportunistic — a slot whose full
    /// burst does not fit the paged pool degrades to its first token
    /// (the single step the batcher pre-reserved), so speculation can
    /// shrink under pool pressure but never fail a wave that plain
    /// decode would have survived.
    fn decode_burst(
        &mut self,
        bursts: &[Vec<u16>],
        positions: &[i32],
    ) -> Result<Vec<Option<Tensor>>> {
        let batch = self.limits.batch;
        ensure!(bursts.len() == batch && positions.len() == batch,
                "burst shape mismatch");
        let active: Vec<usize> =
            (0..batch).filter(|&s| !bursts[s].is_empty()).collect();
        if active.is_empty() {
            return Ok((0..batch).map(|_| None).collect());
        }
        for &slot in &active {
            let pos = self.slot_pos(slot);
            ensure!(pos == positions[slot] as usize,
                    "slot {slot}: cache holds {pos} positions but scheduler is at {}",
                    positions[slot]);
        }
        let mut clamped: Vec<Vec<u16>> = bursts.to_vec();
        if let KvSlots::Paged { pool, tables } = &mut self.kv {
            for &slot in &active {
                let l = clamped[slot].len();
                if l > 1 && tables[slot].reserve(pool, l).is_err() {
                    clamped[slot].truncate(1);
                }
                // the degraded single step rides the batcher's standing
                // one-position pre-reservation, so this cannot fail on
                // the serving path; direct callers surface PoolExhausted
                // here with every slot still replayable
                tables[slot]
                    .reserve(pool, clamped[slot].len())
                    .map_err(anyhow::Error::new)?;
            }
        }
        let out = self.wave_and_commit(&active, &clamped);
        #[cfg(feature = "audit")]
        self.audit_kv();
        out
    }

    fn kv_truncate(&mut self, slot: usize, n: usize) {
        match &mut self.kv {
            KvSlots::Contig(slots) => {
                if let Some(kv) = slots.get_mut(slot) {
                    kv.truncate(n);
                }
            }
            KvSlots::Paged { pool, tables } => {
                if let Some(table) = tables.get_mut(slot) {
                    table.truncate(pool, n);
                }
            }
        }
        #[cfg(feature = "audit")]
        self.audit_kv();
    }

    fn supports_speculative(&self) -> bool {
        true
    }

    fn retire(&mut self, slot: usize) {
        match &mut self.kv {
            KvSlots::Contig(slots) => {
                if let Some(kv) = slots.get_mut(slot) {
                    kv.reset();
                }
            }
            KvSlots::Paged { pool, tables } => {
                if let Some(table) = tables.get_mut(slot) {
                    table.release(pool);
                }
            }
        }
        #[cfg(feature = "audit")]
        self.audit_kv();
    }

    fn kv_pool(&self) -> Option<KvPoolStatus> {
        match &self.kv {
            KvSlots::Contig(_) => None,
            KvSlots::Paged { pool, .. } => Some(KvPoolStatus {
                page_tokens: pool.page_tokens(),
                pages_total: pool.pages_total(),
                pages_free: pool.pages_free(),
            }),
        }
    }

    fn kv_reserve(&mut self, slot: usize, extra: usize) -> bool {
        match &mut self.kv {
            KvSlots::Contig(_) => true,
            KvSlots::Paged { pool, tables } => match tables.get_mut(slot) {
                Some(table) => table.reserve(pool, extra).is_ok(),
                None => false,
            },
        }
    }

    fn kernel_label(&self) -> &'static str {
        simd::active().label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Request, ServeConfig, ServeEngine, TokenEvent};
    use crate::model::config::tests::test_config;
    use crate::model::Weights;

    fn demo_model() -> NativeModel {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 4);
        NativeModel::from_weights(&cfg, &w, None, 2).unwrap()
    }

    fn demo_backend(batch: usize) -> NativeBackend {
        NativeBackend::new(demo_model(), batch)
    }

    #[test]
    fn serves_greedy_requests_deterministically() {
        let run = || {
            let mut engine = ServeEngine::new(
                Box::new(demo_backend(2)),
                ServeConfig { max_new_cap: 4, seed: 1, queue_cap: 8 },
            );
            engine.submit(Request::new(0, vec![10, 20, 30]).with_max_new(4));
            engine.submit(Request::new(1, vec![7]).with_max_new(3));
            let mut out = engine.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].tokens.len(), 4);
        assert!(a[1].tokens.len() <= 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "greedy serving must be deterministic");
        }
    }

    #[test]
    fn retire_clears_slot_state_for_reuse() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..3].copy_from_slice(&[5, 6, 7]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.kv_nbytes() > 0);
        let first = be.decode(&[9], &[3]).unwrap();
        be.retire(0);
        // same prompt again: identical logits from a clean slot
        be.prefill(&tokens, &[0]).unwrap();
        let second = be.decode(&[9], &[3]).unwrap();
        assert_eq!(first.data(), second.data());
    }

    #[test]
    fn decode_position_mismatch_is_an_error() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..2].copy_from_slice(&[1, 2]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.decode(&[3], &[7]).is_err(), "stale position must fail loudly");
    }

    #[test]
    fn paged_backend_matches_contiguous_logits_exactly() {
        let model = demo_model();
        let cfg = model.cfg.clone();
        let mut contig = NativeBackend::new(demo_model(), 2);
        let mut paged = NativeBackend::with_paged_kv(model, 2, 7, 0);
        assert_eq!(paged.kv_pool().unwrap().pages_total,
                   2 * cfg.max_seq.div_ceil(7));
        let t = contig.limits().score_seq;
        let mut tokens = vec![PAD as i32; 2 * t];
        tokens[..3].copy_from_slice(&[5, 6, 7]);
        tokens[t..t + 2].copy_from_slice(&[11, 12]);
        assert!(paged.kv_reserve(0, 3) && paged.kv_reserve(1, 2));
        let a = contig.prefill(&tokens, &[0, 1]).unwrap();
        let b = paged.prefill(&tokens, &[0, 1]).unwrap();
        assert_eq!(a.data(), b.data(), "paged prefill logits must be bit-equal");
        for step in 0..3 {
            assert!(paged.kv_reserve(0, 1) && paged.kv_reserve(1, 1));
            let pos = [3 + step, 2 + step];
            let x = contig.decode(&[9, 13], &[pos[0], pos[1]]).unwrap();
            let y = paged.decode(&[9, 13], &[pos[0], pos[1]]).unwrap();
            assert_eq!(x.data(), y.data(), "paged decode step {step}");
        }
    }

    #[test]
    fn paged_retire_returns_pages_no_leak_after_churn() {
        let model = demo_model();
        let mut be = NativeBackend::with_paged_kv(model, 2, 4, 16);
        let t = be.limits().score_seq;
        for round in 0..8 {
            let mut tokens = vec![PAD as i32; 2 * t];
            let plen = 1 + round % 5;
            for (j, cell) in tokens[..plen].iter_mut().enumerate() {
                *cell = (10 + j) as i32;
            }
            tokens[t..t + 2].copy_from_slice(&[3, 4]);
            assert!(be.kv_reserve(0, plen) && be.kv_reserve(1, 2));
            be.prefill(&tokens, &[0, 1]).unwrap();
            assert!(be.kv_reserve(0, 1));
            be.decode(&[7, PAD as i32], &[plen as i32, 0]).unwrap();
            be.retire(0);
            be.retire(1);
            let pool = be.kv_pool().unwrap();
            assert_eq!(pool.pages_free, pool.pages_total,
                       "round {round}: pages leaked");
            assert_eq!(be.kv_nbytes(), 0);
        }
    }

    /// Decode a multi-slot wave and a set of single-slot backends over
    /// the same prompts; every step's logits must be bit-equal. This is
    /// the slot-parallel determinism contract: wave dispatch must never
    /// change the numbers, on fp and w4a4 models, contiguous and paged.
    fn check_wave_matches_serial(make: &dyn Fn(usize) -> NativeBackend) {
        let batch = 3usize;
        let prompts: [&[i32]; 3] = [&[5, 6, 7], &[11, 12], &[20, 21, 22, 23]];
        let mut wave_be = make(batch);
        let t = wave_be.limits().score_seq;
        let v = wave_be.limits().vocab_size;
        let mut tokens = vec![PAD as i32; batch * t];
        for (s, p) in prompts.iter().enumerate() {
            tokens[s * t..s * t + p.len()].copy_from_slice(p);
        }
        for s in 0..batch {
            assert!(wave_be.kv_reserve(s, prompts[s].len()));
        }
        wave_be.prefill(&tokens, &[0, 1, 2]).unwrap();

        let mut solo: Vec<NativeBackend> = (0..batch).map(|_| make(1)).collect();
        for (s, be) in solo.iter_mut().enumerate() {
            let mut tk = vec![PAD as i32; t];
            tk[..prompts[s].len()].copy_from_slice(prompts[s]);
            assert!(be.kv_reserve(0, prompts[s].len()));
            be.prefill(&tk, &[0]).unwrap();
        }

        let mut pos: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        let mut step_toks: Vec<i32> = vec![30, 31, 32];
        for step in 0..4 {
            for s in 0..batch {
                assert!(wave_be.kv_reserve(s, 1));
            }
            let wave = wave_be.decode(&step_toks, &pos).unwrap();
            for (s, be) in solo.iter_mut().enumerate() {
                assert!(be.kv_reserve(0, 1));
                let one = be.decode(&[step_toks[s]], &[pos[s]]).unwrap();
                assert_eq!(&wave.data()[s * v..(s + 1) * v], one.data(),
                           "step {step} slot {s}: wave diverged from serial");
            }
            for s in 0..batch {
                pos[s] += 1;
                step_toks[s] += 1;
            }
        }
    }

    #[test]
    fn decode_wave_matches_serial_fp_contig() {
        check_wave_matches_serial(&|batch| NativeBackend::new(demo_model(), batch));
    }

    #[test]
    fn decode_wave_matches_serial_fp_paged() {
        check_wave_matches_serial(&|batch| {
            NativeBackend::with_paged_kv(demo_model(), batch, 4, 0)
        });
    }

    fn w4a4_model() -> NativeModel {
        use crate::model::forward::QuantCtx;
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 4);
        let quant = Some(QuantCtx::identity(&cfg, 4));
        NativeModel::from_weights(&cfg, &w, quant, 2).unwrap()
    }

    #[test]
    fn decode_wave_matches_serial_w4a4_contig() {
        check_wave_matches_serial(&|batch| NativeBackend::new(w4a4_model(), batch));
    }

    #[test]
    fn decode_wave_matches_serial_w4a4_paged() {
        check_wave_matches_serial(&|batch| {
            NativeBackend::with_paged_kv(w4a4_model(), batch, 7, 0)
        });
    }

    #[test]
    fn decode_wave_pad_slots_stay_untouched() {
        let mut be = demo_backend(3);
        let t = be.limits().score_seq;
        let v = be.limits().vocab_size;
        let mut tokens = vec![PAD as i32; 3 * t];
        tokens[..2].copy_from_slice(&[5, 6]);
        tokens[2 * t..2 * t + 2].copy_from_slice(&[8, 9]);
        be.prefill(&tokens, &[0, 2]).unwrap();
        // slot 1 is PAD: its logits row stays zero and its empty cache
        // is never validated or advanced
        let lg = be.decode(&[7, PAD as i32, 10], &[2, 0, 2]).unwrap();
        assert!(lg.data()[v..2 * v].iter().all(|&x| x == 0.0));
        let lg2 = be.decode(&[8, PAD as i32, 11], &[3, 99, 3]).unwrap();
        assert!(lg2.data()[v..2 * v].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn exhausted_pool_fails_wave_before_any_commit() {
        // 2 pages of 4 tokens: slot 0 prefills 4 (1 page), slot 1
        // prefills 4 (1 page); the first decode wave needs a page per
        // slot and must fail atomically with both slots replayable
        let mut be = NativeBackend::with_paged_kv(demo_model(), 2, 4, 2);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; 2 * t];
        tokens[..4].copy_from_slice(&[5, 6, 7, 8]);
        tokens[t..t + 4].copy_from_slice(&[9, 10, 11, 12]);
        assert!(be.kv_reserve(0, 4) && be.kv_reserve(1, 4));
        be.prefill(&tokens, &[0, 1]).unwrap();
        let err = be.decode(&[1, 2], &[4, 4]).unwrap_err();
        assert!(err.downcast_ref::<crate::kv::KvError>().is_some(),
                "want KvError, got: {err}");
        // positions unchanged → both slots replayable
        let lg = be.decode(&[1, 2], &[4, 4]).unwrap_err();
        assert!(lg.downcast_ref::<crate::kv::KvError>().is_some());
    }

    /// Acceptance: with a pool far smaller than `batch × max_seq`
    /// (naive sizing `pool_pages × page_tokens / max_seq` = 48/160 → 0
    /// concurrent worst-case slots), the batcher still serves 4-way
    /// concurrency by overcommitting and preempting — zero engine
    /// aborts, every request completes, and greedy outputs are
    /// identical to an uncontended run.
    #[test]
    fn overcommitted_pool_preempts_and_replays_exactly() {
        let requests = |engine: &mut ServeEngine| {
            for i in 0..6u64 {
                let prompt: Vec<u16> = (0..6).map(|j| (10 + 3 * i as u16 + j)).collect();
                engine.submit(Request::new(i, prompt).with_max_new(12));
            }
        };
        // uncontended reference: auto-sized pool (never preempts)
        let mut ref_engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 0)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut ref_engine);
        let mut expect = ref_engine.run_to_completion().unwrap();
        expect.sort_by_key(|r| r.id);
        assert_eq!(ref_engine.metrics.preemptions, 0);

        // tight pool: 12 pages × 4 tokens = 48 positions for 4 slots
        // whose worst case is 4 × 18 = 72
        let mut engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 12)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut engine);
        let mut max_active = 0;
        let mut got = Vec::new();
        while engine.has_work() {
            let events = engine
                .step()
                .expect("pool exhaustion must never abort the engine");
            max_active = max_active.max(engine.active());
            for ev in events {
                if let TokenEvent::Done { response, .. } = ev {
                    got.push(response);
                }
            }
        }
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 6, "every request completes");
        assert!(max_active > 1, "overcommit must beat naive sizing (0-1 slots)");
        assert!(engine.metrics.preemptions > 0, "tight pool must preempt");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.id, e.id);
            assert_eq!(g.tokens, e.tokens,
                       "preempt+replay must reproduce greedy output of request {}", g.id);
        }
    }

    /// `decode_burst` rows must be bit-equal to single-token decodes of
    /// the same chain, and `kv_truncate` must leave the cache exactly
    /// at the accepted prefix — the backend half of the speculative
    /// exactness contract, on both KV layouts.
    #[test]
    fn backend_burst_rows_match_sequential_decode_and_truncate() {
        for paged in [false, true] {
            let make = || {
                if paged {
                    NativeBackend::with_paged_kv(demo_model(), 2, 4, 0)
                } else {
                    demo_backend(2)
                }
            };
            let mut seq = make();
            let mut burst = make();
            let t = seq.limits().score_seq;
            let v = seq.limits().vocab_size;
            let mut tokens = vec![PAD as i32; 2 * t];
            tokens[..3].copy_from_slice(&[5, 6, 7]);
            tokens[t..t + 2].copy_from_slice(&[11, 12]);
            for be in [&mut seq, &mut burst] {
                assert!(be.kv_reserve(0, 3) && be.kv_reserve(1, 2));
                be.prefill(&tokens, &[0, 1]).unwrap();
            }
            let chain0 = [9u16, 10, 11];
            let chain1 = [20u16, 21, 22];
            let mut want0 = Vec::new();
            let mut want1 = Vec::new();
            for s in 0..3 {
                assert!(seq.kv_reserve(0, 1) && seq.kv_reserve(1, 1));
                let lg = seq
                    .decode(&[chain0[s] as i32, chain1[s] as i32],
                            &[3 + s as i32, 2 + s as i32])
                    .unwrap();
                want0.extend_from_slice(&lg.data()[..v]);
                want1.extend_from_slice(&lg.data()[v..]);
            }
            // batcher-style single-step pre-reservation, then one burst
            assert!(burst.kv_reserve(0, 1) && burst.kv_reserve(1, 1));
            let rows = burst
                .decode_burst(&[chain0.to_vec(), chain1.to_vec()], &[3, 2])
                .unwrap();
            let r0 = rows[0].as_ref().unwrap();
            let r1 = rows[1].as_ref().unwrap();
            assert_eq!(r0.shape(), &[3, v][..]);
            assert_eq!(r0.data(), &want0[..], "slot 0 burst rows (paged={paged})");
            assert_eq!(r1.data(), &want1[..], "slot 1 burst rows (paged={paged})");
            // roll slot 0 back to one accepted token and continue: the
            // next decode must reproduce the sequential chain's second
            // step, as if the rejected rows had never existed
            burst.kv_truncate(0, 4);
            burst.kv_truncate(1, 5); // no-op at the current position
            assert!(burst.kv_reserve(0, 1));
            let lg = burst
                .decode(&[chain0[1] as i32, PAD as i32], &[4, 0])
                .unwrap();
            assert_eq!(&lg.data()[..v], &want0[v..2 * v],
                       "decode after truncate (paged={paged})");
        }
    }

    /// Speculative and plain engines over the same backend and request
    /// mix must retire bit-identical responses — greedy and sampled,
    /// with a prompt-lookup draft whose guesses the verifier is free to
    /// reject wholesale.
    fn check_spec_matches_plain(make: &dyn Fn() -> NativeBackend, k: usize) {
        let submit = |engine: &mut ServeEngine| {
            engine.submit(
                Request::new(0, vec![7, 8, 9, 7, 8, 9, 7, 8]).with_max_new(10),
            );
            engine.submit(
                Request::new(1, vec![5, 6, 5, 6, 5])
                    .with_max_new(8)
                    .with_temperature(0.8),
            );
            engine.submit(Request::new(2, vec![11, 23, 42]).with_max_new(6));
        };
        let run = |spec: bool| {
            let mut e = ServeEngine::new(
                Box::new(make()),
                ServeConfig { max_new_cap: 16, seed: 9, queue_cap: 8 },
            );
            if spec {
                e.enable_speculation(k, Box::new(crate::spec::NgramDraft::new(3)));
            }
            submit(&mut e);
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            let pairs: Vec<_> = out
                .into_iter()
                .map(|r| (r.id, r.tokens, r.finish))
                .collect();
            (pairs, e.metrics.spec_proposed)
        };
        let (want, _) = run(false);
        let (got, proposed) = run(true);
        assert_eq!(got, want, "speculation changed engine output (k={k})");
        assert!(proposed > 0, "the draft never proposed — the check is vacuous");
    }

    #[test]
    fn spec_engine_matches_plain_fp_contig() {
        check_spec_matches_plain(&|| NativeBackend::new(demo_model(), 2), 4);
    }

    #[test]
    fn spec_engine_matches_plain_fp_paged() {
        check_spec_matches_plain(
            &|| NativeBackend::with_paged_kv(demo_model(), 2, 4, 0), 2,
        );
    }

    #[test]
    fn spec_engine_matches_plain_w4a4_contig() {
        check_spec_matches_plain(&|| NativeBackend::new(w4a4_model(), 2), 4);
    }

    #[test]
    fn spec_engine_matches_plain_w4a4_paged() {
        check_spec_matches_plain(
            &|| NativeBackend::with_paged_kv(w4a4_model(), 2, 7, 0), 8,
        );
    }

    #[test]
    fn spec_engine_with_native_draft_matches_plain() {
        // the draft carries different random weights (seed 21): its
        // guesses are usually wrong, so exactness must come from
        // verification alone, not from a lucky oracle
        use crate::spec::NativeDraft;
        let draft = || {
            let cfg = test_config();
            let w = Weights::random_init(&cfg, 21);
            let m = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
            NativeDraft::new(m, 2)
        };
        let run = |spec: bool| {
            let mut e = ServeEngine::new(
                Box::new(NativeBackend::new(demo_model(), 2)),
                ServeConfig { max_new_cap: 16, seed: 5, queue_cap: 8 },
            );
            if spec {
                e.enable_speculation(3, Box::new(draft()));
            }
            e.submit(Request::new(0, vec![10, 20, 30]).with_max_new(8));
            e.submit(
                Request::new(1, vec![4, 5, 6, 4, 5]).with_max_new(8)
                    .with_temperature(0.6),
            );
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| (r.tokens, r.finish)).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "native draft changed engine output");
    }

    /// Regression for positional sampling: a preempted-and-replayed
    /// *sampled* request must re-emit exactly the tokens it already
    /// streamed. An RNG keyed on draw count would shift the stream on
    /// replay; keying on (seed, request id, token index) cannot.
    #[test]
    fn preempted_sampled_requests_replay_identically() {
        let requests = |engine: &mut ServeEngine| {
            for i in 0..6u64 {
                let prompt: Vec<u16> =
                    (0..6).map(|j| 10 + 3 * i as u16 + j).collect();
                engine.submit(
                    Request::new(i, prompt).with_max_new(12).with_temperature(0.7),
                );
            }
        };
        let mut ref_engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 0)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut ref_engine);
        let mut expect = ref_engine.run_to_completion().unwrap();
        expect.sort_by_key(|r| r.id);
        assert_eq!(ref_engine.metrics.preemptions, 0);

        let mut engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 10)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut engine);
        let mut got = engine.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), expect.len());
        assert!(engine.metrics.preemptions > 0, "tight pool must preempt");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.id, e.id);
            assert_eq!(g.tokens, e.tokens,
                       "replayed sampled stream diverged for request {}", g.id);
        }
    }

    /// Speculation composes with overcommit: a tight pool forces bursts
    /// to degrade and slots to preempt mid-generation, and the output
    /// still matches an uncontended plain engine bit for bit while the
    /// draft keeps earning acceptances on the periodic prompts.
    #[test]
    fn speculative_overcommitted_pool_stays_exact() {
        let requests = |engine: &mut ServeEngine| {
            for i in 0..6u64 {
                let base = 10 + 2 * i as u16;
                let prompt: Vec<u16> = (0..9).map(|j| base + j % 3).collect();
                engine.submit(Request::new(i, prompt).with_max_new(12));
            }
        };
        let mut ref_engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 0)),
            ServeConfig { max_new_cap: 16, seed: 3, queue_cap: 16 },
        );
        requests(&mut ref_engine);
        let mut expect = ref_engine.run_to_completion().unwrap();
        expect.sort_by_key(|r| r.id);

        let mut engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 12)),
            ServeConfig { max_new_cap: 16, seed: 3, queue_cap: 16 },
        );
        engine.enable_speculation(4, Box::new(crate::spec::NgramDraft::new(3)));
        requests(&mut engine);
        let mut got = engine.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 6, "every request completes");
        assert!(engine.metrics.preemptions > 0, "tight pool must preempt");
        assert!(engine.metrics.spec_proposed > 0, "drafting must have run");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.id, e.id);
            assert_eq!(g.tokens, e.tokens,
                       "speculation + preemption diverged for request {}", g.id);
        }
    }
}
