//! [`ServeBackend`] over [`NativeModel`]: pure-CPU serving of packed
//! quantized checkpoints — no PJRT, no XLA stub, no artifacts on disk.
//!
//! Owns one [`SlotKv`] per batcher slot. Prefill runs each admitted
//! prompt through the full-sequence path (multi-threaded matmuls over the
//! packed weights) and leaves the slot's KV rows resident; decode advances
//! each active slot one position; retire clears the slot's cache so the
//! allocation is reused by the next admission.

use anyhow::{ensure, Result};

use crate::coordinator::backend::{BackendLimits, ServeBackend};
use crate::coordinator::tokenizer::PAD;
use crate::model::{NativeModel, SlotKv};
use crate::tensor::Tensor;

pub struct NativeBackend {
    model: NativeModel,
    slots: Vec<SlotKv>,
    limits: BackendLimits,
}

impl NativeBackend {
    pub fn new(model: NativeModel, batch: usize) -> NativeBackend {
        let limits = BackendLimits {
            batch,
            score_seq: model.cfg.score_seq,
            vocab_size: model.cfg.vocab_size,
            max_seq: model.cfg.max_seq,
        };
        let slots = (0..batch).map(|_| model.new_kv()).collect();
        NativeBackend { model, slots, limits }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Resident KV bytes across all slots (capacity currently in use).
    pub fn kv_nbytes(&self) -> usize {
        self.slots.iter().map(|s| s.nbytes()).sum()
    }
}

impl ServeBackend for NativeBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let BackendLimits { batch, score_seq: t, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch * t, "prefill shape mismatch");
        let mut logits = Tensor::zeros(&[batch, t, v]);
        for &slot in admitted {
            ensure!(slot < batch, "slot {slot} out of range");
            let row = &tokens[slot * t..(slot + 1) * t];
            let prompt: Vec<u16> = row
                .iter()
                .take_while(|&&tok| tok != PAD as i32)
                .map(|&tok| tok as u16)
                .collect();
            ensure!(!prompt.is_empty(), "empty prompt in slot {slot}");
            self.slots[slot].reset();
            let lg = self.model.prefill(&mut self.slots[slot], &prompt)?;
            for p in 0..prompt.len() {
                let base = (slot * t + p) * v;
                logits.data_mut()[base..base + v].copy_from_slice(lg.row(p));
            }
        }
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch && positions.len() == batch,
                "decode shape mismatch");
        let mut logits = Tensor::zeros(&[batch, v]);
        for slot in 0..batch {
            let tok = tokens[slot];
            if tok == PAD as i32 {
                continue;
            }
            let kv = &mut self.slots[slot];
            ensure!(kv.pos == positions[slot] as usize,
                    "slot {slot}: cache holds {} positions but scheduler is at {}",
                    kv.pos, positions[slot]);
            let row = self.model.decode(kv, tok as u16)?;
            logits.data_mut()[slot * v..(slot + 1) * v].copy_from_slice(&row);
        }
        Ok(logits)
    }

    fn retire(&mut self, slot: usize) {
        if let Some(kv) = self.slots.get_mut(slot) {
            kv.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Request, ServeConfig, ServeEngine};
    use crate::model::config::tests::test_config;
    use crate::model::Weights;

    fn demo_backend(batch: usize) -> NativeBackend {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 4);
        let model = NativeModel::from_weights(&cfg, &w, None, 2).unwrap();
        NativeBackend::new(model, batch)
    }

    #[test]
    fn serves_greedy_requests_deterministically() {
        let run = || {
            let mut engine = ServeEngine::new(
                Box::new(demo_backend(2)),
                ServeConfig { max_new_cap: 4, seed: 1, queue_cap: 8 },
            );
            engine.submit(Request::new(0, vec![10, 20, 30]).with_max_new(4));
            engine.submit(Request::new(1, vec![7]).with_max_new(3));
            let mut out = engine.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].tokens.len(), 4);
        assert!(a[1].tokens.len() <= 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "greedy serving must be deterministic");
        }
    }

    #[test]
    fn retire_clears_slot_state_for_reuse() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..3].copy_from_slice(&[5, 6, 7]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.kv_nbytes() > 0);
        let first = be.decode(&[9], &[3]).unwrap();
        be.retire(0);
        // same prompt again: identical logits from a clean slot
        be.prefill(&tokens, &[0]).unwrap();
        let second = be.decode(&[9], &[3]).unwrap();
        assert_eq!(first.data(), second.data());
    }

    #[test]
    fn decode_position_mismatch_is_an_error() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..2].copy_from_slice(&[1, 2]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.decode(&[3], &[7]).is_err(), "stale position must fail loudly");
    }
}
