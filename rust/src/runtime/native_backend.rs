//! [`ServeBackend`] over [`NativeModel`]: pure-CPU serving of packed
//! quantized checkpoints — no PJRT, no XLA stub, no artifacts on disk.
//!
//! Two KV layouts behind one backend:
//!
//! * **Contiguous** (default): one growable [`SlotKv`] per batcher slot,
//!   each able to reach `max_seq` rows — simple, but the memory budget
//!   must assume every slot hits the worst case.
//! * **Paged** (`with_paged_kv`): one shared [`BlockPool`] plus a
//!   [`PageTable`] per slot. Memory follows the live token count, the
//!   batcher reads the pool through [`ServeBackend::kv_pool`] /
//!   [`ServeBackend::kv_reserve`] to gate admission and trigger
//!   preemption, and `retire(slot)` returns the slot's pages to the free
//!   list. Reads are bit-identical to the contiguous layout (pinned by
//!   the property tests in `model::native`).

use anyhow::{ensure, Result};

use crate::coordinator::backend::{BackendLimits, KvPoolStatus, ServeBackend};
use crate::coordinator::tokenizer::PAD;
use crate::kv::{BlockPool, PageTable, PagedSlot, SlotKv};
use crate::model::NativeModel;
use crate::tensor::Tensor;

enum KvSlots {
    Contig(Vec<SlotKv>),
    Paged { pool: BlockPool, tables: Vec<PageTable> },
}

pub struct NativeBackend {
    model: NativeModel,
    kv: KvSlots,
    limits: BackendLimits,
}

impl NativeBackend {
    pub fn new(model: NativeModel, batch: usize) -> NativeBackend {
        let limits = Self::limits_for(&model, batch);
        let slots = (0..batch).map(|_| model.new_kv()).collect();
        NativeBackend { model, kv: KvSlots::Contig(slots), limits }
    }

    /// Paged-KV backend: `pool_pages` pages of `page_tokens` positions
    /// shared by all `batch` slots. `pool_pages = 0` auto-sizes the pool
    /// to the contiguous worst case (`batch × ⌈max_seq / page_tokens⌉`),
    /// which can never reject or preempt — pass an explicit smaller pool
    /// to actually overcommit.
    pub fn with_paged_kv(
        model: NativeModel,
        batch: usize,
        page_tokens: usize,
        pool_pages: usize,
    ) -> NativeBackend {
        let limits = Self::limits_for(&model, batch);
        let pages = if pool_pages == 0 {
            batch * model.cfg.max_seq.div_ceil(page_tokens)
        } else {
            pool_pages
        };
        let pool = BlockPool::new(model.cfg.n_layers, model.cfg.d_model,
                                  page_tokens, pages);
        let tables = (0..batch).map(|_| PageTable::new()).collect();
        NativeBackend { model, kv: KvSlots::Paged { pool, tables }, limits }
    }

    fn limits_for(model: &NativeModel, batch: usize) -> BackendLimits {
        BackendLimits {
            batch,
            score_seq: model.cfg.score_seq,
            vocab_size: model.cfg.vocab_size,
            max_seq: model.cfg.max_seq,
        }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Resident KV bytes: rows held by contiguous slots, or used pages
    /// (the arena is allocated up front; this reports the live share).
    pub fn kv_nbytes(&self) -> usize {
        match &self.kv {
            KvSlots::Contig(slots) => slots.iter().map(|s| s.nbytes()).sum(),
            KvSlots::Paged { pool, .. } => pool.pages_used() * pool.page_nbytes(),
        }
    }
}

impl ServeBackend for NativeBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let BackendLimits { batch, score_seq: t, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch * t, "prefill shape mismatch");
        let mut logits = Tensor::zeros(&[batch, t, v]);
        for &slot in admitted {
            ensure!(slot < batch, "slot {slot} out of range");
            let row = &tokens[slot * t..(slot + 1) * t];
            let prompt: Vec<u16> = row
                .iter()
                .take_while(|&&tok| tok != PAD as i32)
                .map(|&tok| tok as u16)
                .collect();
            ensure!(!prompt.is_empty(), "empty prompt in slot {slot}");
            let lg = match &mut self.kv {
                KvSlots::Contig(slots) => {
                    slots[slot].reset();
                    self.model.prefill(&mut slots[slot], &prompt)?
                }
                KvSlots::Paged { pool, tables } => {
                    let table = &mut tables[slot];
                    if table.pos() != 0 {
                        table.release(pool);
                    }
                    let mut view = PagedSlot { pool, table };
                    self.model.prefill(&mut view, &prompt)?
                }
            };
            for p in 0..prompt.len() {
                let base = (slot * t + p) * v;
                logits.data_mut()[base..base + v].copy_from_slice(lg.row(p));
            }
        }
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        let BackendLimits { batch, vocab_size: v, .. } = self.limits;
        ensure!(tokens.len() == batch && positions.len() == batch,
                "decode shape mismatch");
        let mut logits = Tensor::zeros(&[batch, v]);
        for slot in 0..batch {
            let tok = tokens[slot];
            if tok == PAD as i32 {
                continue;
            }
            let row = match &mut self.kv {
                KvSlots::Contig(slots) => {
                    let kv = &mut slots[slot];
                    ensure!(kv.pos == positions[slot] as usize,
                            "slot {slot}: cache holds {} positions but scheduler is at {}",
                            kv.pos, positions[slot]);
                    self.model.decode(kv, tok as u16)?
                }
                KvSlots::Paged { pool, tables } => {
                    let table = &mut tables[slot];
                    ensure!(table.pos() == positions[slot] as usize,
                            "slot {slot}: cache holds {} positions but scheduler is at {}",
                            table.pos(), positions[slot]);
                    let mut view = PagedSlot { pool, table };
                    self.model.decode(&mut view, tok as u16)?
                }
            };
            logits.data_mut()[slot * v..(slot + 1) * v].copy_from_slice(&row);
        }
        Ok(logits)
    }

    fn retire(&mut self, slot: usize) {
        match &mut self.kv {
            KvSlots::Contig(slots) => {
                if let Some(kv) = slots.get_mut(slot) {
                    kv.reset();
                }
            }
            KvSlots::Paged { pool, tables } => {
                if let Some(table) = tables.get_mut(slot) {
                    table.release(pool);
                }
            }
        }
    }

    fn kv_pool(&self) -> Option<KvPoolStatus> {
        match &self.kv {
            KvSlots::Contig(_) => None,
            KvSlots::Paged { pool, .. } => Some(KvPoolStatus {
                page_tokens: pool.page_tokens(),
                pages_total: pool.pages_total(),
                pages_free: pool.pages_free(),
            }),
        }
    }

    fn kv_reserve(&mut self, slot: usize, extra: usize) -> bool {
        match &mut self.kv {
            KvSlots::Contig(_) => true,
            KvSlots::Paged { pool, tables } => match tables.get_mut(slot) {
                Some(table) => table.reserve(pool, extra).is_ok(),
                None => false,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Request, ServeConfig, ServeEngine, TokenEvent};
    use crate::model::config::tests::test_config;
    use crate::model::Weights;

    fn demo_model() -> NativeModel {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 4);
        NativeModel::from_weights(&cfg, &w, None, 2).unwrap()
    }

    fn demo_backend(batch: usize) -> NativeBackend {
        NativeBackend::new(demo_model(), batch)
    }

    #[test]
    fn serves_greedy_requests_deterministically() {
        let run = || {
            let mut engine = ServeEngine::new(
                Box::new(demo_backend(2)),
                ServeConfig { max_new_cap: 4, seed: 1, queue_cap: 8 },
            );
            engine.submit(Request::new(0, vec![10, 20, 30]).with_max_new(4));
            engine.submit(Request::new(1, vec![7]).with_max_new(3));
            let mut out = engine.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].tokens.len(), 4);
        assert!(a[1].tokens.len() <= 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "greedy serving must be deterministic");
        }
    }

    #[test]
    fn retire_clears_slot_state_for_reuse() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..3].copy_from_slice(&[5, 6, 7]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.kv_nbytes() > 0);
        let first = be.decode(&[9], &[3]).unwrap();
        be.retire(0);
        // same prompt again: identical logits from a clean slot
        be.prefill(&tokens, &[0]).unwrap();
        let second = be.decode(&[9], &[3]).unwrap();
        assert_eq!(first.data(), second.data());
    }

    #[test]
    fn decode_position_mismatch_is_an_error() {
        let mut be = demo_backend(1);
        let t = be.limits().score_seq;
        let mut tokens = vec![PAD as i32; t];
        tokens[..2].copy_from_slice(&[1, 2]);
        be.prefill(&tokens, &[0]).unwrap();
        assert!(be.decode(&[3], &[7]).is_err(), "stale position must fail loudly");
    }

    #[test]
    fn paged_backend_matches_contiguous_logits_exactly() {
        let model = demo_model();
        let cfg = model.cfg.clone();
        let mut contig = NativeBackend::new(demo_model(), 2);
        let mut paged = NativeBackend::with_paged_kv(model, 2, 7, 0);
        assert_eq!(paged.kv_pool().unwrap().pages_total,
                   2 * cfg.max_seq.div_ceil(7));
        let t = contig.limits().score_seq;
        let mut tokens = vec![PAD as i32; 2 * t];
        tokens[..3].copy_from_slice(&[5, 6, 7]);
        tokens[t..t + 2].copy_from_slice(&[11, 12]);
        assert!(paged.kv_reserve(0, 3) && paged.kv_reserve(1, 2));
        let a = contig.prefill(&tokens, &[0, 1]).unwrap();
        let b = paged.prefill(&tokens, &[0, 1]).unwrap();
        assert_eq!(a.data(), b.data(), "paged prefill logits must be bit-equal");
        for step in 0..3 {
            assert!(paged.kv_reserve(0, 1) && paged.kv_reserve(1, 1));
            let pos = [3 + step, 2 + step];
            let x = contig.decode(&[9, 13], &[pos[0], pos[1]]).unwrap();
            let y = paged.decode(&[9, 13], &[pos[0], pos[1]]).unwrap();
            assert_eq!(x.data(), y.data(), "paged decode step {step}");
        }
    }

    #[test]
    fn paged_retire_returns_pages_no_leak_after_churn() {
        let model = demo_model();
        let mut be = NativeBackend::with_paged_kv(model, 2, 4, 16);
        let t = be.limits().score_seq;
        for round in 0..8 {
            let mut tokens = vec![PAD as i32; 2 * t];
            let plen = 1 + round % 5;
            for (j, cell) in tokens[..plen].iter_mut().enumerate() {
                *cell = (10 + j) as i32;
            }
            tokens[t..t + 2].copy_from_slice(&[3, 4]);
            assert!(be.kv_reserve(0, plen) && be.kv_reserve(1, 2));
            be.prefill(&tokens, &[0, 1]).unwrap();
            assert!(be.kv_reserve(0, 1));
            be.decode(&[7, PAD as i32], &[plen as i32, 0]).unwrap();
            be.retire(0);
            be.retire(1);
            let pool = be.kv_pool().unwrap();
            assert_eq!(pool.pages_free, pool.pages_total,
                       "round {round}: pages leaked");
            assert_eq!(be.kv_nbytes(), 0);
        }
    }

    /// Acceptance: with a pool far smaller than `batch × max_seq`
    /// (naive sizing `pool_pages × page_tokens / max_seq` = 48/160 → 0
    /// concurrent worst-case slots), the batcher still serves 4-way
    /// concurrency by overcommitting and preempting — zero engine
    /// aborts, every request completes, and greedy outputs are
    /// identical to an uncontended run.
    #[test]
    fn overcommitted_pool_preempts_and_replays_exactly() {
        let requests = |engine: &mut ServeEngine| {
            for i in 0..6u64 {
                let prompt: Vec<u16> = (0..6).map(|j| (10 + 3 * i as u16 + j)).collect();
                engine.submit(Request::new(i, prompt).with_max_new(12));
            }
        };
        // uncontended reference: auto-sized pool (never preempts)
        let mut ref_engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 0)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut ref_engine);
        let mut expect = ref_engine.run_to_completion().unwrap();
        expect.sort_by_key(|r| r.id);
        assert_eq!(ref_engine.metrics.preemptions, 0);

        // tight pool: 12 pages × 4 tokens = 48 positions for 4 slots
        // whose worst case is 4 × 18 = 72
        let mut engine = ServeEngine::new(
            Box::new(NativeBackend::with_paged_kv(demo_model(), 4, 4, 12)),
            ServeConfig { max_new_cap: 16, seed: 2, queue_cap: 16 },
        );
        requests(&mut engine);
        let mut max_active = 0;
        let mut got = Vec::new();
        while engine.has_work() {
            let events = engine
                .step()
                .expect("pool exhaustion must never abort the engine");
            max_active = max_active.max(engine.active());
            for ev in events {
                if let TokenEvent::Done { response, .. } = ev {
                    got.push(response);
                }
            }
        }
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 6, "every request completes");
        assert!(max_active > 1, "overcommit must beat naive sizing (0-1 slots)");
        assert!(engine.metrics.preemptions > 0, "tight pool must preempt");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.id, e.id);
            assert_eq!(g.tokens, e.tokens,
                       "preempt+replay must reproduce greedy output of request {}", g.id);
        }
    }
}
