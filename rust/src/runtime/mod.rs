//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path. Python is never involved here.

pub mod engine;
pub mod native_backend;
pub mod runner;
pub mod serve_backend;

pub use engine::{Artifact, Engine};
pub use native_backend::NativeBackend;
pub use runner::{KvCache, ModelRunner};
pub use serve_backend::RunnerBackend;
