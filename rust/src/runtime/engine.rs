//! The PJRT engine: a CPU PJRT client plus a compile-once artifact cache.
//!
//! Interchange is HLO **text** (`*.hlo.txt` + `*.layout.json`), produced by
//! `python/compile/aot.py`. Text — not serialized protos — because jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::ModelConfig;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Input dtype of a graph parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct Layout {
    pub inputs: Vec<InputSpec>,
    pub n_outputs: usize,
}

impl Layout {
    fn from_json(j: &Json) -> Result<Layout> {
        let inputs = j
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(InputSpec {
                    name: e.str_at("name")?.to_string(),
                    shape: e
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    dtype: if e.str_at("dtype")? == "i32" {
                        DType::I32
                    } else {
                        DType::F32
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Layout { inputs, n_outputs: j.usize_at("n_outputs")? })
    }
}

/// A compiled artifact: executable + its input layout.
pub struct Artifact {
    pub name: String,
    pub layout: Layout,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.layout.inputs.len() {
            bail!(
                "{}: {} inputs given, layout wants {}",
                self.name,
                inputs.len(),
                self.layout.inputs.len()
            );
        }
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let mut tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }

    /// Execute with device-resident buffers (weights stay on device across
    /// calls — the serving hot path).
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("execute_b {}", self.name))?;
        let mut tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }
}

/// PJRT client + manifest + compiled-artifact cache.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub dir: String,
    pub manifest: Json,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

impl Engine {
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Json::parse_file(&format!("{artifacts_dir}/manifest.json"))
            .context("load manifest (run `make artifacts` first)")?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir: artifacts_dir.to_string(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn config(&self, name: &str) -> Result<ModelConfig> {
        ModelConfig::from_manifest(&self.manifest, name)
    }

    /// Canonical artifact key, e.g. `sq-m_decode_w4a4_b4`.
    pub fn artifact_key(cfg: &ModelConfig, graph: &str, mode: &str, batch: usize) -> String {
        format!("{}_{graph}_{mode}_b{batch}", cfg.artifact_config)
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, key: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(key) {
            return Ok(a.clone());
        }
        let hlo_path = format!("{}/{key}.hlo.txt", self.dir);
        let layout_path = format!("{}/{key}.layout.json", self.dir);
        let layout = Layout::from_json(&Json::parse_file(&layout_path)?)?;
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow!("parse {hlo_path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {key}: {e:?}"))?;
        let art = Arc::new(Artifact { name: key.to_string(), layout, exe });
        self.cache.lock().unwrap().insert(key.to_string(), art.clone());
        Ok(art)
    }

    /// Upload a host tensor as a device-resident buffer.
    pub fn buffer_f32(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    pub fn buffer_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

// ---------------------------------------------------------------------------
// Literal <-> Tensor conversion helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal -> Tensor with the given logical shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data: Vec<f32> = lit.to_vec()?;
    if data.len() != shape.iter().product::<usize>() {
        bail!("literal has {} elems, wanted shape {shape:?}", data.len());
    }
    Ok(Tensor::from_raw(shape.to_vec(), data))
}
