//! ModelRunner: a quantized model bound to its AOT graphs, with
//! device-resident parameters.
//!
//! Parameters (weights + rotation factors + clips) are uploaded once as
//! PJRT buffers; per-call data (tokens, positions, KV caches) are uploaded
//! per step. On the CPU plugin "device" is host memory, so the residency
//! win is avoiding re-validation/copy of the ~all-of-the-model parameter
//! list on every decode step.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::engine::{literal_to_tensor, Artifact, Engine};
use crate::coordinator::tokenizer::PAD;
use crate::model::ModelConfig;
use crate::pipeline::QuantizedModel;
use crate::tensor::Tensor;

pub struct ModelRunner {
    pub engine: Arc<Engine>,
    pub cfg: ModelConfig,
    pub mode: &'static str,
    /// Device-resident parameter buffers keyed by layout name.
    params: HashMap<String, xla::PjRtBuffer>,
    score_art: Arc<Artifact>,
    pub score_batch: usize,
    /// Long-context scoring graph (few-shot eval), when lowered for this
    /// config: (artifact, batch, seq).
    long_art: Option<(Arc<Artifact>, usize, usize)>,
}

/// KV cache pair held between steps.
///
/// Fast path: the cache stays as the PJRT output **literals** and is fed
/// back with `buffer_from_host_literal` — no tensor materialization. The
/// coordinator only needs host access on admission (slot-row merges), at
/// which point the host tensors are materialized lazily and become
/// authoritative until the next decode uploads them (§Perf: this removed
/// one full cache copy per side per decode step).
pub struct KvCache {
    pub batch: usize,
    shape: Vec<usize>,
    k_lit: xla::Literal,
    v_lit: xla::Literal,
    /// Some => host copies are dirty/authoritative.
    host: Option<(Tensor, Tensor)>,
}

impl KvCache {
    fn from_literals(shape: Vec<usize>, k_lit: xla::Literal, v_lit: xla::Literal,
                     batch: usize) -> KvCache {
        KvCache { batch, shape, k_lit, v_lit, host: None }
    }

    /// Materialize (or return the existing) host tensors.
    pub fn host_mut(&mut self) -> Result<(&mut Tensor, &mut Tensor)> {
        if self.host.is_none() {
            let k = literal_to_tensor(&self.k_lit, &self.shape)?;
            let v = literal_to_tensor(&self.v_lit, &self.shape)?;
            self.host = Some((k, v));
        }
        let (k, v) = self.host.as_mut().unwrap();
        Ok((k, v))
    }

    /// Contiguous span of one (layer, slot) row in the [L,B,H,T,dh] layout.
    pub fn row_span(&self, cfg: &ModelConfig, layer: usize, slot: usize) -> std::ops::Range<usize> {
        let row = cfg.n_heads * cfg.max_seq * cfg.d_head();
        let base = (layer * self.batch + slot) * row;
        base..base + row
    }

    /// Copy one slot's rows (all layers) from another cache.
    pub fn copy_slot_from(&mut self, cfg: &ModelConfig, other: &mut KvCache,
                          slot: usize) -> Result<()> {
        let n_layers = cfg.n_layers;
        let spans: Vec<(std::ops::Range<usize>, std::ops::Range<usize>)> = (0..n_layers)
            .map(|l| (self.row_span(cfg, l, slot), other.row_span(cfg, l, slot)))
            .collect();
        let (ok, ov) = other.host_mut()?;
        let (ok, ov) = (ok.data().to_vec(), ov.data().to_vec());
        let (k, v) = self.host_mut()?;
        for (dst, src) in spans {
            k.data_mut()[dst.clone()].copy_from_slice(&ok[src.clone()]);
            v.data_mut()[dst].copy_from_slice(&ov[src]);
        }
        Ok(())
    }
}

impl ModelRunner {
    /// Bind a quantized model package to its artifacts.
    pub fn new(engine: Arc<Engine>, qm: &QuantizedModel) -> Result<ModelRunner> {
        let cfg = qm.cfg.clone();
        let mode: &'static str = match qm.graph_mode() {
            "fp" => "fp",
            "w4a16" => "w4a16",
            "w4a4s" => "w4a4s",
            _ => "w4a4",
        };
        let score_batch = engine.manifest.usize_at("score_batch")?;
        let score_key = Engine::artifact_key(&cfg, "score", mode, score_batch);
        let score_art = engine.load(&score_key)?;
        let long_art = match (engine.manifest.opt("long_batch"),
                              engine.manifest.opt("long_seq")) {
            (Some(b), Some(t)) => {
                let b = b.as_usize()?;
                let key = Engine::artifact_key(&cfg, "scorelong", mode, b);
                engine.load(&key).ok().map(|a| (a, b, t.as_usize().unwrap()))
            }
            _ => None,
        };

        // Upload every graph parameter once.
        let mut params = HashMap::new();
        for spec in &score_art.layout.inputs {
            if spec.name.starts_with("in.") {
                continue;
            }
            let t = param_tensor(qm, &spec.name, &spec.shape)?;
            params.insert(spec.name.clone(), engine.buffer_f32(&t)?);
        }
        Ok(ModelRunner { engine, cfg, mode, params, score_art, score_batch, long_art })
    }

    /// Max sequence length scorable (long graph if available).
    pub fn max_score_len(&self) -> usize {
        self.long_art
            .as_ref()
            .map(|(_, _, t)| *t)
            .unwrap_or(self.cfg.score_seq)
            .max(self.cfg.score_seq)
    }

    fn param_buffers<'a>(&'a self, art: &Artifact) -> Result<Vec<&'a xla::PjRtBuffer>> {
        art.layout
            .inputs
            .iter()
            .filter(|s| !s.name.starts_with("in."))
            .map(|s| {
                self.params
                    .get(&s.name)
                    .ok_or_else(|| anyhow!("missing param buffer {}", s.name))
            })
            .collect()
    }

    // -- score ---------------------------------------------------------------

    /// Logits for one padded batch of token sequences. `seqs` length must
    /// be <= score_batch; sequences are padded/truncated to score_seq.
    /// Returns per-sequence [len, V] logits.
    pub fn score_batch_padded(&self, seqs: &[&[u16]]) -> Result<Vec<Tensor>> {
        let b = self.score_batch;
        let t = self.cfg.score_seq;
        if seqs.is_empty() || seqs.len() > b {
            bail!("score batch size {} out of range", seqs.len());
        }
        let mut tokens = vec![PAD as i32; b * t];
        for (i, seq) in seqs.iter().enumerate() {
            for (j, &tok) in seq.iter().take(t).enumerate() {
                tokens[i * t + j] = tok as i32;
            }
        }
        let tok_buf = self.engine.buffer_i32(&tokens, &[b, t])?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        let pbufs = self.param_buffers(&self.score_art)?;
        bufs.extend(pbufs);
        let out = self.score_art.run_buffers(&bufs)?;
        let logits = literal_to_tensor(&out[0], &[b, t, self.cfg.vocab_size])?;
        // slice out each sequence's prefix
        let v = self.cfg.vocab_size;
        Ok(seqs
            .iter()
            .enumerate()
            .map(|(i, seq)| {
                let len = seq.len().min(t);
                let mut out = Tensor::zeros(&[len, v]);
                for p in 0..len {
                    let base = (i * t + p) * v;
                    out.row_mut(p)
                        .copy_from_slice(&logits.data()[base..base + v]);
                }
                out
            })
            .collect())
    }

    /// Score one padded batch through the long-context graph.
    fn score_batch_long(&self, seqs: &[&[u16]]) -> Result<Vec<Tensor>> {
        let (art, b, t) = self
            .long_art
            .as_ref()
            .ok_or_else(|| anyhow!("no long-score graph lowered for {}", self.cfg.name))?;
        let (b, t) = (*b, *t);
        let mut tokens = vec![PAD as i32; b * t];
        for (i, seq) in seqs.iter().enumerate() {
            for (j, &tok) in seq.iter().take(t).enumerate() {
                tokens[i * t + j] = tok as i32;
            }
        }
        let tok_buf = self.engine.buffer_i32(&tokens, &[b, t])?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        bufs.extend(self.param_buffers(art)?);
        let out = art.run_buffers(&bufs)?;
        let logits = literal_to_tensor(&out[0], &[b, t, self.cfg.vocab_size])?;
        let v = self.cfg.vocab_size;
        Ok(seqs
            .iter()
            .enumerate()
            .map(|(i, seq)| {
                let len = seq.len().min(t);
                let mut o = Tensor::zeros(&[len, v]);
                for p in 0..len {
                    let base = (i * t + p) * v;
                    o.row_mut(p).copy_from_slice(&logits.data()[base..base + v]);
                }
                o
            })
            .collect())
    }

    /// Score arbitrarily many sequences (internally batched; sequences
    /// longer than the short graph route through the long-context graph).
    pub fn score_many(&self, seqs: &[Vec<u16>]) -> Result<Vec<Tensor>> {
        let t_short = self.cfg.score_seq;
        let mut out: Vec<Option<Tensor>> = vec![None; seqs.len()];
        let mut short_idx = Vec::new();
        let mut long_idx = Vec::new();
        for (i, s) in seqs.iter().enumerate() {
            if s.len() <= t_short {
                short_idx.push(i);
            } else {
                long_idx.push(i);
            }
        }
        for chunk in short_idx.chunks(self.score_batch) {
            let refs: Vec<&[u16]> = chunk.iter().map(|&i| seqs[i].as_slice()).collect();
            for (k, lg) in self.score_batch_padded(&refs)?.into_iter().enumerate() {
                out[chunk[k]] = Some(lg);
            }
        }
        if !long_idx.is_empty() {
            let lb = self.long_art.as_ref().map(|(_, b, _)| *b).unwrap_or(1);
            for chunk in long_idx.chunks(lb) {
                let refs: Vec<&[u16]> =
                    chunk.iter().map(|&i| seqs[i].as_slice()).collect();
                for (k, lg) in self.score_batch_long(&refs)?.into_iter().enumerate() {
                    out[chunk[k]] = Some(lg);
                }
            }
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }

    // -- serving graphs --------------------------------------------------------

    fn serve_art(&self, graph: &str, batch: usize) -> Result<Arc<Artifact>> {
        // serving graphs are lowered for fp and w4a4 only
        let mode = if self.mode == "fp" { "fp" } else { "w4a4" };
        let key = Engine::artifact_key(&self.cfg, graph, mode, batch);
        self.engine.load(&key)
    }

    fn kv_shape(&self, batch: usize) -> Vec<usize> {
        vec![
            self.cfg.n_layers,
            batch,
            self.cfg.n_heads,
            self.cfg.max_seq,
            self.cfg.d_head(),
        ]
    }

    /// Prefill a [B, score_seq] right-padded token batch. Returns the full
    /// logits [B, T, V] and the KV cache.
    pub fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<(Tensor, KvCache)> {
        let t = self.cfg.score_seq;
        assert_eq!(tokens.len(), batch * t);
        let art = self.serve_art("prefill", batch)?;
        let tok_buf = self.engine.buffer_i32(tokens, &[batch, t])?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        let pbufs = self.param_buffers(&art)?;
        bufs.extend(pbufs);
        let mut out = art.run_buffers(&bufs)?;
        let logits = literal_to_tensor(&out[0], &[batch, t, self.cfg.vocab_size])?;
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        Ok((logits, KvCache::from_literals(self.kv_shape(batch), k, v, batch)))
    }

    /// One decode step at per-slot positions; updates `kv` in place and
    /// returns logits [B, V].
    pub fn decode(
        &self,
        kv: &mut KvCache,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<Tensor> {
        let b = kv.batch;
        assert_eq!(tokens.len(), b);
        assert_eq!(positions.len(), b);
        let art = self.serve_art("decode", b)?;
        let tok_buf = self.engine.buffer_i32(tokens, &[b])?;
        let pos_buf = self.engine.buffer_i32(positions, &[b])?;
        // fast path: literals straight back to the device; host tensors
        // only when the coordinator dirtied them (admission merge)
        let force_host = std::env::var("SQ_KV_HOST_PATH").is_ok();
        let (k_buf, v_buf) = match kv.host.take() {
            Some((k, v)) => (self.engine.buffer_f32(&k)?, self.engine.buffer_f32(&v)?),
            None if force_host => {
                let (k, v) = {
                    let (k, v) = kv.host_mut()?;
                    (k.clone(), v.clone())
                };
                kv.host = None;
                (self.engine.buffer_f32(&k)?, self.engine.buffer_f32(&v)?)
            }
            None => (
                self.engine.buffer_from_literal(&kv.k_lit)?,
                self.engine.buffer_from_literal(&kv.v_lit)?,
            ),
        };
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &k_buf, &v_buf];
        let pbufs = self.param_buffers(&art)?;
        bufs.extend(pbufs);
        let mut out = art.run_buffers(&bufs)?;
        let logits = literal_to_tensor(&out[0], &[b, self.cfg.vocab_size])?;
        kv.v_lit = out.pop().unwrap();
        kv.k_lit = out.pop().unwrap();
        kv.host = None;
        Ok(logits)
    }

    /// Zero-filled KV cache (fresh decode slots).
    pub fn empty_kv(&self, batch: usize) -> KvCache {
        let shape = self.kv_shape(batch);
        let zeros = Tensor::zeros(&shape);
        let k = super::engine::lit_f32(&zeros).expect("zero literal");
        let v = super::engine::lit_f32(&zeros).expect("zero literal");
        KvCache::from_literals(shape, k, v, batch)
    }
}

/// Resolve a layout parameter name to its tensor in the quantized package.
fn param_tensor(qm: &QuantizedModel, name: &str, shape: &[usize]) -> Result<Tensor> {
    if let Some(rest) = name_rot(name) {
        let (site_key, which) = rest;
        let rot = qm
            .rots
            .get(&site_key)
            .ok_or_else(|| anyhow!("missing rotation {site_key}"))?;
        let t = if which == "r1" { rot.r1.clone() } else { rot.r2.clone() };
        if t.shape() != shape {
            bail!("rotation {name}: shape {:?} vs layout {:?}", t.shape(), shape);
        }
        return Ok(t);
    }
    if let Some(site_key) = name_clip(name) {
        let clip = *qm.clips.get(&site_key).unwrap_or(&1.0);
        return Ok(Tensor::from_raw(vec![], vec![clip]));
    }
    let t = qm.weights.get(name)?;
    if t.shape() != shape {
        bail!("weight {name}: shape {:?} vs layout {:?}", t.shape(), shape);
    }
    Ok(t.clone())
}

/// "l00.rot_qkv.r1" -> ("l00.qkv", "r1")
fn name_rot(name: &str) -> Option<(String, &str)> {
    let parts: Vec<&str> = name.split('.').collect();
    if parts.len() == 3 && parts[1].starts_with("rot_") {
        let site = &parts[1][4..];
        return Some((format!("{}.{site}", parts[0]), parts[2]));
    }
    None
}

/// "l00.clip_qkv" -> "l00.qkv"
fn name_clip(name: &str) -> Option<String> {
    let parts: Vec<&str> = name.split('.').collect();
    if parts.len() == 2 && parts[1].starts_with("clip_") {
        let site = &parts[1][5..];
        return Some(format!("{}.{site}", parts[0]));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parsers() {
        assert_eq!(name_rot("l03.rot_down.r2"),
                   Some(("l03.down".to_string(), "r2")));
        assert_eq!(name_rot("l03.wq"), None);
        assert_eq!(name_clip("l00.clip_mlp"), Some("l00.mlp".to_string()));
        assert_eq!(name_clip("l00.an"), None);
    }
}
