//! [`ServeBackend`] implementation over the PJRT [`ModelRunner`]: the
//! production decode backend the continuous batcher schedules.
//!
//! Owns the live KV cache so the coordinator never touches runtime types:
//! prefill merges freshly-filled slot rows into the cache (all layers per
//! admitted slot), decode advances it in place.

use std::sync::Arc;

use anyhow::Result;

use super::runner::{KvCache, ModelRunner};
use crate::coordinator::backend::{BackendLimits, ServeBackend};
use crate::tensor::Tensor;

pub struct RunnerBackend {
    runner: Arc<ModelRunner>,
    kv: KvCache,
    limits: BackendLimits,
}

impl RunnerBackend {
    /// Bind a runner at one of its lowered serve batch sizes.
    pub fn new(runner: Arc<ModelRunner>, batch: usize) -> RunnerBackend {
        let kv = runner.empty_kv(batch);
        let limits = BackendLimits {
            batch,
            score_seq: runner.cfg.score_seq,
            vocab_size: runner.cfg.vocab_size,
            max_seq: runner.cfg.max_seq,
        };
        RunnerBackend { runner, kv, limits }
    }
}

impl ServeBackend for RunnerBackend {
    fn limits(&self) -> BackendLimits {
        self.limits
    }

    fn prefill(&mut self, tokens: &[i32], admitted: &[usize]) -> Result<Tensor> {
        let (logits, mut fresh_kv) = self.runner.prefill(self.limits.batch, tokens)?;
        for &slot in admitted {
            self.kv.copy_slot_from(&self.runner.cfg, &mut fresh_kv, slot)?;
        }
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Tensor> {
        self.runner.decode(&mut self.kv, tokens, positions)
    }
}
