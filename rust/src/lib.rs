//! # SingleQuant
//!
//! A full-system reproduction of *"Outlier Smoothing with Closed-Form
//! Rotations for W4A4 Large Language Model Quantization"* (SingleQuant):
//! optimization-free W4A4 post-training quantization via closed-form Givens
//! rotations (ART + URT) with Kronecker-structured application, plus every
//! baseline the paper evaluates (SmoothQuant, QuaRot, SpinQuant, DuQuant,
//! FlatQuant, GPTQ/AWQ/QuIP weight quantizers).
//!
//! Three-layer architecture (see `DESIGN.md` at the repository root):
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the W4A4
//!   GEMM and Kronecker-rotation hot path, AOT-lowered into the HLO.
//! * **Layer 2** — JAX model (`python/compile/model.py`): LLaMA-style and
//!   MoE forward graphs, lowered once to HLO text.
//! * **Layer 3** — this crate: the quantization pipeline (calibration →
//!   closed-form rotations → weight quantization), the PJRT runtime that
//!   loads and executes the AOT artifacts, the serving coordinator
//!   (continuous batching, per-token event streaming, prefill/decode
//!   scheduling), the HTTP front-end (`server`: OpenAI-style streaming
//!   completions over `std::net`), the evaluation harness, and the
//!   experiment drivers that regenerate every table and figure in the
//!   paper.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `singlequant` binary is self-contained.
//!
//! Unsafe discipline: every unsafe operation needs an explicit block
//! with its own `// SAFETY:` justification even inside `unsafe fn`
//! (enforced below), and `cargo run -p sqlint` checks the comments —
//! plus the thread, determinism, and hot-path-panic contracts — as a
//! blocking CI step. See DESIGN.md "Static analysis & audit".

#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod experiments;
pub mod kv;
pub mod model;
pub mod pipeline;
pub mod quant;
pub mod rotation;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod tensor;
pub mod util;
