//! A small fp model as the draft: its own `NativeModel` with a private
//! contiguous KV cache per slot. Proposals are the draft's greedy
//! continuations; the target model's verifier decides what survives.

use crate::coordinator::sampler::sample;
use crate::coordinator::tokenizer::EOS;
use crate::kv::{KvCache, SlotKv};
use crate::model::native::NativeModel;
use crate::util::rng::Rng;

use super::DraftModel;

/// Per-slot draft state: the draft's KV cache plus the exact token
/// sequence it holds (context prefix and past greedy rollouts alike),
/// so the next `propose` can reconcile against whatever the engine
/// accepted by truncating to the common prefix.
struct DraftSlot {
    kv: SlotKv,
    fed: Vec<u16>,
}

/// A weight-bearing draft model (typically a tiny fp config sharing the
/// target's tokenizer). Keeps one private KV cache per serving slot;
/// rejected rollouts roll back through `KvCache::truncate`, so a wave's
/// draft work is reused whenever the verifier accepted a prefix of it.
pub struct NativeDraft {
    model: NativeModel,
    slots: Vec<DraftSlot>,
}

impl NativeDraft {
    pub fn new(model: NativeModel, batch: usize) -> NativeDraft {
        let slots = (0..batch)
            .map(|_| DraftSlot { kv: model.new_kv(), fed: Vec::new() })
            .collect();
        NativeDraft { model, slots }
    }
}

impl DraftModel for NativeDraft {
    fn propose(&mut self, slot: usize, ctx: &[u16], k: usize) -> Vec<u16> {
        if k == 0 || ctx.is_empty() {
            return Vec::new();
        }
        let s = &mut self.slots[slot];
        // reconcile: keep the cached prefix that still matches `ctx`,
        // but always re-feed at least the last context token so a fresh
        // logits row exists to roll out from
        let common = s
            .fed
            .iter()
            .zip(ctx.iter())
            .take_while(|(a, b)| a == b)
            .count()
            .min(ctx.len() - 1);
        if common < s.fed.len() {
            s.kv.truncate(common);
            s.fed.truncate(common);
        }
        let fresh = &ctx[common..];
        // the draft is advisory: anything it cannot represent (context
        // past its horizon, tokens outside its vocab) just proposes
        // nothing rather than failing the wave
        if s.kv.pos() + fresh.len() + k > self.model.cfg.max_seq
            || fresh.iter().any(|&t| t as usize >= self.model.cfg.vocab_size)
        {
            return Vec::new();
        }
        let Ok(rows) = self.model.step_rows(&mut s.kv, fresh) else {
            return Vec::new();
        };
        s.fed.extend_from_slice(fresh);
        let mut row: Vec<f32> = rows.row(fresh.len() - 1).to_vec();
        // greedy rollout: each proposal is fed back to extend the
        // rollout; the RNG is inert under greedy sampling
        let mut rng = Rng::new(0);
        let mut proposals = Vec::with_capacity(k);
        loop {
            let tok = sample(&mut rng, &row, None);
            proposals.push(tok);
            if tok == EOS || proposals.len() == k {
                return proposals;
            }
            let Ok(next) = self.model.decode(&mut s.kv, tok) else {
                return proposals;
            };
            s.fed.push(tok);
            row = next;
        }
    }

    fn retire(&mut self, slot: usize) {
        self.slots[slot].kv.reset();
        self.slots[slot].fed.clear();
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::weights::Weights;

    fn draft() -> NativeDraft {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 21);
        NativeDraft::new(NativeModel::from_weights(&cfg, &w, None, 1).unwrap(), 2)
    }

    #[test]
    fn proposals_match_the_drafts_own_greedy_decode() {
        let mut d = draft();
        let ctx = [3u16, 17, 40, 9];
        let got = d.propose(0, &ctx, 3);
        assert_eq!(got.len(), 3);
        // reference: fresh greedy decode on the same model
        let mut kv = d.model.new_kv();
        let rows = d.model.step_rows(&mut kv, &ctx).unwrap();
        let mut rng = Rng::new(0);
        let mut want = Vec::new();
        let mut row = rows.row(ctx.len() - 1).to_vec();
        for _ in 0..3 {
            let tok = sample(&mut rng, &row, None);
            want.push(tok);
            if tok == EOS {
                break;
            }
            row = d.model.decode(&mut kv, tok).unwrap();
        }
        assert_eq!(got, want);
    }

    #[test]
    fn reconciles_cached_state_across_divergent_contexts() {
        let mut d = draft();
        let ctx1 = [3u16, 17, 40, 9];
        let first = d.propose(0, &ctx1, 4);
        // the engine rejected everything and sampled a different token:
        // the cached rollout must be truncated, not replayed
        let mut ctx2 = ctx1.to_vec();
        ctx2.push(55);
        let _ = d.propose(0, &ctx2, 4);
        // back on a fresh slot, the original context reproposes the same
        let ctx1_again = d.propose(1, &ctx1, 4);
        assert_eq!(first, ctx1_again, "slot state must not leak across slots");
        // and the reconciled slot, handed ctx1's extension by its own
        // first proposal, still matches a from-scratch draft
        let mut accepted = ctx1.to_vec();
        accepted.push(first[0]);
        let a = d.propose(0, &accepted, 3);
        let mut fresh = draft();
        let b = fresh.propose(0, &accepted, 3);
        assert_eq!(a, b, "reconciliation must be invisible in the proposals");
    }

    #[test]
    fn oversized_context_proposes_nothing() {
        let mut d = draft();
        let long = vec![5u16; d.model.cfg.max_seq];
        assert!(d.propose(0, &long, 4).is_empty());
        d.retire(0);
        assert_eq!(d.slots[0].kv.pos(), 0);
    }
}
