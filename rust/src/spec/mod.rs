//! Speculative decoding: draft-model token proposal, multi-row
//! verification on the target model, and longest-exact-prefix
//! acceptance — with output guaranteed bit-identical to non-speculative
//! decode.
//!
//! The wave shape: a [`DraftModel`] proposes up to `k` cheap tokens
//! `d1..dk` to follow the current context; the target model consumes
//! the burst `[x0, d1..dk]` (`x0` = the pending last sampled token) in
//! ONE `model::native::NativeModel::step_rows` call, whose row `i` is
//! bit-identical to the logits sequential decode would produce after
//! the same tokens. The accept loop then walks the rows sampling with
//! the positional RNG (`coordinator::sampler`): the token sampled at
//! row `i` is *emitted*; if it equals the next draft token the walk
//! continues, otherwise (or on EOS) it stops. Every emitted token is
//! therefore sampled from the same logits row with the same RNG stream
//! the non-speculative engine would have used — acceptance never
//! changes the output, only how many target-model calls it took.
//! Rejected rows roll back through `kv::KvCache::truncate`, which
//! returns whole pages to the paged pool; a failed or degraded burst
//! leaves the slot replayable, composing with the batcher's
//! preemption exactly like plain decode.
//!
//! Two drafts ship: [`NgramDraft`], a zero-weight prompt-lookup draft
//! (longest context suffix that recurred earlier proposes its
//! continuation), and [`NativeDraft`], a small fp model running its own
//! private KV. The serving integration lives in
//! `coordinator::batcher::ServeEngine::enable_speculation`; the
//! standalone [`SpeculativeDecoder`] drives a single sequence for
//! benches and the equivalence property tests.

mod decoder;
mod native_draft;
mod ngram;

pub use decoder::{SpecStats, SpeculativeDecoder};
pub use native_draft::NativeDraft;
pub use ngram::NgramDraft;

/// A token proposer. Drafts are *advisory*: the verifier accepts a
/// proposal only when the target model's own sampled token equals it,
/// so a wrong (or adversarial) draft can cost speed but never
/// correctness. Implementations may keep per-slot state (the native
/// draft holds a KV cache per slot) and must reconcile it against the
/// `ctx` they are handed — the engine rolls contexts back on rejection
/// and replays them after preemption.
pub trait DraftModel: Send {
    /// Propose up to `k` tokens to follow `ctx` (prompt ++ everything
    /// generated so far, including the pending last token) for `slot`.
    /// Fewer than `k` — or none — is always acceptable.
    fn propose(&mut self, slot: usize, ctx: &[u16], k: usize) -> Vec<u16>;

    /// The slot finished, was preempted, or aborted: drop any per-slot
    /// draft state. Stateless drafts keep the default no-op.
    fn retire(&mut self, _slot: usize) {}

    /// Short name for metrics and logs ("ngram", "native").
    fn label(&self) -> &'static str;
}
