//! The single-sequence speculative decode driver: propose → verify →
//! accept → roll back, one wave at a time, over any [`KvCache`]. The
//! serving engine reimplements this loop across slots (through
//! `ServeBackend::decode_burst`); this standalone form is what the
//! equivalence property tests pin down and what the bench section
//! measures.

use anyhow::Result;

use crate::coordinator::sampler::{sample, token_rng};
use crate::coordinator::tokenizer::{BOS, EOS, PAD};
use crate::kv::{KvCache, KvError};
use crate::model::native::NativeModel;

use super::DraftModel;

/// Counters from one [`SpeculativeDecoder::generate`] run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens submitted to the verifier (after clamping/filtering).
    pub proposed: usize,
    /// Draft tokens the verifier accepted.
    pub accepted: usize,
    /// Verification waves run (= target-model calls after prefill).
    pub waves: usize,
}

impl SpecStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Drives one sequence speculatively against a target model. Sampling
/// uses the positional RNG (`coordinator::sampler`), so the produced
/// token stream is bit-identical to sequential decode with the same
/// `(seed, request_id)` — greedy and sampled alike — regardless of the
/// draft's quality (the property tests below sweep drafts from oracle
/// to adversarial).
pub struct SpeculativeDecoder<'m> {
    model: &'m NativeModel,
    k: usize,
}

impl<'m> SpeculativeDecoder<'m> {
    pub fn new(model: &'m NativeModel, k: usize) -> SpeculativeDecoder<'m> {
        assert!(k >= 1, "speculation depth must be at least 1");
        SpeculativeDecoder { model, k }
    }

    /// Generate up to `max_new` tokens after `prompt` (the trailing EOS,
    /// if sampled, is included and terminates generation). On paged
    /// caches a burst that cannot reserve degrades to a single-token
    /// step; if even that fails the error propagates with the cache
    /// untouched since the last accepted position — the caller can free
    /// pages and replay, exactly like plain decode under preemption.
    pub fn generate<K: KvCache>(
        &self,
        kv: &mut K,
        draft: &mut dyn DraftModel,
        prompt: &[u16],
        max_new: usize,
        seed: u64,
        request_id: u64,
        temperature: Option<f32>,
    ) -> Result<(Vec<u16>, SpecStats)> {
        let mut stats = SpecStats::default();
        let mut generated: Vec<u16> = Vec::new();
        if max_new == 0 {
            return Ok((generated, stats));
        }
        let logits = self.model.prefill(kv, prompt)?;
        let first = sample(
            &mut token_rng(seed, request_id, 0),
            logits.row(prompt.len() - 1),
            temperature,
        );
        generated.push(first);
        let mut last = first;
        while last != EOS && generated.len() < max_new {
            // clamp: the emitted prefix may not pass max_new, the
            // appended rows may not pass the cache horizon
            let want = self
                .k
                .min(max_new - generated.len() - 1)
                .min(self.model.cfg.max_seq.saturating_sub(kv.pos() + 1));
            let mut burst = vec![last];
            if want > 0 {
                let mut ctx = prompt.to_vec();
                ctx.extend_from_slice(&generated);
                for d in draft.propose(0, &ctx, want).into_iter().take(want) {
                    if d == PAD || d == BOS || d as usize >= self.model.cfg.vocab_size {
                        break;
                    }
                    burst.push(d);
                    if d == EOS {
                        break;
                    }
                }
            }
            let before = kv.pos();
            let rows = match self.model.step_rows(kv, &burst) {
                Ok(rows) => rows,
                Err(e) if burst.len() > 1 && is_pool_exhausted(&e) => {
                    // degrade to a plain decode step — covered by one
                    // position, which is all sequential decode needs
                    burst.truncate(1);
                    self.model.step_rows(kv, &burst)?
                }
                Err(e) => return Err(e),
            };
            stats.waves += 1;
            stats.proposed += burst.len() - 1;
            let mut emitted = 0usize;
            for r in 0..burst.len() {
                let tok = sample(
                    &mut token_rng(seed, request_id, generated.len()),
                    rows.row(r),
                    temperature,
                );
                generated.push(tok);
                last = tok;
                emitted += 1;
                if tok == EOS || r + 1 >= burst.len() || tok != burst[r + 1] {
                    break;
                }
            }
            stats.accepted += emitted - 1;
            if before + emitted < kv.pos() {
                kv.truncate(before + emitted);
            }
        }
        Ok((generated, stats))
    }
}

fn is_pool_exhausted(e: &anyhow::Error) -> bool {
    matches!(e.downcast_ref::<KvError>(), Some(KvError::PoolExhausted { .. }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{BlockPool, PageTable, PagedSlot};
    use crate::model::config::tests::test_config;
    use crate::model::layers::QuantCtx;
    use crate::model::weights::Weights;
    use crate::spec::{NativeDraft, NgramDraft};
    use crate::util::rng::Rng;

    /// A draft that proposes a fixed wrong token k times — worst case:
    /// every wave verifies a full burst and rejects everything.
    struct AdversarialDraft;
    impl DraftModel for AdversarialDraft {
        fn propose(&mut self, _slot: usize, ctx: &[u16], k: usize) -> Vec<u16> {
            // always "wrong": one past whatever the context ends with
            let t = ctx.last().copied().unwrap_or(0);
            vec![(t + 101) % 250; k]
        }
        fn label(&self) -> &'static str {
            "adversarial"
        }
    }

    fn models() -> Vec<NativeModel> {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        vec![
            NativeModel::from_weights(&cfg, &w, None, 2).unwrap(),
            NativeModel::from_weights(&cfg, &w, Some(QuantCtx::identity(&cfg, 4)), 2)
                .unwrap(),
        ]
    }

    /// The non-speculative reference: sequential decode with the same
    /// positional sampler.
    fn sequential<K: KvCache>(
        nm: &NativeModel,
        kv: &mut K,
        prompt: &[u16],
        max_new: usize,
        seed: u64,
        id: u64,
        temperature: Option<f32>,
    ) -> Vec<u16> {
        let logits = nm.prefill(kv, prompt).unwrap();
        let mut out = Vec::new();
        let mut last = sample(
            &mut token_rng(seed, id, 0),
            logits.row(prompt.len() - 1),
            temperature,
        );
        out.push(last);
        while last != EOS && out.len() < max_new {
            let row = nm.decode(kv, last).unwrap();
            last = sample(&mut token_rng(seed, id, out.len()), &row, temperature);
            out.push(last);
        }
        out
    }

    fn prompt() -> Vec<u16> {
        let mut rng = Rng::new(5);
        (0..8).map(|_| rng.below(250) as u16).collect()
    }

    /// The tentpole property: speculative output is bit-identical to
    /// sequential decode — fp and w4a4 targets, greedy and sampled,
    /// contiguous and paged KV (page sizes splitting bursts mid-page and
    /// on boundaries), k in {1, 2, 4, 8}, and drafts from oracle
    /// (same-weights native) through prompt-lookup to adversarial.
    #[test]
    fn speculative_output_is_bit_identical_to_sequential() {
        let p = prompt();
        let max_new = 12;
        for nm in &models() {
            for &temperature in &[None, Some(0.8)] {
                let mut ref_kv = nm.new_kv();
                let want = sequential(nm, &mut ref_kv, &p, max_new, 7, 1, temperature);
                for k in [1usize, 2, 4, 8] {
                    let dec = SpeculativeDecoder::new(nm, k);
                    let drafts: Vec<Box<dyn DraftModel>> = vec![
                        Box::new(NgramDraft::new(3)),
                        Box::new(AdversarialDraft),
                        Box::new(NativeDraft::new(
                            NativeModel::from_weights(
                                &nm.cfg,
                                &Weights::random_init(&nm.cfg, 1),
                                None,
                                1,
                            )
                            .unwrap(),
                            1,
                        )),
                    ];
                    for mut draft in drafts {
                        // contiguous
                        let mut kv = nm.new_kv();
                        let (got, stats) = dec
                            .generate(&mut kv, draft.as_mut(), &p, max_new, 7, 1, temperature)
                            .unwrap();
                        assert_eq!(
                            got, want,
                            "contig k={k} draft={} temp={temperature:?}",
                            draft.label()
                        );
                        assert!(stats.accepted <= stats.proposed);
                        draft.retire(0);

                        // paged, across page sizes
                        for pt in [1usize, 7, 16] {
                            let mut pool = BlockPool::new(
                                nm.cfg.n_layers,
                                nm.cfg.d_model,
                                pt,
                                (p.len() + max_new + k + 1).div_ceil(pt),
                            );
                            let mut table = PageTable::new();
                            let mut slot =
                                PagedSlot { pool: &mut pool, table: &mut table };
                            let (got, _) = dec
                                .generate(
                                    &mut slot,
                                    draft.as_mut(),
                                    &p,
                                    max_new,
                                    7,
                                    1,
                                    temperature,
                                )
                                .unwrap();
                            assert_eq!(
                                got, want,
                                "paged pt={pt} k={k} draft={} temp={temperature:?}",
                                draft.label()
                            );
                            draft.retire(0);
                        }
                    }
                }
            }
        }
    }

    /// An oracle draft (same weights as the greedy target) must reach
    /// 100% acceptance and finish in fewer waves than tokens; the
    /// adversarial draft must reach 0% while still being exact.
    #[test]
    fn acceptance_spans_oracle_to_adversarial() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 2).unwrap();
        let p = prompt();
        let max_new = 12;
        let dec = SpeculativeDecoder::new(&nm, 4);

        let mut ref_kv = nm.new_kv();
        let want = sequential(&nm, &mut ref_kv, &p, max_new, 7, 1, None);

        let oracle_model = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let mut oracle = NativeDraft::new(oracle_model, 1);
        let mut kv = nm.new_kv();
        let (got, stats) =
            dec.generate(&mut kv, &mut oracle, &p, max_new, 7, 1, None).unwrap();
        assert_eq!(got, want);
        assert_eq!(
            stats.accepted, stats.proposed,
            "a same-weights greedy draft is always right"
        );
        if want.len() > 2 {
            assert!(
                stats.waves < want.len() - 1,
                "oracle speculation must save target-model calls: {} waves for {} tokens",
                stats.waves,
                want.len()
            );
        }

        let mut kv = nm.new_kv();
        let (got, stats) = dec
            .generate(&mut kv, &mut AdversarialDraft, &p, max_new, 7, 1, None)
            .unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.accepted, 0, "nothing adversarial may survive verification");
        assert!(stats.proposed > 0);
    }

    /// Deterministic pool pressure: a cache that refuses every
    /// multi-position reservation after prefill, so each burst hits
    /// `PoolExhausted` mid-generation and must fall back to a plain
    /// single-token step without changing the output.
    struct SingleStepOnly<K: KvCache>(K);

    impl<K: KvCache> crate::kv::KvRows for SingleStepOnly<K> {
        fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
            self.0.rows(layer, pos)
        }
    }

    impl<K: KvCache> KvCache for SingleStepOnly<K> {
        fn pos(&self) -> usize {
            self.0.pos()
        }
        fn reserve(&mut self, extra: usize) -> Result<(), KvError> {
            if self.0.pos() > 0 && extra > 1 {
                return Err(KvError::PoolExhausted { needed: extra, free: 1 });
            }
            self.0.reserve(extra)
        }
        fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
            self.0.append_row(layer, pos, k, v)
        }
        fn advance(&mut self, n: usize) {
            self.0.advance(n)
        }
        fn truncate(&mut self, n: usize) {
            self.0.truncate(n)
        }
    }

    #[test]
    fn pool_pressure_degrades_bursts_and_stays_exact() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 2).unwrap();
        let p = prompt();
        let max_new = 6;
        let mut ref_kv = nm.new_kv();
        let want = sequential(&nm, &mut ref_kv, &p, max_new, 7, 1, None);

        let mut kv = SingleStepOnly(nm.new_kv());
        let dec = SpeculativeDecoder::new(&nm, 4);
        let (got, stats) = dec
            .generate(&mut kv, &mut AdversarialDraft, &p, max_new, 7, 1, None)
            .unwrap();
        assert_eq!(got, want, "degraded waves must not change output");
        assert_eq!(stats.waves, want.len() - 1, "every wave fell back to one token");
        assert_eq!(
            (stats.proposed, stats.accepted),
            (0, 0),
            "no draft token reached the verifier under pressure"
        );
    }
}
