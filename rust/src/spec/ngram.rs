//! Prompt-lookup draft: zero weights, zero KV. The longest recent
//! suffix of the context that occurred earlier proposes the tokens that
//! followed that earlier occurrence — strong on repetitive contexts
//! (code, templated text, the bench's cyclic prompts), free elsewhere.

use super::DraftModel;

/// N-gram / prompt-lookup draft. `max_n` bounds the suffix length
/// matched against history; longer matches are tried first, so the most
/// specific recurrence wins.
pub struct NgramDraft {
    max_n: usize,
}

impl NgramDraft {
    pub fn new(max_n: usize) -> NgramDraft {
        assert!(max_n >= 1, "suffix length must be at least 1");
        NgramDraft { max_n }
    }
}

impl DraftModel for NgramDraft {
    fn propose(&mut self, _slot: usize, ctx: &[u16], k: usize) -> Vec<u16> {
        if k == 0 || ctx.len() < 2 {
            return Vec::new();
        }
        for n in (1..=self.max_n.min(ctx.len() - 1)).rev() {
            let suffix = &ctx[ctx.len() - n..];
            // most recent earlier occurrence: windows ending before the
            // final position, newest first (an overlap with the suffix
            // itself is fine — that is what continues a period-n cycle)
            for end in (n..ctx.len()).rev() {
                if &ctx[end - n..end] == suffix {
                    let cont = &ctx[end..(end + k).min(ctx.len())];
                    if !cont.is_empty() {
                        return cont.to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    fn label(&self) -> &'static str {
        "ngram"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposes_continuation_of_longest_recurring_suffix() {
        let mut d = NgramDraft::new(3);
        // ctx ends in [7, 8]; the earlier [7, 8] at positions 0..2 is
        // followed by [9, 7] — the proposal
        let ctx = [7u16, 8, 9, 7, 8];
        assert_eq!(d.propose(0, &ctx, 2), vec![9, 7]);
        // the longer match wins over a shorter, more recent one
        let ctx = [1u16, 2, 3, 9, 2, 3, 5, 1, 2, 3];
        assert_eq!(d.propose(0, &ctx, 2), vec![9, 2]);
    }

    #[test]
    fn continues_a_periodic_cycle_through_self_overlap() {
        let mut d = NgramDraft::new(2);
        let ctx = [4u16, 5, 4, 5, 4, 5];
        // suffix [5, 4, 5]... max_n=2: suffix [4, 5] recurs ending at 4,
        // continuation [4, 5]
        assert_eq!(d.propose(0, &ctx, 4), vec![4, 5]);
    }

    #[test]
    fn no_match_or_degenerate_context_proposes_nothing() {
        let mut d = NgramDraft::new(3);
        assert!(d.propose(0, &[1, 2, 3, 4, 5], 4).is_empty());
        assert!(d.propose(0, &[9], 4).is_empty());
        assert!(d.propose(0, &[], 4).is_empty());
        assert!(d.propose(0, &[1, 1, 2], 0).is_empty());
    }
}
