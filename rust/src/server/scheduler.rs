//! The scheduler thread: sole owner of the [`ServeEngine`].
//!
//! HTTP handler threads never touch the engine; they talk to this thread
//! over a bounded `std::sync::mpsc` command channel. Each `Submit` carries
//! the request, a per-token event sink, and a one-shot reply channel the
//! scheduler answers with the admission verdict — so bounded admission
//! (HTTP 429) is decided by exactly one authority, the engine's
//! `try_submit`. The loop ticks the engine while it has work, blocks on
//! the command channel when idle, and publishes a metrics snapshot the
//! `/metrics` and `/healthz` handlers read lock-free of the engine.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::AdmissionError;
use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::{BackendLimits, Request, ServeEngine, TokenEvent};

/// Commands handler threads send the scheduler.
pub enum SchedCmd {
    Submit {
        req: Request,
        /// Receives Started/Token/Done/Failed for this request.
        sink: Sender<TokenEvent>,
        /// Answered once with the admission verdict.
        reply: Sender<Result<(), AdmissionError>>,
    },
    /// Stop admitting, drain in-flight work, then exit the thread.
    Shutdown,
}

/// State the scheduler shares with HTTP handlers.
pub struct SchedulerShared {
    /// Snapshot of the engine's metrics, refreshed every tick.
    pub metrics: Mutex<ServeMetrics>,
    pub limits: BackendLimits,
    pub active: AtomicUsize,
    pub pending: AtomicUsize,
    /// True once shutdown started (health reports "draining").
    pub draining: AtomicBool,
}

pub struct SchedulerHandle {
    pub tx: SyncSender<SchedCmd>,
    pub thread: JoinHandle<()>,
    pub shared: Arc<SchedulerShared>,
}

/// How long the scheduler parks on the command channel when idle.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Snapshot cadence: cloning the metrics (histogram windows included) on
/// every tick of a fast backend would spend the hot path on memcpy for a
/// surface scraped at most every few seconds.
const PUBLISH_EVERY: Duration = Duration::from_millis(50);

/// Move the engine onto its own named thread. `channel_cap` bounds the
/// command backlog; handler `try_send` failures are the fast-path 429
/// under extreme burst, engine `try_submit` the authoritative one.
pub fn spawn(engine: ServeEngine, channel_cap: usize) -> SchedulerHandle {
    let shared = Arc::new(SchedulerShared {
        metrics: Mutex::new(ServeMetrics::default()),
        limits: engine.limits(),
        active: AtomicUsize::new(0),
        pending: AtomicUsize::new(0),
        draining: AtomicBool::new(false),
    });
    let (tx, rx) = sync_channel(channel_cap.max(1));
    let shared2 = shared.clone();
    let thread = std::thread::Builder::new()
        .name("sq-scheduler".into())
        .spawn(move || run(engine, rx, shared2))
        .expect("spawn scheduler thread");
    SchedulerHandle { tx, thread, shared }
}

fn run(mut engine: ServeEngine, rx: Receiver<SchedCmd>, shared: Arc<SchedulerShared>) {
    let mut shutting = false;
    let mut last_publish: Option<Instant> = None;
    loop {
        // drain queued commands without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => handle_cmd(cmd, &mut engine, &mut shutting, &shared),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutting = true;
                    break;
                }
            }
        }

        if engine.has_work() {
            if let Err(e) = engine.step() {
                // A backend fault must not kill the serving loop: fail
                // everything in flight (subscribers get Failed) and keep
                // accepting — the next tick starts clean.
                eprintln!("[serve-http] backend error, aborting in-flight work: {e:#}");
                engine.abort_all(&format!("backend failure: {e:#}"));
            }
            if last_publish.map_or(true, |t| t.elapsed() >= PUBLISH_EVERY) {
                publish(&engine, &shared);
                last_publish = Some(Instant::now());
            }
            continue;
        }

        if last_publish.map_or(true, |t| t.elapsed() >= PUBLISH_EVERY) {
            publish(&engine, &shared);
            last_publish = Some(Instant::now());
        }
        if shutting {
            break;
        }
        match rx.recv_timeout(IDLE_POLL) {
            Ok(cmd) => handle_cmd(cmd, &mut engine, &mut shutting, &shared),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    publish(&engine, &shared);
}

fn handle_cmd(
    cmd: SchedCmd,
    engine: &mut ServeEngine,
    shutting: &mut bool,
    shared: &SchedulerShared,
) {
    match cmd {
        SchedCmd::Submit { req, sink, reply } => {
            let verdict = if *shutting {
                // refuse new work while draining; 429 tells clients to retry
                // against a healthy replica
                engine.metrics.rejected += 1;
                Err(AdmissionError::QueueFull { cap: 0 })
            } else {
                engine.try_submit(req, Some(sink))
            };
            let _ = reply.send(verdict);
        }
        SchedCmd::Shutdown => {
            *shutting = true;
            shared.draining.store(true, Ordering::SeqCst);
        }
    }
}

fn publish(engine: &ServeEngine, shared: &SchedulerShared) {
    shared.active.store(engine.active(), Ordering::Relaxed);
    shared.pending.store(engine.pending(), Ordering::Relaxed);
    if let Ok(mut m) = shared.metrics.lock() {
        *m = engine.metrics.clone();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;

    use super::*;
    use crate::coordinator::{ServeConfig, SyntheticBackend};

    fn spawn_synthetic(queue_cap: usize) -> SchedulerHandle {
        let engine = ServeEngine::new(
            Box::new(SyntheticBackend::new(2).with_seq(32, 64)),
            ServeConfig { max_new_cap: 8, seed: 3, queue_cap },
        );
        spawn(engine, queue_cap + 4)
    }

    #[test]
    fn submits_round_trip_through_the_thread() {
        let h = spawn_synthetic(8);
        let (sink, events) = channel();
        let (rtx, rrx) = channel();
        h.tx.send(SchedCmd::Submit {
            req: Request::new(1, vec![5, 6]).with_max_new(3),
            sink,
            reply: rtx,
        })
        .unwrap();
        rrx.recv_timeout(Duration::from_secs(5))
            .expect("reply arrives")
            .expect("admitted");
        let mut tokens = 0;
        loop {
            match events.recv_timeout(Duration::from_secs(5)).expect("event") {
                TokenEvent::Token { .. } => tokens += 1,
                TokenEvent::Done { response, .. } => {
                    assert_eq!(response.tokens.len(), 3);
                    break;
                }
                TokenEvent::Started { .. } => {}
                TokenEvent::Failed { error, .. } => panic!("failed: {error}"),
            }
        }
        assert_eq!(tokens, 3);
        h.tx.send(SchedCmd::Shutdown).unwrap();
        h.thread.join().unwrap();
        assert!(h.shared.draining.load(Ordering::SeqCst));
    }

    #[test]
    fn shutdown_refuses_new_work() {
        let h = spawn_synthetic(8);
        h.tx.send(SchedCmd::Shutdown).unwrap();
        // the scheduler may already have exited; a refused send is also a
        // correct outcome
        let (sink, _events) = channel();
        let (rtx, rrx) = channel();
        let sent = h
            .tx
            .send(SchedCmd::Submit {
                req: Request::new(9, vec![1]),
                sink,
                reply: rtx,
            })
            .is_ok();
        if sent {
            if let Ok(verdict) = rrx.recv_timeout(Duration::from_secs(5)) {
                assert!(verdict.is_err(), "draining scheduler must refuse work");
            }
        }
        drop(h.tx);
        h.thread.join().unwrap();
    }
}
