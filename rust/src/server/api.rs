//! The OpenAI-style completions wire format: request parsing and JSON /
//! SSE-chunk rendering, kept separate from socket handling so it unit
//! tests without a server.

use std::time::{SystemTime, UNIX_EPOCH};

use crate::coordinator::{FinishReason, Response};
use crate::util::json::Json;

/// Parsed + defaulted body of `POST /v1/completions`.
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionParams {
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: Option<f32>,
    pub stream: bool,
    /// Optional per-request deadline (milliseconds from admission).
    pub deadline_ms: Option<u64>,
}

/// Validate a completions body. `Err` carries a client-facing message
/// (HTTP 400).
pub fn parse_completion(
    body: &Json,
    default_max_tokens: usize,
    default_deadline_ms: Option<u64>,
) -> Result<CompletionParams, String> {
    if body.as_obj().is_err() {
        return Err("body must be a JSON object".to_string());
    }
    let prompt = match body.opt("prompt") {
        Some(Json::Str(s)) => s.clone(),
        Some(_) => return Err("\"prompt\" must be a string".to_string()),
        None => return Err("missing required field \"prompt\"".to_string()),
    };
    if prompt.is_empty() {
        return Err("\"prompt\" must be non-empty".to_string());
    }
    let max_tokens = match body.opt("max_tokens") {
        Some(v) => match v.as_f64() {
            Ok(x) if x >= 0.0 => (x as usize).max(1),
            _ => return Err("\"max_tokens\" must be a non-negative number".to_string()),
        },
        None => default_max_tokens,
    };
    let temperature = match body.opt("temperature") {
        Some(v) => match v.as_f64() {
            Ok(x) => Some(x as f32),
            Err(_) => return Err("\"temperature\" must be a number".to_string()),
        },
        None => None,
    };
    let stream = match body.opt("stream") {
        Some(v) => v
            .as_bool()
            .map_err(|_| "\"stream\" must be a boolean".to_string())?,
        None => false,
    };
    // capped at 24h so downstream arithmetic (relay timeout = deadline +
    // margin) can never overflow
    const MAX_DEADLINE_MS: f64 = 86_400_000.0;
    let deadline_ms = match body.opt("deadline_ms") {
        Some(v) => match v.as_f64() {
            Ok(x) if x > 0.0 => Some(x.min(MAX_DEADLINE_MS) as u64),
            _ => return Err("\"deadline_ms\" must be a positive number".to_string()),
        },
        None => default_deadline_ms,
    };
    Ok(CompletionParams { prompt, max_tokens, temperature, stream, deadline_ms })
}

fn unix_now() -> i64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0)
}

fn cmpl_id(id: u64) -> Json {
    Json::str(format!("cmpl-{id}"))
}

/// Full (non-streaming) completion response body.
pub fn completion_json(model: &str, resp: &Response) -> Json {
    Json::obj(vec![
        ("id", cmpl_id(resp.id)),
        ("object", Json::str("text_completion")),
        ("created", Json::int(unix_now())),
        ("model", Json::str(model)),
        (
            "choices",
            Json::arr(vec![Json::obj(vec![
                ("index", Json::int(0)),
                ("text", Json::str(resp.text.clone())),
                ("finish_reason", Json::str(resp.finish.as_str())),
            ])]),
        ),
        (
            "usage",
            Json::obj(vec![
                ("prompt_tokens", Json::usize(resp.prompt_len)),
                ("completion_tokens", Json::usize(resp.tokens.len())),
                ("total_tokens", Json::usize(resp.prompt_len + resp.tokens.len())),
            ]),
        ),
        (
            "timing",
            Json::obj(vec![
                ("ttft_s", Json::num(resp.ttft_s)),
                ("latency_s", Json::num(resp.latency_s)),
            ]),
        ),
    ])
}

/// One SSE chunk: a token delta, or the closing chunk carrying the finish
/// reason when `finish` is set.
pub fn chunk_json(model: &str, id: u64, text: &str, finish: Option<FinishReason>) -> Json {
    Json::obj(vec![
        ("id", cmpl_id(id)),
        ("object", Json::str("text_completion.chunk")),
        ("created", Json::int(unix_now())),
        ("model", Json::str(model)),
        (
            "choices",
            Json::arr(vec![Json::obj(vec![
                ("index", Json::int(0)),
                ("text", Json::str(text)),
                (
                    "finish_reason",
                    match finish {
                        Some(f) => Json::str(f.as_str()),
                        None => Json::Null,
                    },
                ),
            ])]),
        ),
    ])
}

/// Error body, OpenAI-shaped: `{"error": {"message", "type"}}`.
pub fn error_json(kind: &str, message: &str) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("message", Json::str(message)),
            ("type", Json::str(kind)),
        ]),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<CompletionParams, String> {
        parse_completion(&Json::parse(s).unwrap(), 16, None)
    }

    #[test]
    fn parses_full_body() {
        let p = parse(
            r#"{"prompt": "hi", "max_tokens": 4, "temperature": 0.7,
                "stream": true, "deadline_ms": 250}"#,
        )
        .unwrap();
        assert_eq!(p.prompt, "hi");
        assert_eq!(p.max_tokens, 4);
        assert_eq!(p.temperature, Some(0.7));
        assert!(p.stream);
        assert_eq!(p.deadline_ms, Some(250));
    }

    #[test]
    fn applies_defaults() {
        let p = parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(p.max_tokens, 16);
        assert_eq!(p.temperature, None);
        assert!(!p.stream);
        assert_eq!(p.deadline_ms, None);
    }

    #[test]
    fn rejects_bad_bodies() {
        assert!(parse(r#"{}"#).is_err(), "missing prompt");
        assert!(parse(r#"{"prompt": 3}"#).is_err(), "non-string prompt");
        assert!(parse(r#"{"prompt": ""}"#).is_err(), "empty prompt");
        assert!(parse(r#"{"prompt": "x", "stream": "yes"}"#).is_err());
        assert!(parse(r#"{"prompt": "x", "max_tokens": -1}"#).is_err());
        assert!(parse(r#"[1,2]"#).is_err(), "non-object body");
    }

    #[test]
    fn max_tokens_zero_means_one() {
        let p = parse(r#"{"prompt": "x", "max_tokens": 0}"#).unwrap();
        assert_eq!(p.max_tokens, 1);
    }

    #[test]
    fn absurd_deadline_is_capped() {
        let p = parse(r#"{"prompt": "x", "deadline_ms": 1e30}"#).unwrap();
        assert_eq!(p.deadline_ms, Some(86_400_000));
    }

    #[test]
    fn renders_wire_shapes() {
        let resp = Response {
            id: 7,
            tokens: vec![1, 2],
            text: "ab".into(),
            ttft_s: 0.01,
            latency_s: 0.05,
            prompt_len: 3,
            finish: FinishReason::Length,
        };
        let body = completion_json("sq-m", &resp).to_string();
        assert!(body.contains("\"id\":\"cmpl-7\""));
        assert!(body.contains("\"finish_reason\":\"length\""));
        assert!(body.contains("\"total_tokens\":5"));

        let chunk = chunk_json("sq-m", 7, "a", None).to_string();
        assert!(chunk.contains("\"finish_reason\":null"));
        let last = chunk_json("sq-m", 7, "", Some(FinishReason::Eos)).to_string();
        assert!(last.contains("\"finish_reason\":\"stop\""));

        let err = error_json("overloaded_error", "queue full").to_string();
        assert_eq!(
            err,
            r#"{"error":{"message":"queue full","type":"overloaded_error"}}"#
        );
    }
}
