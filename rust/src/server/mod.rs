//! HTTP serving front-end: a streaming completions API over the
//! continuous batcher.
//!
//! Dependency-light by construction — `std::net` + the hand-rolled JSON in
//! `util::json`; no async runtime. Threading model (see `DESIGN.md`):
//!
//! * **scheduler thread** (`scheduler.rs`) — sole owner of the
//!   [`ServeEngine`]; runs the admit/prefill/decode tick loop and answers
//!   admission verdicts over a bounded mpsc command channel.
//! * **accept thread** — blocking `TcpListener::accept`; spawns one
//!   short-lived handler thread per connection (one request per
//!   connection, `Connection: close`).
//! * **handler threads** — parse HTTP, submit to the scheduler, then relay
//!   [`TokenEvent`]s: SSE frames for `"stream": true`, a single JSON body
//!   otherwise. On the streaming path a dropped client surfaces as a failed
//!   SSE write, the handler drops its receiver, and the engine cancels the
//!   request — freeing the slot the same tick. Non-streaming handlers only
//!   touch the socket at the end, so a mid-generation disconnect there is
//!   bounded by the request deadline rather than detected eagerly.
//!
//! Endpoints: `POST /v1/completions` (OpenAI-style, optional SSE),
//! `GET /healthz`, `GET /metrics` (Prometheus text), `POST
//! /admin/shutdown` (graceful drain). Overload returns HTTP 429 rather
//! than queueing unboundedly.

pub mod api;
pub mod http;
pub mod scheduler;

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::batcher::AdmissionError;
use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::tokenizer;
use crate::coordinator::{Request, ServeEngine, TokenEvent};
use crate::util::json::Json;

use api::{chunk_json, completion_json, error_json, parse_completion};
use http::{write_response, write_sse_data, write_sse_headers, HttpRequest};
use scheduler::{SchedCmd, SchedulerHandle, SchedulerShared};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// `max_tokens` when the request omits it.
    pub default_max_tokens: usize,
    /// Deadline applied to requests that don't set `deadline_ms`.
    pub default_deadline_ms: Option<u64>,
    /// Model label echoed in the wire format.
    pub model: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8071".to_string(),
            default_max_tokens: 16,
            default_deadline_ms: None,
            model: "singlequant".to_string(),
        }
    }
}

/// Shared server state (everything handler threads need).
struct ServerState {
    cfg: ServerConfig,
    sched_tx: SyncSender<SchedCmd>,
    sched_shared: Arc<SchedulerShared>,
    addr: SocketAddr,
    stop: AtomicBool,
    next_id: AtomicU64,
    http_requests: AtomicU64,
    http_400: AtomicU64,
    http_404: AtomicU64,
    http_429: AtomicU64,
    http_500: AtomicU64,
    streams_opened: AtomicU64,
}

impl ServerState {
    /// Begin graceful drain: stop accepting, tell the scheduler to finish
    /// in-flight work and exit. The blocking `send` is safe: the
    /// scheduler always drains its channel between ticks. The accept loop
    /// polls nonblockingly, so it observes `stop` within one poll
    /// interval without needing a wake-up connection.
    fn request_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.sched_tx.send(SchedCmd::Shutdown);
    }
}

/// A running server; dropping the handle does NOT stop it — call
/// [`ServerHandle::shutdown`].
pub struct ServerHandle {
    state: Arc<ServerState>,
    accept_thread: Option<JoinHandle<()>>,
    sched_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Graceful shutdown: refuse new work, drain in-flight requests, join
    /// both threads. Returns the engine's final metrics snapshot (the
    /// scheduler publishes once more on exit, so it reflects the drain).
    pub fn shutdown(mut self) -> ServeMetrics {
        self.state.request_shutdown();
        self.join()
    }

    /// Block until a drain is requested externally (POST /admin/shutdown),
    /// then join — the `serve-http` subcommand's run-forever mode.
    /// Returns the final metrics snapshot like [`ServerHandle::shutdown`].
    pub fn shutdown_on_drain(mut self) -> ServeMetrics {
        while !self.state.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.join()
    }

    fn join(&mut self) -> ServeMetrics {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.sched_thread.take() {
            let _ = t.join();
        }
        self.state
            .sched_shared
            .metrics
            .lock()
            .map(|m| m.clone())
            .unwrap_or_default()
    }
}

/// Start serving `engine` per `cfg`. Returns once the listener is bound
/// and the scheduler thread is running.
pub fn serve(engine: ServeEngine, cfg: ServerConfig) -> Result<ServerHandle> {
    let queue_cap = engine.queue_cap();
    let batch = engine.limits().batch;
    let SchedulerHandle { tx: sched_tx, thread: sched_thread, shared: sched_shared } =
        scheduler::spawn(engine, queue_cap + batch + 4);

    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;

    let state = Arc::new(ServerState {
        cfg,
        sched_tx,
        sched_shared,
        addr,
        stop: AtomicBool::new(false),
        next_id: AtomicU64::new(1),
        http_requests: AtomicU64::new(0),
        http_400: AtomicU64::new(0),
        http_404: AtomicU64::new(0),
        http_429: AtomicU64::new(0),
        http_500: AtomicU64::new(0),
        streams_opened: AtomicU64::new(0),
    });

    let accept_state = state.clone();
    let accept_thread = std::thread::Builder::new()
        .name("sq-http-accept".into())
        .spawn(move || accept_loop(listener, accept_state))
        .expect("spawn accept thread");

    Ok(ServerHandle {
        state,
        accept_thread: Some(accept_thread),
        sched_thread: Some(sched_thread),
    })
}

/// Nonblocking accept poll: a blocking `accept()` could only be woken by
/// a loopback connection, which can fail exactly when shutdown matters
/// most (listen backlog full under flood) — polling makes drain
/// unconditional at the cost of one syscall per interval.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !state.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let conn_state = state.clone();
                let _ = std::thread::Builder::new()
                    .name("sq-http-conn".into())
                    .spawn(move || handle_conn(stream, conn_state));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    // a client that stops draining its socket must not pin this thread
    // forever: a stalled write errors out, the handler drops its event
    // receiver, and the engine cancels the request
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;

    let req = match HttpRequest::read_from(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            // port scans and probes disconnect before sending a request
            // line — nothing to answer there; real malformed HTTP counts
            // as a request and gets a counted 400
            if !e.to_string().contains("closed before request line") {
                state.http_requests.fetch_add(1, Ordering::Relaxed);
                respond_error(
                    &mut writer,
                    &state,
                    400,
                    "invalid_request_error",
                    &format!("{e:#}"),
                );
            }
            return;
        }
    };
    state.http_requests.fetch_add(1, Ordering::Relaxed);

    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(&mut writer, &state),
        ("GET", "/metrics") => handle_metrics(&mut writer, &state),
        ("POST", "/v1/completions") => handle_completions(&mut writer, &req, &state),
        ("POST", "/admin/shutdown") => {
            let _ = write_response(
                &mut writer,
                200,
                "application/json",
                b"{\"status\":\"draining\"}",
                &[],
            );
            state.request_shutdown();
        }
        ("GET" | "POST", _) => {
            state.http_404.fetch_add(1, Ordering::Relaxed);
            respond_error(&mut writer, &state, 404, "not_found_error", "no such route");
        }
        _ => {
            respond_error(
                &mut writer,
                &state,
                405,
                "invalid_request_error",
                "method not allowed",
            );
        }
    }
}

fn respond_error(
    w: &mut impl Write,
    state: &ServerState,
    code: u16,
    kind: &str,
    msg: &str,
) {
    match code {
        400 => state.http_400.fetch_add(1, Ordering::Relaxed),
        429 => state.http_429.fetch_add(1, Ordering::Relaxed),
        500 | 503 => state.http_500.fetch_add(1, Ordering::Relaxed),
        _ => 0,
    };
    let extra: &[(&str, &str)] =
        if code == 429 { &[("Retry-After", "1")] } else { &[] };
    let _ = write_response(
        w,
        code,
        "application/json",
        error_json(kind, msg).to_string().as_bytes(),
        extra,
    );
}

fn handle_healthz(w: &mut impl Write, state: &ServerState) {
    let shared = &state.sched_shared;
    let body = Json::obj(vec![
        (
            "status",
            Json::str(if shared.draining.load(Ordering::SeqCst) {
                "draining"
            } else {
                "ok"
            }),
        ),
        ("active", Json::usize(shared.active.load(Ordering::Relaxed))),
        ("pending", Json::usize(shared.pending.load(Ordering::Relaxed))),
        ("batch", Json::usize(shared.limits.batch)),
        ("model", Json::str(state.cfg.model.clone())),
    ]);
    let _ = write_response(w, 200, "application/json", body.to_string().as_bytes(), &[]);
}

fn handle_metrics(w: &mut impl Write, state: &ServerState) {
    let mut text = match state.sched_shared.metrics.lock() {
        Ok(m) => m.prometheus(),
        Err(_) => String::new(),
    };
    use std::fmt::Write as _;
    let http = [
        ("singlequant_http_requests_total", &state.http_requests),
        ("singlequant_http_responses_400_total", &state.http_400),
        ("singlequant_http_responses_404_total", &state.http_404),
        ("singlequant_http_responses_429_total", &state.http_429),
        ("singlequant_http_responses_5xx_total", &state.http_500),
        ("singlequant_http_streams_opened_total", &state.streams_opened),
    ];
    for (name, v) in http {
        let _ = writeln!(text, "# TYPE {name} counter");
        let _ = writeln!(text, "{name} {}", v.load(Ordering::Relaxed));
    }
    let _ = write_response(
        w,
        200,
        "text/plain; version=0.0.4",
        text.as_bytes(),
        &[],
    );
}

fn handle_completions(w: &mut impl Write, req: &HttpRequest, state: &ServerState) {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        Json::parse(s).map_err(|e| format!("invalid JSON: {e:#}"))
    }) {
        Ok(b) => b,
        Err(e) => return respond_error(w, state, 400, "invalid_request_error", &e),
    };
    let params = match parse_completion(
        &body,
        state.cfg.default_max_tokens,
        state.cfg.default_deadline_ms,
    ) {
        Ok(p) => p,
        Err(e) => return respond_error(w, state, 400, "invalid_request_error", &e),
    };

    let id = state.next_id.fetch_add(1, Ordering::Relaxed);
    let mut request = Request::new(id, tokenizer::encode(&params.prompt))
        .with_max_new(params.max_tokens);
    if let Some(t) = params.temperature {
        request = request.with_temperature(t);
    }
    if let Some(ms) = params.deadline_ms {
        request = request.with_deadline_in(Duration::from_millis(ms));
    }

    // submit through the scheduler thread; the reply channel carries the
    // admission verdict (bounded queue -> 429)
    let (sink, events) = channel::<TokenEvent>();
    let (reply_tx, reply_rx) = channel();
    match state.sched_tx.try_send(SchedCmd::Submit {
        req: request,
        sink,
        reply: reply_tx,
    }) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            return respond_error(
                w,
                state,
                429,
                "overloaded_error",
                "command channel full, retry later",
            )
        }
        Err(TrySendError::Disconnected(_)) => {
            return respond_error(
                w,
                state,
                503,
                "overloaded_error",
                "scheduler is down",
            )
        }
    }
    match reply_rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(())) => {}
        Ok(Err(AdmissionError::QueueFull { .. })) => {
            return respond_error(
                w,
                state,
                429,
                "overloaded_error",
                "admission queue full, retry later",
            )
        }
        Ok(Err(e @ AdmissionError::KvBudget { .. })) => {
            // could never be scheduled on this replica's KV pool, no
            // matter how long it waits — tell the client to retry
            // elsewhere rather than camp in the queue
            return respond_error(w, state, 429, "overloaded_error", &e.to_string())
        }
        Ok(Err(
            e @ (AdmissionError::InvalidPrompt { .. }
            | AdmissionError::InvalidToken { .. }),
        )) => {
            return respond_error(w, state, 400, "invalid_request_error", &e.to_string())
        }
        Err(_) => {
            return respond_error(
                w,
                state,
                500,
                "internal_error",
                "no admission verdict from scheduler",
            )
        }
    }

    // generous relay timeout: the engine's own deadline machinery is the
    // real cutoff; this only guards a wedged scheduler
    let relay_timeout = Duration::from_millis(
        params.deadline_ms.map(|ms| ms + 30_000).unwrap_or(120_000),
    );
    if params.stream {
        state.streams_opened.fetch_add(1, Ordering::Relaxed);
        stream_events(w, state, id, &events, relay_timeout);
    } else {
        collect_and_respond(w, state, &events, relay_timeout);
    }
}

/// Non-streaming: wait for the terminal event, answer with one JSON body.
fn collect_and_respond(
    w: &mut impl Write,
    state: &ServerState,
    events: &std::sync::mpsc::Receiver<TokenEvent>,
    timeout: Duration,
) {
    loop {
        match events.recv_timeout(timeout) {
            Ok(TokenEvent::Done { response, .. }) => {
                let body = completion_json(&state.cfg.model, &response).to_string();
                let _ =
                    write_response(w, 200, "application/json", body.as_bytes(), &[]);
                return;
            }
            Ok(TokenEvent::Failed { error, .. }) => {
                return respond_error(w, state, 500, "internal_error", &error);
            }
            Ok(_) => continue, // Started / Token
            Err(_) => {
                return respond_error(
                    w,
                    state,
                    500,
                    "internal_error",
                    "event stream stalled",
                );
            }
        }
    }
}

/// Streaming: one SSE frame per token, a finishing chunk with the
/// `finish_reason`, then `[DONE]`. A failed socket write simply drops the
/// receiver — the scheduler observes the hangup and cancels the request.
fn stream_events(
    w: &mut impl Write,
    state: &ServerState,
    id: u64,
    events: &std::sync::mpsc::Receiver<TokenEvent>,
    timeout: Duration,
) {
    if write_sse_headers(w).is_err() {
        return;
    }
    let model = &state.cfg.model;
    loop {
        match events.recv_timeout(timeout) {
            Ok(TokenEvent::Started { .. }) => {}
            Ok(TokenEvent::Token { text, .. }) => {
                let chunk = chunk_json(model, id, &text, None).to_string();
                if write_sse_data(w, &chunk).is_err() {
                    return; // client gone; engine will cancel
                }
            }
            Ok(TokenEvent::Done { reason, .. }) => {
                let last = chunk_json(model, id, "", Some(reason)).to_string();
                let _ = write_sse_data(w, &last);
                let _ = write_sse_data(w, "[DONE]");
                return;
            }
            Ok(TokenEvent::Failed { error, .. }) => {
                let payload = error_json("internal_error", &error).to_string();
                let _ = write_sse_data(w, &payload);
                let _ = write_sse_data(w, "[DONE]");
                return;
            }
            Err(_) => {
                let payload =
                    error_json("internal_error", "event stream stalled").to_string();
                let _ = write_sse_data(w, &payload);
                let _ = write_sse_data(w, "[DONE]");
                return;
            }
        }
    }
}
