//! Minimal HTTP/1.1 on top of `std::io` — request parsing, response
//! writing, and Server-Sent-Events framing. Deliberately small: one
//! request per connection (`Connection: close` on every response),
//! `Content-Length` bodies only, hard caps on header/body size. This is
//! the entire wire layer of the serving front-end; no hyper, no tokio.

use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{anyhow, bail, Result};

/// Cap on the request line + headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on request bodies (prompts are short; this is generous).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|e| anyhow!("body not UTF-8: {e}"))
    }

    /// Parse one request (head + body) from a buffered stream.
    pub fn read_from<R: Read>(reader: &mut BufReader<R>) -> Result<HttpRequest> {
        let mut head_bytes = 0usize;
        let mut line = String::new();
        read_line_limited(reader, &mut line, MAX_HEAD_BYTES)?;
        if line.is_empty() {
            bail!("connection closed before request line");
        }
        head_bytes += line.len();
        let mut parts = line.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| anyhow!("empty request line"))?
            .to_string();
        let path = parts
            .next()
            .ok_or_else(|| anyhow!("request line missing path"))?
            .to_string();
        let version = parts.next().unwrap_or("HTTP/1.0");
        if !version.starts_with("HTTP/1.") {
            bail!("unsupported protocol {version:?}");
        }

        let mut headers = Vec::new();
        loop {
            let mut hline = String::new();
            read_line_limited(reader, &mut hline, MAX_HEAD_BYTES)?;
            head_bytes += hline.len();
            if head_bytes > MAX_HEAD_BYTES {
                bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
            }
            let trimmed = hline.trim_end_matches(['\r', '\n']);
            if trimmed.is_empty() {
                break;
            }
            let (k, v) = trimmed
                .split_once(':')
                .ok_or_else(|| anyhow!("malformed header line {trimmed:?}"))?;
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }

        let req = HttpRequest { method, path, headers, body: Vec::new() };
        if req
            .header("transfer-encoding")
            .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
        {
            bail!("transfer-encoding not supported");
        }
        let len = match req.header("content-length") {
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| anyhow!("bad content-length {v:?}: {e}"))?,
            None => 0,
        };
        if len > MAX_BODY_BYTES {
            bail!("body of {len} bytes exceeds {MAX_BODY_BYTES}");
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        Ok(HttpRequest { body, ..req })
    }
}

/// `read_line` with a hard byte cap: a newline-less flood errors out at
/// `limit` instead of growing the line buffer unboundedly.
fn read_line_limited<R: Read>(
    reader: &mut BufReader<R>,
    line: &mut String,
    limit: usize,
) -> Result<()> {
    let mut bounded = reader.by_ref().take(limit as u64 + 1);
    bounded.read_line(line)?;
    if line.len() > limit {
        bail!("line exceeds {limit} bytes");
    }
    Ok(())
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete non-streaming response (Content-Length framed,
/// connection closing).
pub fn write_response(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        status_text(code),
        body.len()
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Start an SSE response; frames follow via [`write_sse_data`].
pub fn write_sse_headers(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

/// One `data: <payload>\n\n` frame, flushed immediately (`payload` must be
/// newline-free — JSON-encode first).
pub fn write_sse_data(w: &mut impl Write, payload: &str) -> std::io::Result<()> {
    debug_assert!(!payload.contains('\n'), "SSE payload must be single-line");
    write!(w, "data: {payload}\n\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<HttpRequest> {
        HttpRequest::read_from(&mut BufReader::new(Cursor::new(raw.as_bytes().to_vec())))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
             content-length: 11\r\nContent-Type: application/json\r\n\r\n\
             {\"a\": true}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("Content-Length"), Some("11"));
        assert_eq!(req.header("CONTENT-TYPE"), Some("application/json"));
        assert_eq!(req.body_str().unwrap(), "{\"a\": true}");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("").is_err(), "empty stream");
        assert!(parse("GARBAGE\r\n\r\n").is_err(), "no path");
        assert!(
            parse("GET / HTTP/1.1\r\nContent-Length: 9999999999\r\n\r\n").is_err(),
            "oversized body"
        );
        assert!(
            parse("GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err(),
            "chunked bodies unsupported"
        );
        assert!(
            parse("GET / SPDY/9\r\n\r\n").is_err(),
            "unknown protocol"
        );
        let flood = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(20_000));
        assert!(parse(&flood).is_err(), "newline-less flood must be capped");
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{}", &[("Retry-After", "1")])
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn sse_framing() {
        let mut out = Vec::new();
        write_sse_headers(&mut out).unwrap();
        write_sse_data(&mut out, "{\"x\":1}").unwrap();
        write_sse_data(&mut out, "[DONE]").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Content-Type: text/event-stream"));
        assert!(s.contains("data: {\"x\":1}\n\ndata: [DONE]\n\n"));
    }
}
