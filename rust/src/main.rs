//! `singlequant` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   info                         — artifacts / configs / checkpoint summary
//!   quantize                     — run the pipeline, save a package report
//!   eval                         — PPL + zero-shot eval of one (model, method)
//!   serve                        — serve a synthetic request trace, print metrics
//!   serve-http                   — run the HTTP front-end: POST /v1/completions
//!                                  (OpenAI-style JSON; `"stream": true` for SSE
//!                                  token streaming), GET /healthz, GET /metrics
//!                                  (Prometheus text), POST /admin/shutdown
//!                                  (graceful drain). Flags: --port N (default
//!                                  8071), --host IP, --batch N, --max-new N,
//!                                  --queue-cap N (admission bound -> HTTP 429),
//!                                  --deadline-ms N, --backend native|pjrt|
//!                                  synthetic (native = threaded CPU kernels on
//!                                  packed weights, no artifacts required;
//!                                  --threads N caps its workers), --synthetic
//!                                  (alias for --backend synthetic),
//!                                  --speculative K --draft ngram|demo|PATH
//!                                  (draft/verify decoding; bit-identical
//!                                  output, acceptance metrics on /metrics)
//!   generate                     — one-shot text generation
//!   reproduce --id <id>          — regenerate a paper table/figure (or `all`)
//!   analyze-ste                  — the Fig. 2 STE instability study
//!
//! Common flags: --artifacts DIR (default ./artifacts), --model NAME,
//! --method NAME, --wq rtn|gptq, --wbits N, --abits N, --lct, --fast.

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use singlequant::coordinator::{
    Request, ServeBackend, ServeConfig, ServeEngine, SyntheticBackend,
};
use singlequant::eval::ppl::perplexity;
use singlequant::eval::tasks::zero_shot_suite;
use singlequant::eval::TaskSuite;
use singlequant::experiments::{run_experiment, EvalBudget, ExpContext};
use singlequant::model::{ModelConfig, NativeModel, Weights};
use singlequant::pipeline::{quantize, quantize_with_progress, Method, PipelineOptions};
use singlequant::quant::WeightQuantizer;
use singlequant::rotation::singlequant::SingleQuantConfig;
use singlequant::runtime::{ModelRunner, NativeBackend, RunnerBackend};
use singlequant::server::{serve as serve_http, ServerConfig};
use singlequant::spec::{DraftModel, NativeDraft, NgramDraft};
use singlequant::util::cli::Args;
use singlequant::util::json::Json;
use singlequant::util::rng::Rng;
use singlequant::util::sqt::SqtFile;

fn method_from_name(name: &str) -> Result<Method> {
    Ok(match name.to_lowercase().as_str() {
        "fp16" | "fp" => Method::Fp16,
        "rtn" => Method::Rtn,
        "smoothquant" | "smooth" => Method::SmoothQuant { alpha: 0.5 },
        "awq" => Method::Awq { grid: 10 },
        "quarot" => Method::QuaRot,
        "quip" => Method::Quip,
        "spinquant" | "spin" => Method::SpinQuant { steps: 100 },
        "duquant" | "duq" => Method::DuQuant { steps: 16 },
        "flatquant" | "flat" => Method::FlatQuant { steps: 60 },
        "singlequant" | "single" | "sq" => {
            Method::SingleQuant(SingleQuantConfig::default())
        }
        other => bail!("unknown method {other:?}"),
    })
}

/// Apply method-parameter overrides from CLI flags.
fn tune_method(method: Method, args: &Args) -> Result<Method> {
    Ok(match method {
        Method::SingleQuant(mut c) => {
            c.art_steps = args.usize_or("art-steps", c.art_steps)?;
            if args.flag("urt-axis2") {
                c.urt_axis2 = true;
            }
            Method::SingleQuant(c)
        }
        Method::SpinQuant { .. } => Method::SpinQuant {
            steps: args.usize_or("opt-steps", 100)?,
        },
        Method::FlatQuant { .. } => Method::FlatQuant {
            steps: args.usize_or("opt-steps", 60)?,
        },
        m => m,
    })
}

fn wq_from_name(name: &str) -> Result<WeightQuantizer> {
    Ok(match name.to_lowercase().as_str() {
        "rtn" => WeightQuantizer::Rtn,
        "gptq" => WeightQuantizer::Gptq,
        "gptq-g32" => WeightQuantizer::GptqGrouped(32),
        "rtn-g32" => WeightQuantizer::RtnGrouped(32),
        other => bail!("unknown weight quantizer {other:?}"),
    })
}

fn opts_from_args(args: &Args) -> Result<PipelineOptions> {
    let method = tune_method(
        method_from_name(args.get_or("method", "singlequant"))?,
        args,
    )?;
    Ok(PipelineOptions {
        method,
        weight_quantizer: wq_from_name(args.get_or("wq", "rtn"))?,
        weight_bits: args.usize_or("wbits", 4)? as u32,
        act_bits: args.usize_or("abits", 4)? as u32,
        lct: args.flag("lct"),
        calib_seqs: args.usize_or("calib-seqs", 8)?,
        calib_len: args.usize_or("calib-len", 96)?,
        seed: args.usize_or("seed", 0x5142)? as u64,
        threads: args.usize_or("threads", 0)?,
    })
}

fn ctx_from_args(args: &Args) -> Result<ExpContext> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let budget = if args.flag("fast") {
        EvalBudget::fast()
    } else {
        EvalBudget::full()
    };
    ExpContext::new(&dir, budget)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["fast", "lct", "verbose", "urt-axis2", "synthetic"])?;
    if let Some(k) = args.get("kernel") {
        // pin the microkernel before any matmul runs (selection is
        // once-per-process); "auto" re-states the default runtime detection
        let chosen = singlequant::tensor::simd::force(k)?;
        eprintln!("[kernel] {}", chosen.label());
    }
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "info" => info(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "serve-http" => cmd_serve_http(&args),
        "generate" => cmd_generate(&args),
        "reproduce" => cmd_reproduce(&args),
        "analyze-ste" => cmd_ste(&args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "singlequant — W4A4 LLM quantization via closed-form rotations
usage: singlequant <info|quantize|eval|serve|serve-http|generate|reproduce|analyze-ste> [flags]
  --artifacts DIR   artifact directory (default: artifacts)
  --model NAME      sq-s | sq-m | sq-l | sq-xl | sq-moe | sq-m-chat
  --method NAME     fp16|rtn|smoothquant|awq|quarot|quip|spinquant|duquant|flatquant|singlequant
  --wq NAME         rtn | gptq | gptq-g32 | rtn-g32
  --wbits N --abits N --lct --fast
  --backend NAME    native (threaded CPU, packed weights; eval + serve-http)
                    | pjrt (AOT graphs) | synthetic (serve-http only)
  --threads N       worker lanes: native backend + quantize pipeline
                    (0 = all cores; quantize output is bit-identical
                    for every thread count)
  --kernel NAME     scalar | simd | auto — pin the CPU microkernel (default:
                    runtime detection; SQ_KERNEL=scalar env does the same)
  quantize          prints per-stage progress lines and a timing breakdown;
                    falls back to the built-in demo model when no artifacts
                    exist (omit --model/--artifacts)
  serve-http        --host IP --port N --batch N --max-new N --queue-cap N
                    --deadline-ms N --backend native|pjrt|synthetic
                    --kv-page-tokens N (native; 0 = contiguous KV, default 16)
                    --kv-pool-pages N  (native; 0 = worst-case auto-size; a
                    smaller pool overcommits: admission gates on worst-case
                    page demand and decode preempts+replays under pressure)
                    --speculative K (propose K draft tokens per decode wave,
                    verified in one burst; output stays bit-identical, 0 =
                    off; native|synthetic backends) --draft ngram|demo|PATH
                    (ngram = zero-weight prompt lookup; demo = built-in fp
                    demo draft; PATH = fp .sqt checkpoint on the demo config)
  reproduce --id X  table1..table8 tableb3 fig1a fig1b fig2 fig3 fig4 all
  generate          --prompt TEXT --max-new N";

fn info(args: &Args) -> Result<()> {
    let ctx = ctx_from_args(args)?;
    let configs = ctx.engine.manifest.get("configs")?.as_obj()?;
    println!("artifacts: {}", ctx.dir);
    println!("platform: {}", ctx.engine.client.platform_name());
    for (name, c) in configs {
        println!(
            "  {name}: d={} L={} H={} ff={} experts={} kron_d={:?}",
            c.usize_at("d_model")?,
            c.usize_at("n_layers")?,
            c.usize_at("n_heads")?,
            c.usize_at("d_ff")?,
            c.usize_at("n_experts")?,
            c.get("kron_d")?.as_arr()?.iter()
                .map(|x| x.as_usize().unwrap()).collect::<Vec<_>>(),
        );
    }
    let n_arts = ctx.engine.manifest.get("artifacts")?.as_arr()?.len();
    println!("{n_arts} HLO artifacts");
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let opts = opts_from_args(args)?;
    // artifact checkpoint when available, built-in demo model otherwise —
    // quantize no longer needs a PJRT engine or lowered graphs at all
    let (cfg, weights, calib) = native_model_inputs(args)?;
    let progress = |line: &str| println!("{line}");
    let qm = quantize_with_progress(&cfg, &weights, &calib, &opts, Some(&progress))?;
    println!(
        "quantized {} with {} (wq {}, W{}A{}, {} lanes):",
        cfg.name,
        qm.method_label,
        args.get_or("wq", "rtn"),
        opts.weight_bits,
        opts.act_bits,
        qm.stats.lanes,
    );
    println!("  calibration : {:.3}s", qm.stats.calib_seconds);
    println!("  scale folds : {:.3}s", qm.stats.fold_seconds);
    println!("  rotations   : {:.3}s", qm.stats.rotation_seconds);
    println!("  weight quant: {:.3}s", qm.stats.weight_quant_seconds);
    println!("  total       : {:.3}s", qm.total_seconds());
    println!("  packed bytes: {} (+{} fp)", qm.packed_bytes, qm.fp_bytes);
    for (k, r) in qm.rots.iter().take(2) {
        println!("  {k}: r1 {:?} r2 {:?} defect {:.2e}",
                 r.r1.shape(), r.r2.shape(), r.defect());
    }
    Ok(())
}

/// Load (config, checkpoint, calibration corpus) for the native backend:
/// straight from the artifact files when they exist (no PJRT engine is
/// ever constructed), or a built-in demo model so the native path runs on
/// a bare machine.
fn native_model_inputs(args: &Args) -> Result<(ModelConfig, Weights, Vec<u16>)> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest_path = format!("{dir}/manifest.json");
    if std::path::Path::new(&manifest_path).exists() {
        let manifest = Json::parse_file(&manifest_path)?;
        let model = args.get_or("model", "sq-m");
        let cfg = ModelConfig::from_manifest(&manifest, model)?;
        let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt"))?;
        let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
            .get("tokens")?
            .as_u16()?
            .to_vec();
        Ok((cfg, weights, calib))
    } else if args.get("model").is_some() || args.get("artifacts").is_some() {
        // an explicitly requested checkpoint must never silently degrade
        // to the random-weights demo model
        bail!(
            "--backend native: no manifest at {manifest_path}; the requested \
             checkpoint is unavailable (omit --model/--artifacts to serve the \
             built-in demo model)"
        );
    } else {
        eprintln!(
            "[native] no artifacts at {dir}; serving the built-in demo model \
             (random weights, byte-level vocab)"
        );
        let cfg = ModelConfig::demo();
        let weights = Weights::random_init(&cfg, 0x5142);
        let mut rng = Rng::new(7);
        let calib: Vec<u16> = (0..4096).map(|_| rng.below(256) as u16).collect();
        Ok((cfg, weights, calib))
    }
}

/// Quantize and wrap a checkpoint for pure-CPU serving.
fn native_backend_from_args(
    args: &Args,
    batch: usize,
) -> Result<(Box<dyn ServeBackend>, String)> {
    let threads = args.usize_or("threads", 0)?;
    let page_tokens = args.usize_or("kv-page-tokens", 16)?;
    let pool_pages = args.usize_or("kv-pool-pages", 0)?;
    let opts = opts_from_args(args)?;
    let (cfg, weights, calib) = native_model_inputs(args)?;
    let qm = quantize(&cfg, &weights, &calib, &opts)?;
    let label = format!("{}/{}/native", cfg.name, opts.method.label());
    let model = NativeModel::from_quantized(&qm, opts.weight_bits, threads)?;
    let backend: Box<dyn ServeBackend> = if page_tokens == 0 {
        // legacy contiguous KV: one growable max_seq cache per slot
        Box::new(NativeBackend::new(model, batch))
    } else {
        Box::new(NativeBackend::with_paged_kv(model, batch, page_tokens, pool_pages))
    };
    Ok((backend, label))
}

fn cmd_eval(args: &Args) -> Result<()> {
    match args.get_or("backend", "pjrt") {
        "native" => return cmd_eval_native(args),
        "pjrt" => {}
        other => bail!("unknown --backend {other:?} (native|pjrt)"),
    }
    let ctx = ctx_from_args(args)?;
    let model = args.get_or("model", "sq-m");
    let opts = opts_from_args(args)?;
    let cfg = ctx.config(model)?;
    let runner = ctx.runner(model, &opts)?;
    let wiki = ctx.corpus("wiki_eval")?;
    let web = ctx.corpus("web_eval")?;
    let p1 = perplexity(&runner, &wiki, cfg.score_seq, ctx.budget.ppl_windows)?;
    let p2 = perplexity(&runner, &web, cfg.score_seq, ctx.budget.ppl_windows)?;
    println!("{model} [{}]: wiki ppl {p1:.3}  web ppl {p2:.3}", opts.method.label());
    let suite = ctx.tasks()?;
    let (per, avg) = zero_shot_suite(&runner, &suite, ctx.budget.task_items)?;
    for (name, acc) in per {
        println!("  {name:<14} {:.1}", acc * 100.0);
    }
    println!("  zero-shot avg  {:.1}", avg * 100.0);
    Ok(())
}

/// Eval through the native CPU backend: artifact *files* only (checkpoint,
/// corpora, task suites) — no PJRT engine, no lowered graphs.
fn cmd_eval_native(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    // unlike serve-http, eval has no artifact-free mode: the corpora and
    // task suites it measures live in the artifacts dir — fail before
    // spending time quantizing
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        bail!("eval --backend native needs the artifact data files \
               (checkpoint, corpora, task suites) at {dir}; run `make \
               artifacts` first");
    }
    let model = args.get_or("model", "sq-m");
    let threads = args.usize_or("threads", 0)?;
    let opts = opts_from_args(args)?;
    let budget = if args.flag("fast") {
        EvalBudget::fast()
    } else {
        EvalBudget::full()
    };
    let (cfg, weights, calib) = native_model_inputs(args)?;
    let qm = quantize(&cfg, &weights, &calib, &opts)?;
    let nm = NativeModel::from_quantized(&qm, opts.weight_bits, threads)?;
    let corpus = |name: &str| -> Result<Vec<u16>> {
        Ok(SqtFile::load(&format!("{dir}/data/corpus_{name}.sqt"))?
            .get("tokens")?
            .as_u16()?
            .to_vec())
    };
    let wiki = corpus("wiki_eval")?;
    let web = corpus("web_eval")?;
    let p1 = perplexity(&nm, &wiki, cfg.score_seq, budget.ppl_windows)?;
    let p2 = perplexity(&nm, &web, cfg.score_seq, budget.ppl_windows)?;
    println!("{model} [{} | native]: wiki ppl {p1:.3}  web ppl {p2:.3}",
             opts.method.label());
    let suite = TaskSuite::load(&format!("{dir}/data/tasks.json"))?;
    let (per, avg) = zero_shot_suite(&nm, &suite, budget.task_items)?;
    for (name, acc) in per {
        println!("  {name:<14} {:.1}", acc * 100.0);
    }
    println!("  zero-shot avg  {:.1}", avg * 100.0);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ctx = ctx_from_args(args)?;
    let model = args.get_or("model", "sq-m");
    let opts = opts_from_args(args)?;
    let qm = ctx.package(model, &opts)?;
    let runner = Arc::new(ModelRunner::new(ctx.engine.clone(), &qm)?);
    let batch = args.usize_or("batch", 4)?;
    let n_req = args.usize_or("requests", ctx.budget.serve_requests)?;
    let max_new = args.usize_or("max-new", 24)?;
    let backend = RunnerBackend::new(runner, batch);
    let mut engine = ServeEngine::new(
        Box::new(backend),
        ServeConfig { max_new_cap: max_new, seed: 7, ..Default::default() },
    );

    // synthetic request trace from corpus prompts
    let corpus = ctx.corpus("wiki_eval")?;
    let mut rng = Rng::new(13);
    for id in 0..n_req {
        let start = rng.below(corpus.len() - 64);
        let len = 16 + rng.below(48);
        let prompt = &corpus[start..start + len];
        engine.submit(Request::new(id as u64, prompt.to_vec()).with_max_new(max_new));
    }
    let responses = engine.run_to_completion()?;
    println!("served {} requests [{} | batch {batch}]", responses.len(),
             opts.method.label());
    println!("{}", engine.metrics.summary());
    Ok(())
}

/// Build the draft model behind `--draft` for speculative serving.
/// "ngram" is the zero-weight prompt-lookup draft; "demo" carries the
/// built-in demo config with fresh random fp weights (a stand-in for a
/// distilled small checkpoint); any other value loads an fp `.sqt`
/// checkpoint shaped like the demo config.
fn draft_from_args(args: &Args, batch: usize) -> Result<Box<dyn DraftModel>> {
    Ok(match args.get_or("draft", "ngram") {
        "ngram" => Box::new(NgramDraft::new(3)),
        "demo" => {
            let threads = args.usize_or("threads", 0)?;
            let cfg = ModelConfig::demo();
            let w = Weights::random_init(&cfg, 0x7a31);
            let model = NativeModel::from_weights(&cfg, &w, None, threads)?;
            Box::new(NativeDraft::new(model, batch))
        }
        path => {
            let threads = args.usize_or("threads", 0)?;
            let cfg = ModelConfig::demo();
            let w = Weights::load(path)?;
            let model = NativeModel::from_weights(&cfg, &w, None, threads)?;
            Box::new(NativeDraft::new(model, batch))
        }
    })
}

fn cmd_serve_http(args: &Args) -> Result<()> {
    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 8071)?;
    let batch = args.usize_or("batch", 4)?;
    let max_new = args.usize_or("max-new", 32)?;
    let queue_cap = args.usize_or("queue-cap", 64)?;
    let deadline_ms = args.get("deadline-ms").map(|v| v.parse::<u64>()).transpose()
        .map_err(|e| anyhow!("--deadline-ms: {e}"))?;

    let kind = if args.flag("synthetic") {
        "synthetic"
    } else {
        args.get_or("backend", "pjrt")
    };
    let (backend, model_label): (Box<dyn ServeBackend>, String) = match kind {
        "synthetic" => (Box::new(SyntheticBackend::new(batch)), "synthetic".to_string()),
        "native" => native_backend_from_args(args, batch)?,
        "pjrt" => {
            let ctx = ctx_from_args(args)?;
            let model = args.get_or("model", "sq-m");
            let opts = opts_from_args(args)?;
            let qm = ctx.package(model, &opts)?;
            let runner = Arc::new(ModelRunner::new(ctx.engine.clone(), &qm)?);
            (
                Box::new(RunnerBackend::new(runner, batch)),
                format!("{model}/{}", opts.method.label()),
            )
        }
        other => bail!("unknown --backend {other:?} (native|pjrt|synthetic)"),
    };
    let mut engine = ServeEngine::new(
        backend,
        ServeConfig { max_new_cap: max_new, seed: 7, queue_cap },
    );
    let spec_k = args.usize_or("speculative", 0)?;
    if spec_k > 0 {
        ensure!(kind != "pjrt",
                "--speculative needs --backend native or synthetic (the PJRT \
                 graphs have no multi-row verification entry point)");
        engine.enable_speculation(spec_k, draft_from_args(args, batch)?);
        println!("[serve-http] speculative decoding: k={spec_k} draft={}",
                 args.get_or("draft", "ngram"));
    }
    let handle = serve_http(engine, ServerConfig {
        addr: format!("{host}:{port}"),
        default_max_tokens: max_new.min(16),
        default_deadline_ms: deadline_ms,
        model: model_label,
    })?;
    println!("serving on http://{}  (POST /v1/completions, GET /healthz, \
              GET /metrics; POST /admin/shutdown to drain)", handle.addr());
    // Block until a graceful drain is requested over HTTP; shutdown() then
    // joins the scheduler after in-flight requests finish.
    let metrics = handle.shutdown_on_drain();
    println!("[serve-http] drained: {}", metrics.summary());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let ctx = ctx_from_args(args)?;
    let model = args.get_or("model", "sq-m");
    let opts = opts_from_args(args)?;
    let qm = ctx.package(model, &opts)?;
    let runner = Arc::new(ModelRunner::new(ctx.engine.clone(), &qm)?);
    let backend = RunnerBackend::new(runner, 4);
    let mut engine = ServeEngine::new(Box::new(backend), ServeConfig::default());
    let prompt = args.get_or("prompt", "the weaving master ");
    let max_new = args.usize_or("max-new", 32)?;
    let resp = engine.generate(0, prompt, max_new)?;
    println!("prompt : {prompt}");
    println!("output : {}", resp.text);
    println!("ttft {:.1}ms, total {:.1}ms, {} tokens",
             resp.ttft_s * 1e3, resp.latency_s * 1e3, resp.tokens.len());
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let ctx = ctx_from_args(args)?;
    let id = args
        .get("id")
        .or_else(|| args.get("table"))
        .ok_or_else(|| anyhow!("reproduce needs --id <table1..fig4|all>"))?
        .to_string();
    run_experiment(&ctx, &id)?;
    println!("reports written under {}/../reports/", ctx.dir);
    Ok(())
}

fn cmd_ste(args: &Args) -> Result<()> {
    let ctx = ctx_from_args(args)?;
    run_experiment(&ctx, "fig2")?;
    Ok(())
}
