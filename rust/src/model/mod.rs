//! Model definitions on the Rust side: configuration (mirroring
//! `python/compile/model.py` via `artifacts/manifest.json`), checkpoint
//! weights, parameter layout, the shared per-layer primitives
//! ([`layers`]), the pure-Rust reference forward used by calibration and
//! GPTQ ([`forward`]), and the packed-weight KV-cached execution engine
//! behind the native serving backend ([`native`]).

pub mod config;
pub mod forward;
pub mod layers;
pub mod native;
pub mod weights;

pub use config::ModelConfig;
pub use native::NativeModel;
// Re-exported for back-compat: the slot cache moved to the kv subsystem.
pub use crate::kv::SlotKv;
pub use weights::Weights;
