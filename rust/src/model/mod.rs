//! Model definitions on the Rust side: configuration (mirroring
//! `python/compile/model.py` via `artifacts/manifest.json`), checkpoint
//! weights, parameter layout, and the pure-Rust reference forward used by
//! calibration and GPTQ.

pub mod config;
pub mod forward;
pub mod weights;

pub use config::ModelConfig;
pub use weights::Weights;
