//! Per-layer primitives shared by every execution path: the full-sequence
//! reference forward (`forward::forward_score`), the KV-cached incremental
//! decode (`native::NativeModel`), and the calibration taps.
//!
//! Everything here is written so that a row's result depends only on that
//! row (plus, for attention, the cached K/V rows at earlier positions) and
//! accumulates in a fixed order — which is what lets the cached decode
//! path reproduce the full-sequence forward bit-for-bit.

use std::collections::BTreeMap;

use super::config::ModelConfig;
use crate::kv::KvRows;
use crate::quant::fake_quant_per_token;
use crate::rotation::singlequant::SiteRotation;
use crate::tensor::Tensor;

pub const EPS: f32 = 1e-5;

/// Quantized-forward context: per-site rotations + clips, activation bits.
#[derive(Clone, Debug)]
pub struct QuantCtx {
    /// Keyed `l{i:02}.{site}`.
    pub rots: BTreeMap<String, SiteRotation>,
    pub clips: BTreeMap<String, f32>,
    /// 4 for W4A4; 16 disables activation quantization (weight-only).
    pub act_bits: u32,
    /// Static per-tensor activation quantization: `clips` carry per-site
    /// scales Δ instead of clip ratios (SmoothQuant's original form).
    pub static_act: bool,
}

impl QuantCtx {
    pub fn identity(cfg: &ModelConfig, act_bits: u32) -> QuantCtx {
        let mut rots = BTreeMap::new();
        let mut clips = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for site in super::config::ROT_SITES {
                let (n, _, _) = cfg.site_dims(site);
                rots.insert(format!("l{i:02}.{site}"), SiteRotation::identity(n));
                clips.insert(format!("l{i:02}.{site}"), 1.0);
            }
        }
        QuantCtx { rots, clips, act_bits, static_act: false }
    }
}

pub fn rmsnorm(x: &Tensor, g: &Tensor) -> Tensor {
    let (t, d) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[t, d]);
    for i in 0..t {
        let row = x.row(i);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for (j, &v) in row.iter().enumerate() {
            out.row_mut(i)[j] = v * inv * g.data()[j];
        }
    }
    out
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// The SwiGLU combine, in place: hidden ← silu(hidden) ⊙ u. Every
/// execution path (reference forward, native prefill/decode, dense and
/// MoE MLPs) must share this exact loop — the decode == reference
/// bit-equality invariant depends on it.
pub fn swiglu_inplace(hidden: &mut Tensor, u: &Tensor) {
    assert_eq!(hidden.shape(), u.shape(), "swiglu shape mismatch");
    for (h, &uv) in hidden.data_mut().iter_mut().zip(u.data()) {
        *h = silu(*h) * uv;
    }
}

/// Activation quantization matching the graphs: dynamic per-token (clip =
/// ratio) or static per-tensor (clip = scale Δ) — see `QLinearCtx` on the
/// Python side.
pub fn apply_act_quant(xr: &Tensor, q: &QuantCtx, clip: f32) -> Tensor {
    if q.act_bits >= 16 {
        return xr.clone();
    }
    if q.static_act {
        let delta = clip.max(1e-8);
        return xr.map(|v| (v / delta).round().clamp(-8.0, 7.0) * delta);
    }
    fake_quant_per_token(&xr.scale(1.0 / clip), q.act_bits, 1.0).scale(clip)
}

/// RoPE tables for positions `0..t`.
pub struct Rope {
    cos: Vec<Vec<f32>>, // [T][dh/2]
    sin: Vec<Vec<f32>>,
}

impl Rope {
    pub fn new(cfg: &ModelConfig, t: usize) -> Rope {
        let dh = cfg.d_head();
        let half = dh / 2;
        let mut cos = Vec::with_capacity(t);
        let mut sin = Vec::with_capacity(t);
        for pos in 0..t {
            let mut c = Vec::with_capacity(half);
            let mut s = Vec::with_capacity(half);
            for i in 0..half {
                let inv_freq =
                    1.0 / cfg.rope_theta.powf(2.0 * i as f32 / dh as f32);
                let ang = pos as f32 * inv_freq;
                c.push(ang.cos());
                s.push(ang.sin());
            }
            cos.push(c);
            sin.push(s);
        }
        Rope { cos, sin }
    }

    /// Apply in place to one head vector at position `pos`.
    pub fn apply(&self, v: &mut [f32], pos: usize) {
        let half = v.len() / 2;
        for i in 0..half {
            let (x1, x2) = (v[2 * i], v[2 * i + 1]);
            let (c, s) = (self.cos[pos][i], self.sin[pos][i]);
            v[2 * i] = x1 * c - x2 * s;
            v[2 * i + 1] = x2 * c + x1 * s;
        }
    }

    /// Apply to every head of a `[d_model]` token row at position `pos`.
    pub fn apply_row(&self, cfg: &ModelConfig, row: &mut [f32], pos: usize) {
        let dh = cfg.d_head();
        for head in 0..cfg.n_heads {
            self.apply(&mut row[head * dh..(head + 1) * dh], pos);
        }
    }
}

/// Causal multi-head attention over full sequences.
/// q,k,v: [T, d] with head-major packing [H, dh] per row.
pub fn attention_full(cfg: &ModelConfig, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let t = q.rows();
    let (h, dh) = (cfg.n_heads, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[t, cfg.d_model]);
    let mut logits = vec![0.0f32; t];
    for head in 0..h {
        let off = head * dh;
        for ti in 0..t {
            let qrow = &q.row(ti)[off..off + dh];
            // scores over keys 0..=ti
            let mut maxv = f32::NEG_INFINITY;
            for tj in 0..=ti {
                let krow = &k.row(tj)[off..off + dh];
                let mut dot = 0.0f32;
                for x in 0..dh {
                    dot += qrow[x] * krow[x];
                }
                logits[tj] = dot * scale;
                maxv = maxv.max(logits[tj]);
            }
            let mut denom = 0.0f32;
            for l in logits.iter_mut().take(ti + 1) {
                *l = (*l - maxv).exp();
                denom += *l;
            }
            let orow = &mut out.row_mut(ti)[off..off + dh];
            for tj in 0..=ti {
                let p = logits[tj] / denom;
                if p == 0.0 {
                    continue;
                }
                let vrow = &v.row(tj)[off..off + dh];
                for x in 0..dh {
                    orow[x] += p * vrow[x];
                }
            }
        }
    }
    out
}

/// One query row attending over `len` cached K/V rows fetched through
/// any [`KvRows`] store — contiguous vectors or pool pages. The query
/// sits at position `len - 1`; the per-element math and accumulation
/// order are identical to [`attention_full`]'s row `len - 1`, which is
/// what keeps cached decode (paged or not) bit-equal to the
/// full-sequence reference.
pub fn attention_step_kv<K: KvRows + ?Sized>(
    cfg: &ModelConfig,
    qrow: &[f32],
    kv: &K,
    layer: usize,
    len: usize,
) -> Vec<f32> {
    let (h, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; d];
    let mut logits = vec![0.0f32; len];
    for head in 0..h {
        let off = head * dh;
        let q = &qrow[off..off + dh];
        let mut maxv = f32::NEG_INFINITY;
        for tj in 0..len {
            let krow = &kv.rows(layer, tj).0[off..off + dh];
            let mut dot = 0.0f32;
            for x in 0..dh {
                dot += q[x] * krow[x];
            }
            logits[tj] = dot * scale;
            maxv = maxv.max(logits[tj]);
        }
        let mut denom = 0.0f32;
        for l in logits.iter_mut().take(len) {
            *l = (*l - maxv).exp();
            denom += *l;
        }
        let orow = &mut out[off..off + dh];
        for tj in 0..len {
            let p = logits[tj] / denom;
            if p == 0.0 {
                continue;
            }
            let vrow = &kv.rows(layer, tj).1[off..off + dh];
            for x in 0..dh {
                orow[x] += p * vrow[x];
            }
        }
    }
    out
}

/// Flat `[len, d_model]` K/V slices viewed as a single-layer row store,
/// so [`attention_step`] shares [`attention_step_kv`]'s one code path.
struct FlatKv<'a> {
    k: &'a [f32],
    v: &'a [f32],
    d: usize,
}

impl KvRows for FlatKv<'_> {
    fn rows(&self, _layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (a, b) = (pos * self.d, (pos + 1) * self.d);
        (&self.k[a..b], &self.v[a..b])
    }
}

/// One query row attending over `len` cached K/V rows (the query sits at
/// position `len - 1`). `k`/`v` are flattened `[len, d_model]` row-major
/// with the same head-major packing as the full-sequence tensors.
pub fn attention_step(
    cfg: &ModelConfig,
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    len: usize,
) -> Vec<f32> {
    attention_step_kv(cfg, qrow, &FlatKv { k, v, d: cfg.d_model }, 0, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::util::rng::Rng;

    #[test]
    fn attention_step_matches_full_rows() {
        let cfg = test_config();
        let mut rng = Rng::new(1);
        let t = 6;
        let q = Tensor::randn(&[t, cfg.d_model], 1.0, &mut rng);
        let k = Tensor::randn(&[t, cfg.d_model], 1.0, &mut rng);
        let v = Tensor::randn(&[t, cfg.d_model], 1.0, &mut rng);
        let full = attention_full(&cfg, &q, &k, &v);
        for ti in 0..t {
            let len = ti + 1;
            let got = attention_step(&cfg, q.row(ti),
                                     &k.data()[..len * cfg.d_model],
                                     &v.data()[..len * cfg.d_model], len);
            assert_eq!(got.as_slice(), full.row(ti), "row {ti} must be exact");
        }
    }

    #[test]
    fn rope_row_matches_per_head_apply() {
        let cfg = test_config();
        let mut rng = Rng::new(2);
        let rope = Rope::new(&cfg, 8);
        let mut a = rng.normal_vec(cfg.d_model, 1.0);
        let mut b = a.clone();
        rope.apply_row(&cfg, &mut a, 5);
        for head in 0..cfg.n_heads {
            let dh = cfg.d_head();
            rope.apply(&mut b[head * dh..(head + 1) * dh], 5);
        }
        assert_eq!(a, b);
    }
}
