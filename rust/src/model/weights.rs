//! Checkpoint weights: named f32 tensors loaded from the SQT checkpoints
//! written by `python/compile/train.py`, plus synthetic-init helpers for
//! tests that should not depend on artifacts.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::sqt::SqtFile;

#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub map: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("missing weight {name:?}"))
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn load(path: &str) -> Result<Weights> {
        let f = SqtFile::load(path)?;
        let mut map = BTreeMap::new();
        for (name, t) in f.tensors {
            map.insert(name, t.as_f32()?.clone());
        }
        Ok(Weights { map })
    }

    /// Expected parameter shape; mirrors python `param_shape`.
    pub fn param_shape(cfg: &ModelConfig, name: &str) -> Vec<usize> {
        let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let base = name.rsplit('.').next().unwrap();
        match name {
            "emb.tok" => vec![v, d],
            "out.norm" => vec![d],
            "out.head" => vec![d, v],
            _ => match base {
                "an" | "mn" => vec![d],
                "wq" | "wk" | "wv" | "wo" => vec![d, d],
                "wg" | "wu" => vec![d, ff],
                "wd" => vec![ff, d],
                "router" => vec![d, cfg.n_experts],
                _ => panic!("unknown weight {name}"),
            },
        }
    }

    /// Random init with the training-side scaling (tests only).
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let mut map = BTreeMap::new();
        for name in cfg.weight_names() {
            let shape = Self::param_shape(cfg, &name);
            let base = name.rsplit('.').next().unwrap();
            let t = if base == "an" || base == "mn" || name == "out.norm" {
                Tensor::filled(&shape, 1.0)
            } else {
                let fan_in = shape[0] as f32;
                Tensor::randn(&shape, 1.0 / fan_in.sqrt(), &mut rng)
            };
            map.insert(name, t);
        }
        Weights { map }
    }

    /// Validate that every expected weight exists with the right shape.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in cfg.weight_names() {
            let t = self.get(&name)?;
            let want = Self::param_shape(cfg, &name);
            if t.shape() != want.as_slice() {
                return Err(anyhow!(
                    "weight {name}: shape {:?}, expected {:?}",
                    t.shape(),
                    want
                ));
            }
        }
        Ok(())
    }

    /// Total f32 parameter count.
    pub fn n_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    #[test]
    fn random_init_validates() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        w.validate(&cfg).unwrap();
        assert!(w.n_params() > 10_000);
    }

    #[test]
    fn missing_weight_is_error() {
        let cfg = test_config();
        let mut w = Weights::random_init(&cfg, 1);
        w.map.remove("l00.wq");
        assert!(w.validate(&cfg).is_err());
    }
}
