//! Pure-Rust reference forward of the Layer-2 model.
//!
//! Matches `python/compile/model.py` op-for-op (RMSNorm, RoPE, causal
//! attention, SwiGLU, Mixtral-style top-k MoE), built on the shared
//! per-layer primitives in [`super::layers`]. Three uses:
//!
//! 1. **Calibration** — the single pass that records per-site activation
//!    profiles and GPTQ Hessians (`calib::run_calibration`), with a tap
//!    invoked at every rotation site.
//! 2. **Quantized emulation** — with a [`QuantCtx`] the forward applies the
//!    site rotations and per-token activation fake-quant exactly like the
//!    w4a4 graphs, letting the pipeline evaluate candidate transforms
//!    without a PJRT round-trip.
//! 3. **Cross-checking** — integration tests compare these logits against
//!    the lowered HLO executed through PJRT, and `model::native`'s
//!    KV-cached decode must reproduce them bit-for-bit.

use anyhow::Result;

use super::config::ModelConfig;
use super::layers::{apply_act_quant, attention_full, rmsnorm, swiglu_inplace, Rope};
use super::weights::Weights;
use crate::rotation::kronecker::kron_rotate_rows;
use crate::tensor::Tensor;

pub use super::layers::QuantCtx;

/// Observation tap: called with (layer, site, pre-rotation site input).
pub type Tap<'a> = &'a mut dyn FnMut(usize, &str, &Tensor);

/// Apply the site transform (rotate -> fake-quant) then multiply by each
/// weight; returns per-weight outputs. `x` is the raw site input.
fn site_linear(
    x: &Tensor,
    ws: &[&Tensor],
    key: &str,
    quant: Option<&QuantCtx>,
    layer: usize,
    site: &str,
    tap: &mut Option<Tap>,
) -> Vec<Tensor> {
    if let Some(t) = tap.as_mut() {
        t(layer, site, x);
    }
    let _ = key;
    match quant {
        None => ws.iter().map(|w| x.matmul(w)).collect(),
        Some(q) => {
            let skey = format!("l{layer:02}.{site}");
            let rot = &q.rots[&skey];
            let clip = q.clips[&skey];
            let xr = kron_rotate_rows(x, &rot.r1, &rot.r2);
            let xq = apply_act_quant(&xr, q, clip);
            ws.iter().map(|w| xq.matmul(w)).collect()
        }
    }
}

/// Full-sequence forward: tokens -> logits [T, V].
pub fn forward_score(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[u16],
    quant: Option<&QuantCtx>,
    mut tap: Option<Tap>,
) -> Result<Tensor> {
    let t = tokens.len();
    let d = cfg.d_model;
    let emb = w.get("emb.tok")?;
    let mut x = Tensor::zeros(&[t, d]);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(emb.row(tok as usize));
    }
    let rope = Rope::new(cfg, t);

    for layer in 0..cfg.n_layers {
        let p = format!("l{layer:02}");
        // -- attention --------------------------------------------------------
        let h = rmsnorm(&x, w.get(&format!("{p}.an"))?);
        let qkv = site_linear(
            &h,
            &[w.get(&format!("{p}.wq"))?, w.get(&format!("{p}.wk"))?,
              w.get(&format!("{p}.wv"))?],
            &p, quant, layer, "qkv", &mut tap,
        );
        let (mut q, mut k, v) = (qkv[0].clone(), qkv[1].clone(), qkv[2].clone());
        for ti in 0..t {
            rope.apply_row(cfg, q.row_mut(ti), ti);
            rope.apply_row(cfg, k.row_mut(ti), ti);
        }
        let att = attention_full(cfg, &q, &k, &v);
        let o = site_linear(&att, &[w.get(&format!("{p}.wo"))?], &p, quant,
                            layer, "o", &mut tap);
        x = x.add(&o[0]);

        // -- MLP ----------------------------------------------------------------
        let h2 = rmsnorm(&x, w.get(&format!("{p}.mn"))?);
        let y = if cfg.is_moe() {
            moe_mlp(cfg, w, &h2, layer, quant, &mut tap)?
        } else {
            dense_mlp(cfg, w, &h2, layer, &p, quant, &mut tap)?
        };
        x = x.add(&y);
    }

    let xf = rmsnorm(&x, w.get("out.norm")?);
    Ok(xf.matmul(w.get("out.head")?))
}

fn dense_mlp(
    _cfg: &ModelConfig,
    w: &Weights,
    h2: &Tensor,
    layer: usize,
    prefix: &str,
    quant: Option<&QuantCtx>,
    tap: &mut Option<Tap>,
) -> Result<Tensor> {
    let gu = site_linear(
        h2,
        &[w.get(&format!("{prefix}.wg"))?, w.get(&format!("{prefix}.wu"))?],
        prefix, quant, layer, "mlp", tap,
    );
    let mut hidden = gu[0].clone();
    swiglu_inplace(&mut hidden, &gu[1]);
    let out = site_linear(&hidden, &[w.get(&format!("{prefix}.wd"))?], prefix,
                          quant, layer, "down", tap);
    Ok(out[0].clone())
}

/// Top-k softmax gate over router logits `rl` [T, E].
pub(crate) fn moe_gate(cfg: &ModelConfig, rl: &Tensor) -> Tensor {
    let t = rl.rows();
    let mut gate = Tensor::zeros(&[t, cfg.n_experts]);
    for ti in 0..t {
        let row = rl.row(ti);
        let mut idx: Vec<usize> = (0..cfg.n_experts).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        let top = &idx[..cfg.top_k];
        let maxv = row[top[0]];
        let mut denom = 0.0f32;
        let mut exps = vec![0.0f32; cfg.top_k];
        for (j, &e) in top.iter().enumerate() {
            exps[j] = (row[e] - maxv).exp();
            denom += exps[j];
        }
        for (j, &e) in top.iter().enumerate() {
            gate.set(ti, e, exps[j] / denom);
        }
    }
    gate
}

fn moe_mlp(
    cfg: &ModelConfig,
    w: &Weights,
    h2: &Tensor,
    layer: usize,
    quant: Option<&QuantCtx>,
    tap: &mut Option<Tap>,
) -> Result<Tensor> {
    let p = format!("l{layer:02}");
    let t = h2.rows();
    let router = w.get(&format!("{p}.router"))?;
    let rl = h2.matmul(router); // [T, E]
    let gate = moe_gate(cfg, &rl);

    // The mlp/down site transforms are shared across experts: tap once on
    // the site input, then compute the quantized input once per site.
    if let Some(tp) = tap.as_mut() {
        tp(layer, "mlp", h2);
    }
    let skey_mlp = format!("l{layer:02}.mlp");
    let skey_down = format!("l{layer:02}.down");
    let xq = match quant {
        None => h2.clone(),
        Some(q) => {
            let rot = &q.rots[&skey_mlp];
            let clip = q.clips[&skey_mlp];
            let xr = kron_rotate_rows(h2, &rot.r1, &rot.r2);
            apply_act_quant(&xr, q, clip)
        }
    };

    let mut out = Tensor::zeros(&[t, cfg.d_model]);
    let mut tapped_down = false;
    for e in 0..cfg.n_experts {
        let wg = w.get(&format!("{p}.x{e}.wg"))?;
        let wu = w.get(&format!("{p}.x{e}.wu"))?;
        let wd = w.get(&format!("{p}.x{e}.wd"))?;
        let g = xq.matmul(wg);
        let u = xq.matmul(wu);
        let mut hidden = g;
        swiglu_inplace(&mut hidden, &u);
        if let Some(tp) = tap.as_mut() {
            if !tapped_down {
                tp(layer, "down", &hidden);
                tapped_down = true;
            }
        }
        let hq = match quant {
            None => hidden,
            Some(q) => {
                let rot = &q.rots[&skey_down];
                let clip = q.clips[&skey_down];
                let xr = kron_rotate_rows(&hidden, &rot.r1, &rot.r2);
                apply_act_quant(&xr, q, clip)
            }
        };
        let y = hq.matmul(wd);
        for ti in 0..t {
            let gw = gate.at(ti, e);
            if gw == 0.0 {
                continue;
            }
            let orow = out.row_mut(ti);
            for (j, &v) in y.row(ti).iter().enumerate() {
                orow[j] += gw * v;
            }
        }
    }
    Ok(out)
}

/// Next-token cross-entropy (nats/token) of a full sequence.
pub fn sequence_nll(logits: &Tensor, tokens: &[u16]) -> f32 {
    let t = tokens.len();
    let mut total = 0.0f32;
    for i in 0..t - 1 {
        let row = logits.row(i);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = maxv
            + row.iter().map(|v| (v - maxv).exp()).sum::<f32>().ln();
        total += lse - row[tokens[i + 1] as usize];
    }
    total / (t - 1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    #[test]
    fn fp_forward_shapes_and_finite() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let lg = forward_score(&cfg, &w, &toks(12, 2), None, None).unwrap();
        assert_eq!(lg.shape(), &[12, 260]);
        assert!(lg.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn identity_quant_ctx_w16_matches_fp() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let t = toks(10, 3);
        let fp = forward_score(&cfg, &w, &t, None, None).unwrap();
        let ctx = QuantCtx::identity(&cfg, 16);
        let qf = forward_score(&cfg, &w, &t, Some(&ctx), None).unwrap();
        assert!(fp.sub(&qf).max_abs() < 1e-3,
                "diff {}", fp.sub(&qf).max_abs());
    }

    #[test]
    fn w4a4_differs_but_finite() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let t = toks(10, 4);
        let fp = forward_score(&cfg, &w, &t, None, None).unwrap();
        let ctx = QuantCtx::identity(&cfg, 4);
        let qf = forward_score(&cfg, &w, &t, Some(&ctx), None).unwrap();
        let diff = fp.sub(&qf).max_abs();
        assert!(diff > 1e-4 && qf.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tap_sees_all_sites() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let mut seen = Vec::new();
        {
            let mut tap = |layer: usize, site: &str, x: &Tensor| {
                seen.push((layer, site.to_string(), x.rows(), x.cols()));
            };
            forward_score(&cfg, &w, &toks(8, 5), None, Some(&mut tap)).unwrap();
        }
        assert_eq!(seen.len(), cfg.n_layers * 4);
        assert!(seen.iter().any(|s| s.1 == "down" && s.3 == cfg.d_ff));
    }

    #[test]
    fn nll_positive_near_uniform_at_init() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 7);
        let t = toks(16, 8);
        let lg = forward_score(&cfg, &w, &t, None, None).unwrap();
        let nll = sequence_nll(&lg, &t);
        assert!(nll > 3.0 && nll < 8.0, "nll {nll}");
    }

    #[test]
    fn moe_forward_runs() {
        let mut cfg = test_config();
        cfg.n_experts = 3;
        cfg.top_k = 2;
        let w = Weights::random_init(&cfg, 2);
        let lg = forward_score(&cfg, &w, &toks(8, 9), None, None).unwrap();
        assert!(lg.data().iter().all(|v| v.is_finite()));
        // quantized MoE path too
        let ctx = QuantCtx::identity(&cfg, 4);
        let lq = forward_score(&cfg, &w, &toks(8, 9), Some(&ctx), None).unwrap();
        assert!(lq.data().iter().all(|v| v.is_finite()));
    }
}
