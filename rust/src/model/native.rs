//! NativeModel: direct multi-threaded CPU execution of a (quantized)
//! checkpoint — full-sequence prefill plus KV-cached incremental decode —
//! with the quantized linears held as packed low-bit codes
//! (`quant::repack::RepackedWeight`) and dequantized only inside the
//! matmul inner loop. No PJRT, no XLA, no f32 weight materialization.
//!
//! Built on the same per-layer primitives (`model::layers`) as the
//! reference forward, with the same per-row accumulation order, so the
//! dense configuration reproduces `forward::forward_score` bit-for-bit at
//! every decode step — the invariant `tests` pin down and the serving
//! backend (`runtime::NativeBackend`) relies on.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use super::config::ModelConfig;
use super::forward::moe_gate;
use super::layers::{apply_act_quant, attention_step, rmsnorm, swiglu_inplace, QuantCtx, Rope};
use super::weights::Weights;
use crate::pipeline::QuantizedModel;
use crate::quant::pack::PackedWeight;
use crate::quant::repack::RepackedWeight;
use crate::rotation::kronecker::kron_rotate_rows;
use crate::tensor::kernels::{matmul_packed, matmul_threaded, resolve_threads};
use crate::tensor::Tensor;

/// One linear weight as the execution engine holds it.
pub enum LinearOp {
    Dense(Tensor),
    Packed(RepackedWeight),
}

impl LinearOp {
    fn matmul(&self, x: &Tensor, threads: usize) -> Tensor {
        match self {
            LinearOp::Dense(w) => matmul_threaded(x, w, threads),
            LinearOp::Packed(w) => matmul_packed(x, w, threads),
        }
    }

    fn nbytes(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.len() * 4,
            LinearOp::Packed(w) => w.nbytes(),
        }
    }
}

/// Per-slot KV cache: post-RoPE K/V rows per layer, appended as positions
/// fill. Grows lazily to at most `max_seq · d_model` floats per side per
/// layer; `reset` keeps the allocation for the slot's next request.
pub struct SlotKv {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Number of cached positions (== rows per layer).
    pub pos: usize,
}

impl SlotKv {
    fn new(n_layers: usize) -> SlotKv {
        SlotKv {
            k: (0..n_layers).map(|_| Vec::new()).collect(),
            v: (0..n_layers).map(|_| Vec::new()).collect(),
            pos: 0,
        }
    }

    /// Drop the cached sequence (retire/reuse); capacity is kept.
    pub fn reset(&mut self) {
        for side in self.k.iter_mut().chain(self.v.iter_mut()) {
            side.clear();
        }
        self.pos = 0;
    }

    /// Resident bytes currently held by this slot's cache.
    pub fn nbytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|s| s.len() * 4).sum::<usize>()
    }
}

pub struct NativeModel {
    pub cfg: ModelConfig,
    /// Non-quantized parameters: embeddings, norms, router, output head.
    fp: Weights,
    /// Site-quantized linears (packed) or their dense f32 form.
    linears: BTreeMap<String, LinearOp>,
    /// Site rotations + activation quantization; `None` = fp forward.
    quant: Option<QuantCtx>,
    /// RoPE tables precomputed to `max_seq`.
    rope: Rope,
    pub threads: usize,
}

impl NativeModel {
    fn build(
        cfg: ModelConfig,
        weights: &Weights,
        quant: Option<QuantCtx>,
        pack_bits: Option<u32>,
        threads: usize,
    ) -> Result<NativeModel> {
        let site_names: BTreeSet<String> = (0..cfg.n_layers)
            .flat_map(|l| {
                super::config::ROT_SITES
                    .iter()
                    .flat_map(move |s| cfg.site_weights(l, s))
            })
            .collect();
        let mut fp = Weights::default();
        let mut linears = BTreeMap::new();
        for (name, t) in &weights.map {
            if site_names.contains(name) {
                let op = match pack_bits {
                    Some(bits) => LinearOp::Packed(RepackedWeight::from_packed(
                        &PackedWeight::pack(t, bits)?,
                    )?),
                    None => LinearOp::Dense(t.clone()),
                };
                linears.insert(name.clone(), op);
            } else {
                fp.insert(name, t.clone());
            }
        }
        let rope = Rope::new(&cfg, cfg.max_seq);
        Ok(NativeModel {
            fp,
            linears,
            quant,
            rope,
            threads: resolve_threads(threads),
            cfg,
        })
    }

    /// Dense execution of raw weights (fp when `quant` is `None`, the
    /// fake-quant emulation path otherwise). Bit-identical to
    /// `forward_score` under the same `quant`.
    pub fn from_weights(
        cfg: &ModelConfig,
        weights: &Weights,
        quant: Option<QuantCtx>,
        threads: usize,
    ) -> Result<NativeModel> {
        Self::build(cfg.clone(), weights, quant, None, threads)
    }

    /// Packed execution of a quantized package: the site linears (already
    /// on the `weight_bits` grid) are bit-packed and dequantize inside the
    /// matmul kernel. Grouped/GPTQ packages re-pack per output channel,
    /// which can move a code by one step at the grid edge — within the
    /// quantizer's own error floor.
    pub fn from_quantized(
        qm: &QuantizedModel,
        weight_bits: u32,
        threads: usize,
    ) -> Result<NativeModel> {
        let pack = if qm.graph_mode() == "fp" { None } else { Some(weight_bits) };
        Self::build(qm.cfg.clone(), &qm.weights, qm.quant_ctx(), pack, threads)
    }

    pub fn new_kv(&self) -> SlotKv {
        SlotKv::new(self.cfg.n_layers)
    }

    /// Total resident weight bytes (packed codes + scales + fp params).
    pub fn weight_nbytes(&self) -> usize {
        self.linears.values().map(|op| op.nbytes()).sum::<usize>()
            + self.fp.n_params() * 4
    }

    fn linear(&self, name: &str) -> Result<&LinearOp> {
        self.linears
            .get(name)
            .ok_or_else(|| anyhow!("missing linear {name:?}"))
    }

    /// Rotate + activation-quantize a site input (identity when fp).
    fn site_input(&self, x: &Tensor, layer: usize, site: &str) -> Tensor {
        match &self.quant {
            None => x.clone(),
            Some(q) => {
                let skey = format!("l{layer:02}.{site}");
                let rot = &q.rots[&skey];
                let clip = q.clips[&skey];
                let xr = kron_rotate_rows(x, &rot.r1, &rot.r2);
                apply_act_quant(&xr, q, clip)
            }
        }
    }

    /// Prefill a fresh slot with a prompt; returns logits `[len, V]` (the
    /// scheduler samples from the last row).
    pub fn prefill(&self, kv: &mut SlotKv, tokens: &[u16]) -> Result<Tensor> {
        if tokens.is_empty() {
            bail!("prefill: empty prompt");
        }
        if kv.pos != 0 {
            bail!("prefill: slot already holds {} positions", kv.pos);
        }
        self.step_rows(kv, tokens)
    }

    /// One incremental decode step: append `token` at position `kv.pos`,
    /// return its logits row `[V]`.
    pub fn decode(&self, kv: &mut SlotKv, token: u16) -> Result<Vec<f32>> {
        if kv.pos == 0 {
            bail!("decode before prefill");
        }
        Ok(self.step_rows(kv, &[token])?.into_data())
    }

    /// Full-sequence forward through a scratch cache: logits `[T, V]`.
    pub fn forward_full(&self, tokens: &[u16]) -> Result<Tensor> {
        let mut kv = self.new_kv();
        self.step_rows(&mut kv, tokens)
    }

    /// Process `t` new token rows at positions `kv.pos ..`, appending
    /// their K/V rows; the shared core of prefill and decode.
    fn step_rows(&self, kv: &mut SlotKv, tokens: &[u16]) -> Result<Tensor> {
        let t = tokens.len();
        let d = self.cfg.d_model;
        let start = kv.pos;
        if start + t > self.cfg.max_seq {
            bail!("kv cache capacity exceeded: {} + {t} > {}", start, self.cfg.max_seq);
        }
        let emb = self.fp.get("emb.tok")?;
        let mut x = Tensor::zeros(&[t, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            if tok as usize >= self.cfg.vocab_size {
                bail!("token {tok} out of vocab range {}", self.cfg.vocab_size);
            }
            x.row_mut(i).copy_from_slice(emb.row(tok as usize));
        }

        for layer in 0..self.cfg.n_layers {
            let p = format!("l{layer:02}");
            // -- attention ----------------------------------------------------
            let h = rmsnorm(&x, self.fp.get(&format!("{p}.an"))?);
            let hq = self.site_input(&h, layer, "qkv");
            let mut q = self.linear(&format!("{p}.wq"))?.matmul(&hq, self.threads);
            let mut k = self.linear(&format!("{p}.wk"))?.matmul(&hq, self.threads);
            let vv = self.linear(&format!("{p}.wv"))?.matmul(&hq, self.threads);
            for ti in 0..t {
                self.rope.apply_row(&self.cfg, q.row_mut(ti), start + ti);
                self.rope.apply_row(&self.cfg, k.row_mut(ti), start + ti);
            }
            kv.k[layer].extend_from_slice(k.data());
            kv.v[layer].extend_from_slice(vv.data());
            let kc = &kv.k[layer];
            let vc = &kv.v[layer];
            let mut att = Tensor::zeros(&[t, d]);
            for ti in 0..t {
                let len = start + ti + 1;
                let row = attention_step(&self.cfg, q.row(ti),
                                         &kc[..len * d], &vc[..len * d], len);
                att.row_mut(ti).copy_from_slice(&row);
            }
            let aq = self.site_input(&att, layer, "o");
            let o = self.linear(&format!("{p}.wo"))?.matmul(&aq, self.threads);
            x = x.add(&o);

            // -- MLP ----------------------------------------------------------
            let h2 = rmsnorm(&x, self.fp.get(&format!("{p}.mn"))?);
            let y = if self.cfg.is_moe() {
                self.moe(&h2, layer)?
            } else {
                self.mlp(&h2, layer)?
            };
            x = x.add(&y);
        }
        kv.pos = start + t;

        let xf = rmsnorm(&x, self.fp.get("out.norm")?);
        Ok(matmul_threaded(&xf, self.fp.get("out.head")?, self.threads))
    }

    fn mlp(&self, h2: &Tensor, layer: usize) -> Result<Tensor> {
        let p = format!("l{layer:02}");
        let xq = self.site_input(h2, layer, "mlp");
        let g = self.linear(&format!("{p}.wg"))?.matmul(&xq, self.threads);
        let u = self.linear(&format!("{p}.wu"))?.matmul(&xq, self.threads);
        let mut hidden = g;
        swiglu_inplace(&mut hidden, &u);
        let hq = self.site_input(&hidden, layer, "down");
        Ok(self.linear(&format!("{p}.wd"))?.matmul(&hq, self.threads))
    }

    fn moe(&self, h2: &Tensor, layer: usize) -> Result<Tensor> {
        let p = format!("l{layer:02}");
        let t = h2.rows();
        let router = self.fp.get(&format!("{p}.router"))?;
        let rl = h2.matmul(router);
        let gate = moe_gate(&self.cfg, &rl);
        let xq = self.site_input(h2, layer, "mlp");
        let mut out = Tensor::zeros(&[t, self.cfg.d_model]);
        for e in 0..self.cfg.n_experts {
            let g = self.linear(&format!("{p}.x{e}.wg"))?.matmul(&xq, self.threads);
            let u = self.linear(&format!("{p}.x{e}.wu"))?.matmul(&xq, self.threads);
            let mut hidden = g;
            swiglu_inplace(&mut hidden, &u);
            let hq = self.site_input(&hidden, layer, "down");
            let y = self.linear(&format!("{p}.x{e}.wd"))?.matmul(&hq, self.threads);
            for ti in 0..t {
                let gw = gate.at(ti, e);
                if gw == 0.0 {
                    continue;
                }
                let orow = out.row_mut(ti);
                for (j, &v) in y.row(ti).iter().enumerate() {
                    orow[j] += gw * v;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::forward::forward_score;
    use crate::pipeline::{quantize, PipelineOptions};

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    /// Prefill a prompt prefix then decode the rest; every logits row must
    /// equal the full-sequence reference bit-for-bit.
    fn check_exact(cfg: &ModelConfig, w: &Weights, quant: Option<QuantCtx>) {
        let tokens = toks(11, 3);
        let full = forward_score(cfg, w, &tokens, quant.as_ref(), None).unwrap();
        let nm = NativeModel::from_weights(cfg, w, quant, 2).unwrap();
        let mut kv = nm.new_kv();
        let plen = 5;
        let prefill = nm.prefill(&mut kv, &tokens[..plen]).unwrap();
        for i in 0..plen {
            assert_eq!(prefill.row(i), full.row(i), "prefill row {i}");
        }
        for (i, &tok) in tokens.iter().enumerate().skip(plen) {
            let row = nm.decode(&mut kv, tok).unwrap();
            assert_eq!(row.as_slice(), full.row(i), "decode row {i}");
        }
        assert_eq!(kv.pos, tokens.len());
    }

    #[test]
    fn decode_matches_reference_forward_exactly_fp() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_exact(&cfg, &w, None);
    }

    #[test]
    fn decode_matches_reference_forward_exactly_w4a4() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_exact(&cfg, &w, Some(QuantCtx::identity(&cfg, 4)));
    }

    #[test]
    fn decode_matches_reference_forward_exactly_moe() {
        let mut cfg = test_config();
        cfg.n_experts = 3;
        cfg.top_k = 2;
        let w = Weights::random_init(&cfg, 2);
        check_exact(&cfg, &w, None);
    }

    #[test]
    fn packed_decode_is_self_consistent_and_near_reference() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks(400, 9), &opts).unwrap();
        let nm = NativeModel::from_quantized(&qm, opts.weight_bits, 2).unwrap();
        let tokens = toks(9, 4);

        // packed prefill+decode must equal packed full forward exactly
        let full = nm.forward_full(&tokens).unwrap();
        let mut kv = nm.new_kv();
        let pre = nm.prefill(&mut kv, &tokens[..4]).unwrap();
        for i in 0..4 {
            assert_eq!(pre.row(i), full.row(i), "packed prefill row {i}");
        }
        for (i, &tok) in tokens.iter().enumerate().skip(4) {
            let row = nm.decode(&mut kv, tok).unwrap();
            assert_eq!(row.as_slice(), full.row(i), "packed decode row {i}");
        }

        // and stay within kernel-rounding distance of the fake-quant
        // reference forward over the same package
        let ctx = qm.quant_ctx().unwrap();
        let reference =
            forward_score(&qm.cfg, &qm.weights, &tokens, Some(&ctx), None).unwrap();
        let diff = full.sub(&reference).max_abs();
        assert!(diff < 5e-2, "packed vs fake-quant drift {diff}");
        assert!(full.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kv_reset_reuses_slot() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let mut kv = nm.new_kv();
        let a = nm.prefill(&mut kv, &toks(6, 5)).unwrap();
        assert!(kv.nbytes() > 0);
        kv.reset();
        assert_eq!(kv.pos, 0);
        let b = nm.prefill(&mut kv, &toks(6, 5)).unwrap();
        assert_eq!(a.data(), b.data(), "reset slot must replay identically");
    }

    #[test]
    fn capacity_and_misuse_errors() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let mut kv = nm.new_kv();
        assert!(nm.decode(&mut kv, 1).is_err(), "decode before prefill");
        assert!(nm.prefill(&mut kv, &[]).is_err(), "empty prompt");
        let long = toks(cfg.max_seq + 1, 6);
        assert!(nm.prefill(&mut kv, &long).is_err(), "over capacity");
    }

    #[test]
    fn packed_weights_are_smaller_than_dense() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks(400, 7), &opts).unwrap();
        let packed = NativeModel::from_quantized(&qm, 4, 1).unwrap();
        let dense = NativeModel::from_weights(&cfg, &qm.weights, None, 1).unwrap();
        assert!(packed.weight_nbytes() * 2 < dense.weight_nbytes(),
                "packed {} vs dense {}", packed.weight_nbytes(),
                dense.weight_nbytes());
    }
}
