//! NativeModel: direct multi-threaded CPU execution of a (quantized)
//! checkpoint — full-sequence prefill plus KV-cached incremental decode —
//! with the quantized linears held as packed low-bit codes
//! (`quant::repack::RepackedWeight`) and dequantized only inside the
//! matmul inner loop. No PJRT, no XLA, no f32 weight materialization.
//!
//! Built on the same per-layer primitives (`model::layers`) as the
//! reference forward, with the same per-row accumulation order, so the
//! dense configuration reproduces `forward::forward_score` bit-for-bit at
//! every decode step — the invariant `tests` pin down and the serving
//! backend (`runtime::NativeBackend`) relies on.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use super::config::ModelConfig;
use super::forward::moe_gate;
use super::layers::{
    apply_act_quant, attention_step_kv, rmsnorm, swiglu_inplace, QuantCtx, Rope,
};
use super::weights::Weights;
use crate::kv::{KvCache, SlotKv};
use crate::pipeline::QuantizedModel;
use crate::quant::pack::PackedWeight;
use crate::quant::repack::RepackedWeight;
use crate::rotation::kronecker::kron_rotate_rows;
use crate::tensor::kernels::{matmul_packed, matmul_threaded, resolve_threads};
use crate::tensor::Tensor;

/// One linear weight as the execution engine holds it.
pub enum LinearOp {
    Dense(Tensor),
    Packed(RepackedWeight),
}

impl LinearOp {
    fn matmul(&self, x: &Tensor, threads: usize) -> Tensor {
        match self {
            LinearOp::Dense(w) => matmul_threaded(x, w, threads),
            LinearOp::Packed(w) => matmul_packed(x, w, threads),
        }
    }

    fn nbytes(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.len() * 4,
            LinearOp::Packed(w) => w.nbytes(),
        }
    }
}

pub struct NativeModel {
    pub cfg: ModelConfig,
    /// Non-quantized parameters: embeddings, norms, router, output head.
    fp: Weights,
    /// Site-quantized linears (packed) or their dense f32 form.
    linears: BTreeMap<String, LinearOp>,
    /// Site rotations + activation quantization; `None` = fp forward.
    quant: Option<QuantCtx>,
    /// RoPE tables precomputed to `max_seq`.
    rope: Rope,
    pub threads: usize,
}

impl NativeModel {
    fn build(
        cfg: ModelConfig,
        weights: &Weights,
        quant: Option<QuantCtx>,
        pack_bits: Option<u32>,
        pack_group: Option<usize>,
        threads: usize,
    ) -> Result<NativeModel> {
        let site_names: BTreeSet<String> = (0..cfg.n_layers)
            .flat_map(|l| {
                super::config::ROT_SITES
                    .iter()
                    .flat_map(move |s| cfg.site_weights(l, s))
            })
            .collect();
        let mut fp = Weights::default();
        let mut linears = BTreeMap::new();
        for (name, t) in &weights.map {
            if site_names.contains(name) {
                let op = match pack_bits {
                    // Grouped packages re-quantize on their exact
                    // input-dim group grid; per-channel packages keep the
                    // original PackedWeight route.
                    Some(bits) => LinearOp::Packed(match pack_group {
                        Some(g) if g < t.rows() => RepackedWeight::pack(t, bits, g)?,
                        _ => RepackedWeight::from_packed(&PackedWeight::pack(t, bits)?)?,
                    }),
                    None => LinearOp::Dense(t.clone()),
                };
                linears.insert(name.clone(), op);
            } else {
                fp.insert(name, t.clone());
            }
        }
        let rope = Rope::new(&cfg, cfg.max_seq);
        Ok(NativeModel {
            fp,
            linears,
            quant,
            rope,
            threads: resolve_threads(threads),
            cfg,
        })
    }

    /// Dense execution of raw weights (fp when `quant` is `None`, the
    /// fake-quant emulation path otherwise). Bit-identical to
    /// `forward_score` under the same `quant`.
    pub fn from_weights(
        cfg: &ModelConfig,
        weights: &Weights,
        quant: Option<QuantCtx>,
        threads: usize,
    ) -> Result<NativeModel> {
        Self::build(cfg.clone(), weights, quant, None, None, threads)
    }

    /// Packed execution of a quantized package: the site linears (already
    /// on the `weight_bits` grid) are bit-packed and dequantize inside the
    /// matmul kernel. Grouped packages (GPTQ-g32, RTN-g32, ...) carry their
    /// group size and are re-packed on that exact input-dim grid; ungrouped
    /// ones re-pack per output channel, which can move a code by one step
    /// at the grid edge — within the quantizer's own error floor.
    pub fn from_quantized(
        qm: &QuantizedModel,
        weight_bits: u32,
        threads: usize,
    ) -> Result<NativeModel> {
        let pack = if qm.graph_mode() == "fp" { None } else { Some(weight_bits) };
        Self::build(qm.cfg.clone(), &qm.weights, qm.quant_ctx(), pack,
                    qm.weight_group, threads)
    }

    pub fn new_kv(&self) -> SlotKv {
        SlotKv::new(self.cfg.n_layers, self.cfg.d_model)
    }

    /// Total resident weight bytes (packed codes + scales + fp params).
    pub fn weight_nbytes(&self) -> usize {
        self.linears.values().map(|op| op.nbytes()).sum::<usize>()
            + self.fp.n_params() * 4
    }

    fn linear(&self, name: &str) -> Result<&LinearOp> {
        self.linears
            .get(name)
            .ok_or_else(|| anyhow!("missing linear {name:?}"))
    }

    /// Rotate + activation-quantize a site input (identity when fp).
    fn site_input(&self, x: &Tensor, layer: usize, site: &str) -> Tensor {
        match &self.quant {
            None => x.clone(),
            Some(q) => {
                let skey = format!("l{layer:02}.{site}");
                let rot = &q.rots[&skey];
                let clip = q.clips[&skey];
                let xr = kron_rotate_rows(x, &rot.r1, &rot.r2);
                apply_act_quant(&xr, q, clip)
            }
        }
    }

    /// Prefill a fresh slot with a prompt; returns logits `[len, V]` (the
    /// scheduler samples from the last row).
    pub fn prefill<K: KvCache>(&self, kv: &mut K, tokens: &[u16]) -> Result<Tensor> {
        if tokens.is_empty() {
            bail!("prefill: empty prompt");
        }
        if kv.pos() != 0 {
            bail!("prefill: slot already holds {} positions", kv.pos());
        }
        self.step_rows(kv, tokens)
    }

    /// One incremental decode step: append `token` at position `kv.pos`,
    /// return its logits row `[V]`.
    pub fn decode<K: KvCache>(&self, kv: &mut K, token: u16) -> Result<Vec<f32>> {
        if kv.pos() == 0 {
            bail!("decode before prefill");
        }
        Ok(self.step_rows(kv, &[token])?.into_data())
    }

    /// Full-sequence forward through a scratch cache: logits `[T, V]`.
    pub fn forward_full(&self, tokens: &[u16]) -> Result<Tensor> {
        let mut kv = self.new_kv();
        self.step_rows(&mut kv, tokens)
    }

    /// Process `t` new token rows at positions `kv.pos ..`, appending
    /// their K/V rows; the shared core of prefill and decode — and the
    /// speculative-decode verifier: row `i` of the returned `[t, V]`
    /// logits is the next-token distribution after consuming
    /// `tokens[..=i]`, bit-identical to decoding those tokens one at a
    /// time (the property `tests` pin), so a draft burst is checked in
    /// one call and rejected tokens roll back via [`KvCache::truncate`].
    ///
    /// All KV capacity is reserved up front, before any row is written:
    /// a paged cache that cannot cover the step fails here with
    /// [`crate::kv::KvError::PoolExhausted`] (downcastable through the
    /// returned `anyhow::Error`) and the slot state is untouched, so the
    /// batcher can preempt or requeue and replay the request later.
    pub fn step_rows<K: KvCache>(&self, kv: &mut K, tokens: &[u16]) -> Result<Tensor> {
        let t = tokens.len();
        let d = self.cfg.d_model;
        let start = kv.pos();
        if start + t > self.cfg.max_seq {
            bail!("kv cache capacity exceeded: {} + {t} > {}", start, self.cfg.max_seq);
        }
        kv.reserve(t).map_err(anyhow::Error::new)?;
        let emb = self.fp.get("emb.tok")?;
        let mut x = Tensor::zeros(&[t, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            if tok as usize >= self.cfg.vocab_size {
                bail!("token {tok} out of vocab range {}", self.cfg.vocab_size);
            }
            x.row_mut(i).copy_from_slice(emb.row(tok as usize));
        }

        for layer in 0..self.cfg.n_layers {
            let p = format!("l{layer:02}");
            // -- attention ----------------------------------------------------
            let h = rmsnorm(&x, self.fp.get(&format!("{p}.an"))?);
            let hq = self.site_input(&h, layer, "qkv");
            let mut q = self.linear(&format!("{p}.wq"))?.matmul(&hq, self.threads);
            let mut k = self.linear(&format!("{p}.wk"))?.matmul(&hq, self.threads);
            let vv = self.linear(&format!("{p}.wv"))?.matmul(&hq, self.threads);
            for ti in 0..t {
                self.rope.apply_row(&self.cfg, q.row_mut(ti), start + ti);
                self.rope.apply_row(&self.cfg, k.row_mut(ti), start + ti);
            }
            for ti in 0..t {
                kv.append_row(layer, start + ti, k.row(ti), vv.row(ti));
            }
            let mut att = Tensor::zeros(&[t, d]);
            for ti in 0..t {
                let len = start + ti + 1;
                let row = attention_step_kv(&self.cfg, q.row(ti), &*kv, layer, len);
                att.row_mut(ti).copy_from_slice(&row);
            }
            let aq = self.site_input(&att, layer, "o");
            let o = self.linear(&format!("{p}.wo"))?.matmul(&aq, self.threads);
            x = x.add(&o);

            // -- MLP ----------------------------------------------------------
            let h2 = rmsnorm(&x, self.fp.get(&format!("{p}.mn"))?);
            let y = if self.cfg.is_moe() {
                self.moe(&h2, layer)?
            } else {
                self.mlp(&h2, layer)?
            };
            x = x.add(&y);
        }
        kv.advance(t);

        let xf = rmsnorm(&x, self.fp.get("out.norm")?);
        Ok(matmul_threaded(&xf, self.fp.get("out.head")?, self.threads))
    }

    fn mlp(&self, h2: &Tensor, layer: usize) -> Result<Tensor> {
        let p = format!("l{layer:02}");
        let xq = self.site_input(h2, layer, "mlp");
        let g = self.linear(&format!("{p}.wg"))?.matmul(&xq, self.threads);
        let u = self.linear(&format!("{p}.wu"))?.matmul(&xq, self.threads);
        let mut hidden = g;
        swiglu_inplace(&mut hidden, &u);
        let hq = self.site_input(&hidden, layer, "down");
        Ok(self.linear(&format!("{p}.wd"))?.matmul(&hq, self.threads))
    }

    fn moe(&self, h2: &Tensor, layer: usize) -> Result<Tensor> {
        let p = format!("l{layer:02}");
        let t = h2.rows();
        let router = self.fp.get(&format!("{p}.router"))?;
        let rl = h2.matmul(router);
        let gate = moe_gate(&self.cfg, &rl);
        let xq = self.site_input(h2, layer, "mlp");
        let mut out = Tensor::zeros(&[t, self.cfg.d_model]);
        for e in 0..self.cfg.n_experts {
            let g = self.linear(&format!("{p}.x{e}.wg"))?.matmul(&xq, self.threads);
            let u = self.linear(&format!("{p}.x{e}.wu"))?.matmul(&xq, self.threads);
            let mut hidden = g;
            swiglu_inplace(&mut hidden, &u);
            let hq = self.site_input(&hidden, layer, "down");
            let y = self.linear(&format!("{p}.x{e}.wd"))?.matmul(&hq, self.threads);
            for ti in 0..t {
                let gw = gate.at(ti, e);
                if gw == 0.0 {
                    continue;
                }
                let orow = out.row_mut(ti);
                for (j, &v) in y.row(ti).iter().enumerate() {
                    orow[j] += gw * v;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{BlockPool, KvError, PageTable, PagedSlot};
    use crate::model::config::tests::test_config;
    use crate::model::forward::forward_score;
    use crate::pipeline::{quantize, PipelineOptions};
    use crate::quant::WeightQuantizer;

    fn toks(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(260) as u16).collect()
    }

    /// Prefill a prompt prefix then decode the rest; every logits row must
    /// equal the full-sequence reference bit-for-bit.
    fn check_exact(cfg: &ModelConfig, w: &Weights, quant: Option<QuantCtx>) {
        let tokens = toks(11, 3);
        let full = forward_score(cfg, w, &tokens, quant.as_ref(), None).unwrap();
        let nm = NativeModel::from_weights(cfg, w, quant, 2).unwrap();
        let mut kv = nm.new_kv();
        let plen = 5;
        let prefill = nm.prefill(&mut kv, &tokens[..plen]).unwrap();
        for i in 0..plen {
            assert_eq!(prefill.row(i), full.row(i), "prefill row {i}");
        }
        for (i, &tok) in tokens.iter().enumerate().skip(plen) {
            let row = nm.decode(&mut kv, tok).unwrap();
            assert_eq!(row.as_slice(), full.row(i), "decode row {i}");
        }
        assert_eq!(kv.pos, tokens.len());
    }

    #[test]
    fn decode_matches_reference_forward_exactly_fp() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_exact(&cfg, &w, None);
    }

    #[test]
    fn decode_matches_reference_forward_exactly_w4a4() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_exact(&cfg, &w, Some(QuantCtx::identity(&cfg, 4)));
    }

    #[test]
    fn decode_matches_reference_forward_exactly_moe() {
        let mut cfg = test_config();
        cfg.n_experts = 3;
        cfg.top_k = 2;
        let w = Weights::random_init(&cfg, 2);
        check_exact(&cfg, &w, None);
    }

    /// The decode-wave building block: a step through a `WaveOverlay`
    /// (shared base + buffered rows, committed afterwards) must be
    /// bit-equal to decoding straight into the cache.
    #[test]
    fn decode_through_wave_overlay_is_bit_equal_to_direct() {
        use crate::kv::WaveOverlay;
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 2).unwrap();
        let tokens = toks(9, 8);
        let plen = 5;
        let mut direct = nm.new_kv();
        nm.prefill(&mut direct, &tokens[..plen]).unwrap();
        let mut staged = nm.new_kv();
        nm.prefill(&mut staged, &tokens[..plen]).unwrap();
        for &tok in &tokens[plen..] {
            let want = nm.decode(&mut direct, tok).unwrap();
            let rows = {
                let base = &staged;
                let mut ov = WaveOverlay::new(base, base.pos, cfg.n_layers, cfg.d_model);
                let got = nm.decode(&mut ov, tok).unwrap();
                assert_eq!(got, want, "overlay decode diverged");
                ov.into_rows()
            };
            rows.commit(&mut staged).unwrap();
            assert_eq!(staged.pos, direct.pos);
        }
    }

    #[test]
    fn packed_decode_is_self_consistent_and_near_reference() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks(400, 9), &opts).unwrap();
        let nm = NativeModel::from_quantized(&qm, opts.weight_bits, 2).unwrap();
        let tokens = toks(9, 4);

        // packed prefill+decode must equal packed full forward exactly
        let full = nm.forward_full(&tokens).unwrap();
        let mut kv = nm.new_kv();
        let pre = nm.prefill(&mut kv, &tokens[..4]).unwrap();
        for i in 0..4 {
            assert_eq!(pre.row(i), full.row(i), "packed prefill row {i}");
        }
        for (i, &tok) in tokens.iter().enumerate().skip(4) {
            let row = nm.decode(&mut kv, tok).unwrap();
            assert_eq!(row.as_slice(), full.row(i), "packed decode row {i}");
        }

        // and stay within kernel-rounding distance of the fake-quant
        // reference forward over the same package
        let ctx = qm.quant_ctx().unwrap();
        let reference =
            forward_score(&qm.cfg, &qm.weights, &tokens, Some(&ctx), None).unwrap();
        let diff = full.sub(&reference).max_abs();
        assert!(diff < 5e-2, "packed vs fake-quant drift {diff}");
        assert!(full.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kv_reset_reuses_slot() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let mut kv = nm.new_kv();
        let a = nm.prefill(&mut kv, &toks(6, 5)).unwrap();
        assert!(kv.nbytes() > 0);
        kv.reset();
        assert_eq!(kv.pos, 0);
        let b = nm.prefill(&mut kv, &toks(6, 5)).unwrap();
        assert_eq!(a.data(), b.data(), "reset slot must replay identically");
    }

    #[test]
    fn capacity_and_misuse_errors() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let mut kv = nm.new_kv();
        assert!(nm.decode(&mut kv, 1).is_err(), "decode before prefill");
        assert!(nm.prefill(&mut kv, &[]).is_err(), "empty prompt");
        let long = toks(cfg.max_seq + 1, 6);
        assert!(nm.prefill(&mut kv, &long).is_err(), "over capacity");
    }

    /// Property: paged prefill + decode is bit-identical to the
    /// contiguous SlotKv path, for every page size, including sizes that
    /// split the prompt mid-page (1, 7) and one that doesn't (16).
    fn check_paged_exact(nm: &NativeModel) {
        let tokens = toks(11, 3);
        let plen = 5;
        let mut kv = nm.new_kv();
        let ref_pre = nm.prefill(&mut kv, &tokens[..plen]).unwrap();
        let mut ref_rows: Vec<Vec<f32>> = Vec::new();
        for &tok in &tokens[plen..] {
            ref_rows.push(nm.decode(&mut kv, tok).unwrap());
        }
        for page_tokens in [1usize, 7, 16] {
            let mut pool = BlockPool::new(
                nm.cfg.n_layers, nm.cfg.d_model, page_tokens,
                tokens.len().div_ceil(page_tokens),
            );
            let mut table = PageTable::new();
            let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
            let pre = nm.prefill(&mut slot, &tokens[..plen]).unwrap();
            assert_eq!(pre.data(), ref_pre.data(), "prefill pt={page_tokens}");
            for (i, &tok) in tokens.iter().enumerate().skip(plen) {
                let row = nm.decode(&mut slot, tok).unwrap();
                assert_eq!(row, ref_rows[i - plen],
                           "decode row {i} pt={page_tokens}");
            }
            assert_eq!(slot.pos(), tokens.len());
        }
    }

    #[test]
    fn paged_matches_contiguous_bit_exact_fp() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_paged_exact(&NativeModel::from_weights(&cfg, &w, None, 2).unwrap());
    }

    #[test]
    fn paged_matches_contiguous_bit_exact_w4a4() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let quant = Some(QuantCtx::identity(&cfg, 4));
        check_paged_exact(&NativeModel::from_weights(&cfg, &w, quant, 2).unwrap());
    }

    #[test]
    fn paged_matches_contiguous_bit_exact_packed() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks(400, 9), &opts).unwrap();
        check_paged_exact(&NativeModel::from_quantized(&qm, opts.weight_bits, 2).unwrap());
    }

    #[test]
    fn paged_pool_exhaustion_fails_cleanly_and_is_replayable() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let nm = NativeModel::from_weights(&cfg, &w, None, 1).unwrap();
        let tokens = toks(9, 8);
        // 2 pages of 4 = 8 positions: prefill of 8 fits, decode must fail
        let mut pool = BlockPool::new(cfg.n_layers, cfg.d_model, 4, 2);
        let mut table = PageTable::new();
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        nm.prefill(&mut slot, &tokens[..8]).unwrap();
        let err = nm.decode(&mut slot, tokens[8]).unwrap_err();
        let kv_err = err.downcast_ref::<KvError>().expect("typed kv error");
        assert_eq!(*kv_err, KvError::PoolExhausted { needed: 1, free: 0 });
        // the failed step must not have touched the slot: freeing one
        // page's worth elsewhere is not possible here, so instead verify
        // the cache still decodes correctly once capacity appears
        assert_eq!(table.pos(), 8, "failed reserve must not corrupt pos");
        let mut bigger = BlockPool::new(cfg.n_layers, cfg.d_model, 4, 3);
        let mut table2 = PageTable::new();
        let mut slot2 = PagedSlot { pool: &mut bigger, table: &mut table2 };
        nm.prefill(&mut slot2, &tokens[..8]).unwrap();
        let row = nm.decode(&mut slot2, tokens[8]).unwrap();
        let mut kv = nm.new_kv();
        nm.prefill(&mut kv, &tokens[..8]).unwrap();
        assert_eq!(row, nm.decode(&mut kv, tokens[8]).unwrap());
    }

    /// The speculative-decode verify/rollback lemma: a multi-row
    /// `step_rows` burst produces logits rows bit-identical to decoding
    /// the same tokens one at a time, and `truncate` back to an accepted
    /// prefix leaves the cache indistinguishable from one that only ever
    /// decoded that prefix — on contiguous and paged KV at page sizes
    /// that split the burst mid-page and on the boundary.
    fn check_burst_rollback_exact(nm: &NativeModel) {
        let tokens = toks(14, 3);
        let plen = 5;
        // sequential reference rows
        let mut ref_kv = nm.new_kv();
        nm.prefill(&mut ref_kv, &tokens[..plen]).unwrap();
        let mut ref_rows: Vec<Vec<f32>> = Vec::new();
        for &tok in &tokens[plen..] {
            ref_rows.push(nm.decode(&mut ref_kv, tok).unwrap());
        }

        let burst_len = 4usize;
        for accept in 0..=burst_len {
            // contiguous
            let mut kv = nm.new_kv();
            nm.prefill(&mut kv, &tokens[..plen]).unwrap();
            let burst = &tokens[plen..plen + burst_len];
            let rows = nm.step_rows(&mut kv, burst).unwrap();
            for i in 0..burst_len {
                assert_eq!(rows.row(i), ref_rows[i].as_slice(),
                           "burst row {i} vs sequential");
            }
            kv.truncate(plen + accept);
            assert_eq!(kv.pos, plen + accept);
            // decoding after the rollback continues the sequential stream
            let row = nm.decode(&mut kv, tokens[plen + accept]).unwrap();
            assert_eq!(row, ref_rows[accept], "post-truncate decode accept={accept}");

            // paged, across page sizes
            for pt in [1usize, 7, 16] {
                let mut pool = BlockPool::new(
                    nm.cfg.n_layers, nm.cfg.d_model, pt,
                    tokens.len().div_ceil(pt),
                );
                let mut table = PageTable::new();
                let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
                nm.prefill(&mut slot, &tokens[..plen]).unwrap();
                let rows = nm.step_rows(&mut slot, burst).unwrap();
                for i in 0..burst_len {
                    assert_eq!(rows.row(i), ref_rows[i].as_slice(),
                               "paged pt={pt} burst row {i}");
                }
                slot.truncate(plen + accept);
                let row = nm.decode(&mut slot, tokens[plen + accept]).unwrap();
                assert_eq!(row, ref_rows[accept],
                           "paged pt={pt} post-truncate decode accept={accept}");
            }
        }
    }

    #[test]
    fn burst_verify_and_rollback_exact_fp() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        check_burst_rollback_exact(&NativeModel::from_weights(&cfg, &w, None, 2).unwrap());
    }

    #[test]
    fn burst_verify_and_rollback_exact_w4a4() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let quant = Some(QuantCtx::identity(&cfg, 4));
        check_burst_rollback_exact(
            &NativeModel::from_weights(&cfg, &w, quant, 2).unwrap(),
        );
    }

    #[test]
    fn grouped_package_packs_on_its_exact_grid() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions {
            weight_quantizer: WeightQuantizer::RtnGrouped(8),
            calib_seqs: 2,
            calib_len: 24,
            ..Default::default()
        };
        let qm = quantize(&cfg, &w, &toks(400, 9), &opts).unwrap();
        assert_eq!(qm.weight_group, Some(8));
        // the pipeline's dequantized weights sit exactly on the g=8 grid,
        // so a grouped re-pack reproduces them (scale recovery from the
        // absmax element is exact for RTN); the per-channel re-pack the
        // old path used cannot
        let wq = qm.weights.get("l00.wq").unwrap();
        let grouped = RepackedWeight::pack(wq, opts.weight_bits, 8).unwrap();
        let g_err = grouped.dequantize().sub(wq).max_abs();
        assert!(g_err < 1e-5, "grouped re-pack drift {g_err}");
        let per_chan = RepackedWeight::from_packed(
            &PackedWeight::pack(wq, opts.weight_bits).unwrap(),
        )
        .unwrap();
        let c_err = per_chan.dequantize().sub(wq).max_abs();
        assert!(g_err <= c_err, "grouped {g_err} must not lose to per-channel {c_err}");

        // end to end: the packed model runs and matches the fake-quant
        // reference within kernel rounding
        let nm = NativeModel::from_quantized(&qm, opts.weight_bits, 2).unwrap();
        let tokens = toks(9, 4);
        let full = nm.forward_full(&tokens).unwrap();
        let ctx = qm.quant_ctx().unwrap();
        let reference =
            forward_score(&qm.cfg, &qm.weights, &tokens, Some(&ctx), None).unwrap();
        let diff = full.sub(&reference).max_abs();
        assert!(diff < 5e-2, "grouped packed vs fake-quant drift {diff}");
    }

    #[test]
    fn packed_weights_are_smaller_than_dense() {
        let cfg = test_config();
        let w = Weights::random_init(&cfg, 1);
        let opts = PipelineOptions { calib_seqs: 2, calib_len: 24, ..Default::default() };
        let qm = quantize(&cfg, &w, &toks(400, 7), &opts).unwrap();
        let packed = NativeModel::from_quantized(&qm, 4, 1).unwrap();
        let dense = NativeModel::from_weights(&cfg, &qm.weights, None, 1).unwrap();
        assert!(packed.weight_nbytes() * 2 < dense.weight_nbytes(),
                "packed {} vs dense {}", packed.weight_nbytes(),
                dense.weight_nbytes());
    }
}
