//! Model configuration, sourced from `artifacts/manifest.json` (the single
//! source of truth written by `python/compile/aot.py`) so the Rust side can
//! never drift from the lowered graphs.

use anyhow::{anyhow, Result};

use crate::rotation::kronecker::kron_factor;
use crate::util::json::Json;

/// The rotation/quantization sites of every layer, in layout order.
pub const ROT_SITES: [&str; 4] = ["qkv", "o", "mlp", "down"];

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub score_seq: usize,
    pub rope_theta: f32,
    pub n_experts: usize,
    pub top_k: usize,
    /// Name of the config whose HLO artifacts this model executes
    /// (chat variants share their base architecture's graphs).
    pub artifact_config: String,
}

impl ModelConfig {
    /// Built-in small configuration for artifact-free native serving:
    /// lets `serve-http --backend native` and the integration tests run a
    /// real quantized model without any lowered HLO on disk.
    pub fn demo() -> ModelConfig {
        ModelConfig {
            name: "sq-demo".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 128,
            vocab_size: 260,
            max_seq: 160,
            score_seq: 96,
            rope_theta: 10000.0,
            n_experts: 0,
            top_k: 2,
            artifact_config: "sq-demo".into(),
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Kronecker factors for a rotation site's width.
    pub fn site_dims(&self, site: &str) -> (usize, usize, usize) {
        let n = if site == "down" { self.d_ff } else { self.d_model };
        let (n1, n2) = kron_factor(n);
        (n, n1, n2)
    }

    pub fn from_manifest(manifest: &Json, name: &str) -> Result<ModelConfig> {
        let c = manifest
            .get("configs")?
            .opt(name)
            .ok_or_else(|| anyhow!("config {name:?} not in manifest"))?;
        Ok(ModelConfig {
            name: name.to_string(),
            d_model: c.usize_at("d_model")?,
            n_layers: c.usize_at("n_layers")?,
            n_heads: c.usize_at("n_heads")?,
            d_ff: c.usize_at("d_ff")?,
            vocab_size: c.usize_at("vocab_size")?,
            max_seq: c.usize_at("max_seq")?,
            score_seq: c.usize_at("score_seq")?,
            rope_theta: c.f64_at("rope_theta")? as f32,
            n_experts: c.usize_at("n_experts")?,
            top_k: c.usize_at("top_k")?,
            artifact_config: c.str_at("artifact_config")?.to_string(),
        })
    }

    // -- parameter layout (must mirror python/compile/model.py exactly) ------

    pub fn weight_names(&self) -> Vec<String> {
        let mut names = vec!["emb.tok".to_string()];
        for i in 0..self.n_layers {
            let p = format!("l{i:02}");
            names.push(format!("{p}.an"));
            names.push(format!("{p}.wq"));
            names.push(format!("{p}.wk"));
            names.push(format!("{p}.wv"));
            names.push(format!("{p}.wo"));
            names.push(format!("{p}.mn"));
            if self.is_moe() {
                names.push(format!("{p}.router"));
                for e in 0..self.n_experts {
                    names.push(format!("{p}.x{e}.wg"));
                    names.push(format!("{p}.x{e}.wu"));
                    names.push(format!("{p}.x{e}.wd"));
                }
            } else {
                names.push(format!("{p}.wg"));
                names.push(format!("{p}.wu"));
                names.push(format!("{p}.wd"));
            }
        }
        names.push("out.norm".to_string());
        names.push("out.head".to_string());
        names
    }

    pub fn rot_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for i in 0..self.n_layers {
            let p = format!("l{i:02}");
            for site in ROT_SITES {
                names.push(format!("{p}.rot_{site}.r1"));
                names.push(format!("{p}.rot_{site}.r2"));
                names.push(format!("{p}.clip_{site}"));
            }
        }
        names
    }

    /// Ordered parameter list for a graph mode ("fp" | "w4a4" | "w4a16").
    pub fn param_layout(&self, mode: &str) -> Vec<String> {
        let mut names = self.weight_names();
        if mode != "fp" {
            names.extend(self.rot_names());
        }
        names
    }

    /// The quantized linear weights of one layer grouped by rotation site.
    pub fn site_weights(&self, layer: usize, site: &str) -> Vec<String> {
        let p = format!("l{layer:02}");
        match site {
            "qkv" => vec![format!("{p}.wq"), format!("{p}.wk"), format!("{p}.wv")],
            "o" => vec![format!("{p}.wo")],
            "mlp" => {
                if self.is_moe() {
                    (0..self.n_experts)
                        .flat_map(|e| {
                            vec![format!("{p}.x{e}.wg"), format!("{p}.x{e}.wu")]
                        })
                        .collect()
                } else {
                    vec![format!("{p}.wg"), format!("{p}.wu")]
                }
            }
            "down" => {
                if self.is_moe() {
                    (0..self.n_experts).map(|e| format!("{p}.x{e}.wd")).collect()
                } else {
                    vec![format!("{p}.wd")]
                }
            }
            _ => panic!("unknown site {site}"),
        }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    pub fn test_config() -> ModelConfig {
        // same shape as the demo serving model so the tests pin exactly
        // what `--backend native` serves artifact-free
        ModelConfig {
            name: "sq-test".into(),
            artifact_config: "sq-test".into(),
            ..ModelConfig::demo()
        }
    }

    #[test]
    fn layout_shape() {
        let c = test_config();
        let fp = c.param_layout("fp");
        assert_eq!(fp[0], "emb.tok");
        assert_eq!(fp.last().unwrap(), "out.head");
        let q = c.param_layout("w4a4");
        assert_eq!(&q[..fp.len()], &fp[..]);
        assert_eq!(q.len(), fp.len() + c.n_layers * 4 * 3);
    }

    #[test]
    fn site_weights_dense() {
        let c = test_config();
        assert_eq!(c.site_weights(0, "qkv"),
                   vec!["l00.wq", "l00.wk", "l00.wv"]);
        assert_eq!(c.site_weights(1, "down"), vec!["l01.wd"]);
    }

    #[test]
    fn site_dims_factor() {
        let c = test_config();
        let (n, n1, n2) = c.site_dims("qkv");
        assert_eq!(n, 64);
        assert_eq!(n1 * n2, 64);
        let (nf, _, _) = c.site_dims("down");
        assert_eq!(nf, 128);
    }
}
