//! Minimal JSON parser/writer (offline stand-in for serde_json).
//!
//! Parses the build-path outputs (`manifest.json`, `*.layout.json`,
//! `tasks.json`, `mmlu.json`) and serializes experiment reports. Supports
//! the full JSON grammar minus exotic number forms; strings handle the
//! standard escapes plus `\uXXXX` (BMP only — sufficient for our ASCII
//! artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow!("parse {path}: {e}"))
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    // -- optional-field accessors (the HTTP wire format's bread and butter) --

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.opt(key).and_then(|v| v.as_str().ok())
    }

    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.opt(key).and_then(|v| v.as_f64().ok())
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.opt(key).and_then(|v| v.as_usize().ok())
    }

    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        self.opt(key).and_then(|v| v.as_bool().ok())
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_at(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize()
    }

    pub fn f64_at(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64()
    }

    pub fn str_at(&self, key: &str) -> Result<&str> {
        self.get(key)?.as_str()
    }

    // -- construction helpers -----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn bool(b: bool) -> Json {
        Json::Bool(b)
    }

    /// Integer-valued number (serialized without a fraction).
    pub fn int(x: i64) -> Json {
        Json::Num(x as f64)
    }

    pub fn usize(x: usize) -> Json {
        Json::Num(x as f64)
    }

    // -- serialization --------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through (continuation handled by
                    // pushing the full encoded char)
                    let start = self.i - 1;
                    let width = utf8_width(c);
                    self.i = start + width;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""héllo""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn nested_access() {
        let v = Json::parse(r#"{"m": {"n": 7}}"#).unwrap();
        assert_eq!(v.get("m").unwrap().usize_at("n").unwrap(), 7);
    }

    #[test]
    fn wire_helpers() {
        let v = Json::parse(
            r#"{"stream": true, "max_tokens": 8, "temperature": 0.5, "p": "hi", "n": null}"#,
        )
        .unwrap();
        assert_eq!(v.opt_bool("stream"), Some(true));
        assert_eq!(v.opt_usize("max_tokens"), Some(8));
        assert_eq!(v.opt_f64("temperature"), Some(0.5));
        assert_eq!(v.opt_str("p"), Some("hi"));
        assert_eq!(v.opt_str("n"), None, "null reads as absent");
        assert_eq!(v.opt_str("missing"), None);

        let out = Json::obj(vec![
            ("ok", Json::bool(true)),
            ("count", Json::int(-3)),
            ("size", Json::usize(7)),
        ])
        .to_string();
        assert_eq!(out, r#"{"count":-3,"ok":true,"size":7}"#);
    }

    #[test]
    fn sse_control_chars_escaped() {
        // newlines inside a streamed token must never split an SSE frame
        let s = Json::str("a\nb\u{1}").to_string();
        assert_eq!(s, "\"a\\nb\\u0001\"");
    }
}
