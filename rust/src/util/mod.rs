//! Hand-rolled substrates.
//!
//! This environment is fully offline — the only third-party crates
//! available are `xla` and `anyhow` (plus their transitive deps), so the
//! usual ecosystem pieces (serde, rand, clap, criterion, proptest) are
//! implemented here from scratch, sized to what the rest of the system
//! needs:
//!
//! * [`rng`]   — xoshiro256++ PRNG with normal/uniform/permutation helpers.
//! * [`json`]  — recursive-descent JSON parser + writer (manifest, tasks,
//!   reports).
//! * [`sqt`]   — the named-tensor container format shared with the Python
//!   build path (twin of `python/compile/sqt.py`).
//! * [`cli`]   — subcommand + `--flag value` argument parser.
//! * [`bench`] — wall-clock micro-benchmark harness with robust statistics
//!   (criterion stand-in; used by `cargo bench` targets).
//! * [`prop`]  — property-testing harness (proptest stand-in) used for the
//!   invariant suites in `rust/tests/`.
//! * [`clock`] — the single sanctioned wall-clock read for serving logic
//!   (everything else is flagged by `sqlint`'s determinism rule).

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sqt;
