//! xoshiro256++ pseudo-random generator (offline stand-in for `rand`).
//!
//! Deterministic across platforms; every stochastic component in the
//! library (random orthogonal complements, calibration subsampling, the
//! Cayley-SGD baseline, benchmark workloads) threads an explicit [`Rng`]
//! so experiments are reproducible seed-for-seed.

/// xoshiro256++ state. <https://prng.di.unimi.it/xoshiro256plusplus.c>
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the reference recommendation) so small seeds
    /// still produce well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's rejection-free-enough method is overkill here; modulo
        // bias is negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of i.i.d. N(0, sigma^2) f32s.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * sigma).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// k distinct indices from 0..n.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut p = self.permutation(n);
        p.truncate(k.min(n));
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let mut p = r.permutation(57);
        p.sort_unstable();
        assert_eq!(p, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
