//! SQT named-tensor container: the Rust twin of `python/compile/sqt.py`.
//!
//! See the Python module for the byte layout. Checkpoints, token corpora,
//! and quantized-model packages all travel in this format.

use std::collections::BTreeMap;
use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

use super::json::Json;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"SQT1";

/// A tensor of one of the supported on-disk dtypes.
#[derive(Clone, Debug)]
pub enum AnyTensor {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U16 { shape: Vec<usize>, data: Vec<u16> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl AnyTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => t.shape(),
            AnyTensor::I32 { shape, .. } => shape,
            AnyTensor::U16 { shape, .. } => shape,
            AnyTensor::U8 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            AnyTensor::F32(t) => Ok(t),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_u16(&self) -> Result<&[u16]> {
        match self {
            AnyTensor::U16 { data, .. } => Ok(data),
            _ => bail!("tensor is not u16"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            AnyTensor::U8 { data, .. } => Ok(data),
            _ => bail!("tensor is not u8"),
        }
    }
}

/// An SQT file in memory: named tensors + free-form JSON metadata.
#[derive(Clone, Debug, Default)]
pub struct SqtFile {
    pub tensors: BTreeMap<String, AnyTensor>,
    pub meta: Option<Json>,
}

impl SqtFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert_f32(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), AnyTensor::F32(t));
    }

    pub fn get(&self, name: &str) -> Result<&AnyTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("SQT: missing tensor {name:?}"))
    }

    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        self.get(name)?.as_f32()
    }

    pub fn load(path: &str) -> Result<SqtFile> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).map_err(|e| anyhow!("open {path}: {e}"))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path}: bad SQT magic");
        }
        let n_tensors = read_u32(&mut f)? as usize;
        let meta_len = read_u32(&mut f)? as usize;
        let mut meta_bytes = vec![0u8; meta_len];
        f.read_exact(&mut meta_bytes)?;
        let meta = if meta_len > 0 {
            Some(Json::parse(std::str::from_utf8(&meta_bytes)?)?)
        } else {
            None
        };
        let mut tensors = BTreeMap::new();
        for _ in 0..n_tensors {
            let name_len = read_u16(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes)?;
            let mut db = [0u8; 2];
            f.read_exact(&mut db)?;
            let (dtype, ndim) = (db[0], db[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let nbytes = read_u64(&mut f)? as usize;
            let mut raw = vec![0u8; nbytes];
            f.read_exact(&mut raw)?;
            let t = match dtype {
                0 => AnyTensor::F32(Tensor::from_raw(
                    shape,
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )),
                1 => AnyTensor::I32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                2 => AnyTensor::U16 {
                    shape,
                    data: raw
                        .chunks_exact(2)
                        .map(|c| u16::from_le_bytes([c[0], c[1]]))
                        .collect(),
                },
                3 => AnyTensor::U8 { shape, data: raw },
                d => bail!("{path}: unknown dtype code {d}"),
            };
            tensors.insert(name, t);
        }
        Ok(SqtFile { tensors, meta })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        let meta_bytes = self
            .meta
            .as_ref()
            .map(|m| m.to_string().into_bytes())
            .unwrap_or_default();
        f.write_all(&(meta_bytes.len() as u32).to_le_bytes())?;
        f.write_all(&meta_bytes)?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u16).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            let (code, shape): (u8, &[usize]) = match t {
                AnyTensor::F32(x) => (0, x.shape()),
                AnyTensor::I32 { shape, .. } => (1, shape),
                AnyTensor::U16 { shape, .. } => (2, shape),
                AnyTensor::U8 { shape, .. } => (3, shape),
            };
            f.write_all(&[code, shape.len() as u8])?;
            for &d in shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            match t {
                AnyTensor::F32(x) => {
                    f.write_all(&((x.len() * 4) as u64).to_le_bytes())?;
                    for v in x.data() {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                AnyTensor::I32 { data, .. } => {
                    f.write_all(&((data.len() * 4) as u64).to_le_bytes())?;
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                AnyTensor::U16 { data, .. } => {
                    f.write_all(&((data.len() * 2) as u64).to_le_bytes())?;
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                AnyTensor::U8 { data, .. } => {
                    f.write_all(&(data.len() as u64).to_le_bytes())?;
                    f.write_all(data)?;
                }
            }
        }
        Ok(())
    }
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sqt_test_rs");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sqt");
        let mut f = SqtFile::new();
        f.insert_f32("w", Tensor::from_raw(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        f.tensors.insert(
            "toks".into(),
            AnyTensor::U16 { shape: vec![4], data: vec![9, 8, 7, 256] },
        );
        f.meta = Some(Json::parse(r#"{"config": "sq-s", "steps": 10}"#).unwrap());
        f.save(path.to_str().unwrap()).unwrap();
        let g = SqtFile::load(path.to_str().unwrap()).unwrap();
        assert_eq!(g.f32("w").unwrap().data(), &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(g.get("toks").unwrap().as_u16().unwrap(), &[9, 8, 7, 256]);
        assert_eq!(g.meta.unwrap().str_at("config").unwrap(), "sq-s");
    }
}
