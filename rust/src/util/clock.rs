//! The single wall-clock chokepoint for serving logic.
//!
//! Every non-metrics module that needs "now" goes through [`now`], so
//! the determinism lint (`sqlint`, rule `nondet`) can allow exactly one
//! file instead of scattering suppressions: grep for `Instant::now`
//! outside this module, `coordinator/metrics.rs`, `util/bench.rs`, and
//! `server/` and you should find nothing. Centralising the call is also
//! what would let a future record/replay harness swap in a virtual
//! clock without touching call sites.

use std::time::Instant;

/// Current monotonic instant. The one sanctioned `Instant::now()` on
/// the serving path.
#[inline]
pub fn now() -> Instant {
    // sqlint: allow(nondet) — this module IS the sanctioned chokepoint
    Instant::now()
}
