//! Micro-benchmark harness (offline stand-in for criterion).
//!
//! Used by the `cargo bench` targets (`rust/benches/*.rs`, all
//! `harness = false`) and by the quantization-time experiment (Table 7).
//! Reports robust statistics over repeated timed runs after a warmup.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Stats over hand-collected times — for loops where setup work must
    /// stay outside the timed region (`bench`/`bench_for` time the whole
    /// closure).
    pub fn from_times(name: &str, mut times: Vec<f64>) -> BenchStats {
        stats_from(name, &mut times)
    }

    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:>10} {:>10} {:>10} {:>10}",
            self.name,
            fmt_s(self.mean_s),
            fmt_s(self.p50_s),
            fmt_s(self.p95_s),
            fmt_s(self.min_s),
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<38} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p95", "min"
    )
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Time `f` for `iters` measured runs after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, &mut times)
}

/// Time `f` repeatedly until `budget_s` elapses (at least 3 runs).
pub fn bench_for<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchStats {
    f(); // warmup
    let start = Instant::now();
    let mut times = Vec::new();
    while start.elapsed().as_secs_f64() < budget_s || times.len() < 3 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    stats_from(name, &mut times)
}

fn stats_from(name: &str, times: &mut [f64]) -> BenchStats {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: times.iter().sum::<f64>() / n as f64,
        p50_s: times[n / 2],
        p95_s: times[(n as f64 * 0.95) as usize % n.max(1)],
        min_s: times[0],
        max_s: times[n - 1],
    }
}

/// Simple aligned-column table printer for experiment outputs.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 2, 20, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 20);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.max_s);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("demo") && r.contains("bb"));
    }
}
