//! Tiny CLI argument parser (offline stand-in for clap).
//!
//! Grammar: `singlequant <subcommand> [--key value]... [--flag]...`
//! Unknown keys are an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `flag_names` lists the valueless switches.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if flag_names.contains(&key) {
                    out.flags.push(key.to_string());
                    i += 1;
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("--{key} expects a value"))?;
                    out.options.insert(key.to_string(), val.clone());
                    i += 2;
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
                i += 1;
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&v(&["quantize", "--model", "sq-m", "--verbose"]),
                            &["verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.get("model"), Some("sq-m"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["x", "--k"]), &[]).is_err());
    }

    #[test]
    fn numeric_helpers() {
        let a = Args::parse(&v(&["b", "--n", "12", "--r", "0.5"]), &[]).unwrap();
        assert_eq!(a.usize_or("n", 1).unwrap(), 12);
        assert_eq!(a.f64_or("r", 1.0).unwrap(), 0.5);
        assert_eq!(a.usize_or("absent", 3).unwrap(), 3);
    }
}
