//! Property-testing harness (offline stand-in for proptest).
//!
//! `forall` drives a generator through N seeded cases and reports the
//! first failing seed so a failure is reproducible with
//! `check_seed(failing_seed, ...)`. No shrinking — generators are kept
//! small and structured instead.

use crate::util::rng::Rng;

/// Run `prop(gen(rng))` for `cases` seeded inputs; panics with the seed and
/// message on the first failure.
pub fn forall<T, G, P>(name: &str, cases: usize, base_seed: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two slices match elementwise within `tol`.
pub fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert a scalar predicate with a formatted message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall("sum-commutes", 50, 1, |r| (r.f32(), r.f32()), |(a, b)| {
            ensure((a + b - (b + a)).abs() < 1e-9, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn forall_reports_failure() {
        forall("always-fails", 3, 1, |r| r.f32(), |_| Err("always-fails".into()));
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.5], 0.1).is_err());
        assert!(close(&[1.0, 2.0], &[1.0, 2.05], 0.1).is_ok());
    }
}
