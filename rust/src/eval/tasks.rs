//! Multiple-choice accuracy via LM log-likelihood ranking (lm-eval
//! semantics): each option continuation is appended to the context, the
//! summed option-token log-likelihood picks the prediction.

use anyhow::Result;

use super::ppl::token_nll;
use super::{McItem, MmluSuite, Scorer, TaskSuite};
use crate::coordinator::tokenizer::encode;

/// Accuracy on one item set. `max_items` trims for cheap sweeps.
pub fn mc_accuracy<S: Scorer>(
    runner: &S,
    items: &[McItem],
    max_items: usize,
    shot_prefix: Option<&str>,
) -> Result<f64> {
    let items = &items[..items.len().min(max_items)];
    if items.is_empty() {
        return Ok(0.0);
    }
    // Build all (context+option) sequences, remembering option spans.
    let mut seqs: Vec<Vec<u16>> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::new(); // (ctx_len, opt_len)
    let cap = runner.max_score_len();
    for it in items {
        let ctx_text = match shot_prefix {
            Some(p) => format!("{p}{}", it.context),
            None => it.context.clone(),
        };
        let ctx = encode(&ctx_text);
        for opt in &it.options {
            let opt_toks = encode(opt);
            let mut seq = ctx.clone();
            seq.extend(&opt_toks);
            // left-truncate (keep the tail: question + option) if too long
            let (mut ctx_len, opt_len) = (ctx.len(), opt_toks.len());
            if seq.len() > cap {
                let drop = seq.len() - cap;
                seq.drain(..drop);
                ctx_len = ctx_len.saturating_sub(drop);
            }
            spans.push((ctx_len, opt_len));
            seqs.push(seq);
        }
    }
    let logits = runner.score_many(&seqs)?;
    let mut correct = 0usize;
    let mut cursor = 0usize;
    for it in items {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (oi, _) in it.options.iter().enumerate() {
            let lg = &logits[cursor];
            let (ctx_len, opt_len) = spans[cursor];
            let seq = &seqs[cursor];
            // log-likelihood of option tokens given the context: token at
            // position p is predicted by logits at p-1.
            let mut ll = 0.0f64;
            for p in ctx_len..ctx_len + opt_len {
                ll -= token_nll(lg.row(p - 1), seq[p] as usize);
            }
            if ll > best.1 {
                best = (oi, ll);
            }
            cursor += 1;
        }
        if best.0 == it.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Per-task and average accuracy on the six zero-shot suites.
pub fn zero_shot_suite<S: Scorer>(
    runner: &S,
    suite: &TaskSuite,
    max_items: usize,
) -> Result<(Vec<(String, f64)>, f64)> {
    let mut per = Vec::new();
    let mut sum = 0.0;
    for (name, items) in &suite.tasks {
        let acc = mc_accuracy(runner, items, max_items, None)?;
        sum += acc;
        per.push((name.clone(), acc));
    }
    let avg = sum / suite.tasks.len() as f64;
    Ok((per, avg))
}

/// Per-domain and average accuracy on the MMLU-like suite.
pub fn mmlu_suite<S: Scorer>(
    runner: &S,
    suite: &MmluSuite,
    max_items: usize,
    five_shot: bool,
) -> Result<(Vec<(String, f64)>, f64)> {
    let mut per = Vec::new();
    let mut sum = 0.0;
    for (name, items) in &suite.domains {
        let prefix = if five_shot {
            suite.shots.get(name).map(|s| s.as_str())
        } else {
            None
        };
        let acc = mc_accuracy(runner, items, max_items, prefix)?;
        sum += acc;
        per.push((name.clone(), acc));
    }
    let avg = sum / suite.domains.len() as f64;
    Ok((per, avg))
}
