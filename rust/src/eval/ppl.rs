//! Perplexity over a token corpus, scored through any [`Scorer`] — the
//! PJRT graphs or the native CPU backend (the Table 1/4/5/6/B.3 metric).

use anyhow::Result;

use super::Scorer;
use crate::tensor::Tensor;

/// exp(mean NLL) over non-overlapping windows of `window` tokens, up to
/// `max_windows` windows.
pub fn perplexity<S: Scorer>(
    runner: &S,
    corpus: &[u16],
    window: usize,
    max_windows: usize,
) -> Result<f64> {
    let n_windows = (corpus.len() / window).min(max_windows).max(1);
    let seqs: Vec<Vec<u16>> = (0..n_windows)
        .map(|i| corpus[i * window..(i + 1) * window].to_vec())
        .collect();
    let logits = runner.score_many(&seqs)?;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (seq, lg) in seqs.iter().zip(&logits) {
        total += window_nll(lg, seq);
        count += seq.len() - 1;
    }
    Ok((total / count as f64).exp())
}

/// Summed next-token NLL of one window given its logits.
pub fn window_nll(logits: &Tensor, tokens: &[u16]) -> f64 {
    let mut total = 0.0f64;
    for i in 0..tokens.len() - 1 {
        total += token_nll(logits.row(i), tokens[i + 1] as usize);
    }
    total
}

#[inline]
pub fn token_nll(row: &[f32], target: usize) -> f64 {
    let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = maxv as f64
        + (row.iter().map(|&v| ((v - maxv) as f64).exp()).sum::<f64>()).ln();
    lse - row[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_nll_uniform() {
        let row = vec![0.0f32; 10];
        let nll = token_nll(&row, 3);
        assert!((nll - (10f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn token_nll_confident() {
        let mut row = vec![0.0f32; 10];
        row[3] = 20.0;
        assert!(token_nll(&row, 3) < 1e-3);
        assert!(token_nll(&row, 4) > 10.0);
    }
}
