//! Evaluation harness: perplexity, zero-shot multiple-choice tasks, and
//! the MMLU-style few-shot suite — the measurement surface behind
//! Tables 1–6 and B.1/B.3. Scoring semantics follow lm-eval-harness:
//! multiple-choice answers are ranked by summed log-likelihood of the
//! option continuation given the context.

pub mod ppl;
pub mod tasks;

use anyhow::Result;

use crate::model::NativeModel;
use crate::runtime::ModelRunner;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Anything that can score token sequences into per-position logits — the
/// abstraction `perplexity` and the task suites run over. Implemented by
/// the PJRT [`ModelRunner`] (AOT graphs) and the pure-CPU [`NativeModel`]
/// (packed-weight kernels), so every eval runs on either backend.
pub trait Scorer {
    /// Per-sequence `[len, V]` logits (len clipped to `max_score_len`).
    fn score_many(&self, seqs: &[Vec<u16>]) -> Result<Vec<Tensor>>;
    /// Longest sequence this scorer can handle.
    fn max_score_len(&self) -> usize;
}

impl Scorer for ModelRunner {
    fn score_many(&self, seqs: &[Vec<u16>]) -> Result<Vec<Tensor>> {
        ModelRunner::score_many(self, seqs)
    }

    fn max_score_len(&self) -> usize {
        ModelRunner::max_score_len(self)
    }
}

impl Scorer for NativeModel {
    fn score_many(&self, seqs: &[Vec<u16>]) -> Result<Vec<Tensor>> {
        seqs.iter()
            .map(|s| {
                let len = s.len().min(self.cfg.max_seq);
                self.forward_full(&s[..len])
            })
            .collect()
    }

    fn max_score_len(&self) -> usize {
        self.cfg.max_seq
    }
}

impl<T: Scorer + ?Sized> Scorer for std::sync::Arc<T> {
    fn score_many(&self, seqs: &[Vec<u16>]) -> Result<Vec<Tensor>> {
        (**self).score_many(seqs)
    }

    fn max_score_len(&self) -> usize {
        (**self).max_score_len()
    }
}

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: String,
    pub options: Vec<String>,
    pub answer: usize,
}

fn parse_items(arr: &Json) -> Result<Vec<McItem>> {
    arr.as_arr()?
        .iter()
        .map(|it| {
            Ok(McItem {
                context: it.str_at("context")?.to_string(),
                options: it
                    .get("options")?
                    .as_arr()?
                    .iter()
                    .map(|o| Ok(o.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                answer: it.usize_at("answer")?,
            })
        })
        .collect()
}

/// The six zero-shot suites from `tasks.json`.
pub struct TaskSuite {
    pub tasks: Vec<(String, Vec<McItem>)>,
}

pub const TASK_ORDER: [&str; 6] = [
    "facts_hard",   // ARC-C-like
    "facts_easy",   // ARC-E-like
    "continuation", // HellaSwag-like
    "lastword",     // LAMBADA-like
    "procedure",    // PIQA-like
    "pronoun",      // WinoGrande-like
];

impl TaskSuite {
    pub fn load(path: &str) -> Result<TaskSuite> {
        let j = Json::parse_file(path)?;
        let tasks_obj = j.get("tasks")?;
        let mut tasks = Vec::new();
        for name in TASK_ORDER {
            let items = parse_items(tasks_obj.get(name)?)?;
            tasks.push((name.to_string(), items));
        }
        Ok(TaskSuite { tasks })
    }
}

/// The MMLU-like suite from `mmlu.json`.
pub struct MmluSuite {
    pub domains: Vec<(String, Vec<McItem>)>,
    pub shots: std::collections::BTreeMap<String, String>,
}

pub const MMLU_DOMAINS: [&str; 4] = ["stem", "hums", "social", "others"];

impl MmluSuite {
    pub fn load(path: &str) -> Result<MmluSuite> {
        let j = Json::parse_file(path)?;
        let doms = j.get("domains")?;
        let mut domains = Vec::new();
        for name in MMLU_DOMAINS {
            domains.push((name.to_string(), parse_items(doms.get(name)?)?));
        }
        let shots_json = j.get("shots")?.as_obj()?;
        let shots = shots_json
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<_>>()?;
        Ok(MmluSuite { domains, shots })
    }
}
