//! Evaluation harness: perplexity, zero-shot multiple-choice tasks, and
//! the MMLU-style few-shot suite — the measurement surface behind
//! Tables 1–6 and B.1/B.3. Scoring semantics follow lm-eval-harness:
//! multiple-choice answers are ranked by summed log-likelihood of the
//! option continuation given the context.

pub mod ppl;
pub mod tasks;

use anyhow::Result;

use crate::util::json::Json;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: String,
    pub options: Vec<String>,
    pub answer: usize,
}

fn parse_items(arr: &Json) -> Result<Vec<McItem>> {
    arr.as_arr()?
        .iter()
        .map(|it| {
            Ok(McItem {
                context: it.str_at("context")?.to_string(),
                options: it
                    .get("options")?
                    .as_arr()?
                    .iter()
                    .map(|o| Ok(o.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                answer: it.usize_at("answer")?,
            })
        })
        .collect()
}

/// The six zero-shot suites from `tasks.json`.
pub struct TaskSuite {
    pub tasks: Vec<(String, Vec<McItem>)>,
}

pub const TASK_ORDER: [&str; 6] = [
    "facts_hard",   // ARC-C-like
    "facts_easy",   // ARC-E-like
    "continuation", // HellaSwag-like
    "lastword",     // LAMBADA-like
    "procedure",    // PIQA-like
    "pronoun",      // WinoGrande-like
];

impl TaskSuite {
    pub fn load(path: &str) -> Result<TaskSuite> {
        let j = Json::parse_file(path)?;
        let tasks_obj = j.get("tasks")?;
        let mut tasks = Vec::new();
        for name in TASK_ORDER {
            let items = parse_items(tasks_obj.get(name)?)?;
            tasks.push((name.to_string(), items));
        }
        Ok(TaskSuite { tasks })
    }
}

/// The MMLU-like suite from `mmlu.json`.
pub struct MmluSuite {
    pub domains: Vec<(String, Vec<McItem>)>,
    pub shots: std::collections::BTreeMap<String, String>,
}

pub const MMLU_DOMAINS: [&str; 4] = ["stem", "hums", "social", "others"];

impl MmluSuite {
    pub fn load(path: &str) -> Result<MmluSuite> {
        let j = Json::parse_file(path)?;
        let doms = j.get("domains")?;
        let mut domains = Vec::new();
        for name in MMLU_DOMAINS {
            domains.push((name.to_string(), parse_items(doms.get(name)?)?));
        }
        let shots_json = j.get("shots")?.as_obj()?;
        let shots = shots_json
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<_>>()?;
        Ok(MmluSuite { domains, shots })
    }
}
