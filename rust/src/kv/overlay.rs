//! Decode-wave KV overlay: buffer one step's new K/V rows privately on
//! top of a shared read-only base view.
//!
//! A slot-parallel decode wave wants every active slot computing at
//! once, but [`KvCache`] appends need `&mut` access — and the paged
//! variants all borrow one shared [`super::BlockPool`]. The overlay
//! splits the step in two: during the parallel phase each slot runs the
//! model against a [`WaveOverlay`] whose reads fall through to the
//! committed base (`&SlotKv` / [`super::PagedReader`], shared borrows)
//! while the step's fresh rows land in slot-private buffers; afterwards
//! [`WaveOverlay::into_rows`] drops the base borrow and the scheduler
//! commits each [`WaveRows`] serially. Reads and writes are therefore
//! exactly those of the serial slot walk — same rows, same order within
//! a slot — which is what makes wave results bit-equal to it.

use super::{KvCache, KvError, KvRows};

/// The rows a wave step buffered for one slot, detached from the base
/// borrow — plain owned data, safe to hold across the write-back phase.
pub struct WaveRows {
    base_pos: usize,
    appended: usize,
    d: usize,
    /// `k[layer]` / `v[layer]`: `appended` rows of `d` floats each.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl WaveRows {
    /// Positions this step appended beyond the base.
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// Serially replay the buffered rows into the real cache. Propagates
    /// the cache's own `reserve` result — a no-op when the wave
    /// scheduler pre-reserved (the batcher path), a real allocation for
    /// direct callers.
    pub fn commit<K: KvCache>(&self, kv: &mut K) -> Result<(), KvError> {
        debug_assert_eq!(kv.pos(), self.base_pos, "commit to a moved cache");
        kv.reserve(self.appended)?;
        for layer in 0..self.k.len() {
            for off in 0..self.appended {
                let (a, b) = (off * self.d, (off + 1) * self.d);
                kv.append_row(layer, self.base_pos + off, &self.k[layer][a..b],
                              &self.v[layer][a..b]);
            }
        }
        kv.advance(self.appended);
        Ok(())
    }
}

/// A [`KvCache`] whose reads below `base_pos` come from a shared base
/// view and whose appends collect in private buffers (see module docs).
pub struct WaveOverlay<B> {
    base: B,
    rows: WaveRows,
}

impl<B: KvRows> WaveOverlay<B> {
    /// `base_pos` must be the base view's committed position count —
    /// the overlay cannot ask a bare [`KvRows`] for it.
    pub fn new(base: B, base_pos: usize, n_layers: usize, d_model: usize) -> WaveOverlay<B> {
        WaveOverlay {
            base,
            rows: WaveRows {
                base_pos,
                appended: 0,
                d: d_model,
                k: (0..n_layers).map(|_| Vec::new()).collect(),
                v: (0..n_layers).map(|_| Vec::new()).collect(),
            },
        }
    }

    /// Release the base borrow, keeping only the buffered rows.
    pub fn into_rows(self) -> WaveRows {
        self.rows
    }
}

impl<B: KvRows> KvRows for WaveOverlay<B> {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        if pos < self.rows.base_pos {
            self.base.rows(layer, pos)
        } else {
            let off = pos - self.rows.base_pos;
            let (a, b) = (off * self.rows.d, (off + 1) * self.rows.d);
            (&self.rows.k[layer][a..b], &self.rows.v[layer][a..b])
        }
    }
}

impl<B: KvRows> KvCache for WaveOverlay<B> {
    fn pos(&self) -> usize {
        self.rows.base_pos + self.rows.appended
    }

    /// Always succeeds: the overlay's buffers grow on demand, and real
    /// capacity is the wave scheduler's job — it must reserve in the
    /// underlying cache *before* the parallel phase (all-or-nothing, so
    /// a failed wave leaves every slot replayable).
    fn reserve(&mut self, _extra: usize) -> Result<(), KvError> {
        Ok(())
    }

    fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let off = pos - self.rows.base_pos;
        debug_assert_eq!(off * self.rows.d, self.rows.k[layer].len(),
                         "non-sequential overlay append");
        self.rows.k[layer].extend_from_slice(k);
        self.rows.v[layer].extend_from_slice(v);
    }

    fn advance(&mut self, n: usize) {
        self.rows.appended += n;
    }

    /// Drop buffered rows beyond `n`. The base view is shared and
    /// immutable here, so `n` must not reach below `base_pos` — a wave
    /// scheduler rolls the base back separately (after commit, through
    /// the real cache's own `truncate`).
    fn truncate(&mut self, n: usize) {
        debug_assert!(n >= self.rows.base_pos, "overlay truncate below its base");
        debug_assert!(n <= self.pos(), "truncate beyond committed positions");
        let keep = n - self.rows.base_pos;
        for side in self.rows.k.iter_mut().chain(self.rows.v.iter_mut()) {
            side.truncate(keep * self.rows.d);
        }
        self.rows.appended = keep;
    }
}

#[cfg(test)]
mod tests {
    use super::super::SlotKv;
    use super::*;

    fn filled_base(layers: usize, d: usize, n: usize) -> SlotKv {
        let mut kv = SlotKv::new(layers, d);
        kv.reserve(n).unwrap();
        for pos in 0..n {
            for layer in 0..layers {
                let k = vec![(pos * 10 + layer) as f32; d];
                let v = vec![(pos * 10 + layer) as f32 + 0.5; d];
                kv.append_row(layer, pos, &k, &v);
            }
        }
        kv.advance(n);
        kv
    }

    #[test]
    fn overlay_reads_base_below_and_buffer_at_new_positions() {
        let (layers, d, n) = (2usize, 3usize, 4usize);
        let base = filled_base(layers, d, n);
        let mut ov = WaveOverlay::new(&base, n, layers, d);
        assert_eq!(ov.pos(), n);
        ov.reserve(1).unwrap();
        for layer in 0..layers {
            ov.append_row(layer, n, &vec![9.0; d], &vec![9.5; d]);
        }
        ov.advance(1);
        assert_eq!(ov.pos(), n + 1);
        // old positions come from the base
        let (k, _) = ov.rows(1, 2);
        assert!(k.iter().all(|&x| x == 21.0));
        // the new position comes from the buffer
        let (k, v) = ov.rows(0, n);
        assert!(k.iter().all(|&x| x == 9.0));
        assert!(v.iter().all(|&x| x == 9.5));
    }

    #[test]
    fn commit_replays_into_the_real_cache() {
        let (layers, d, n) = (2usize, 3usize, 4usize);
        let mut kv = filled_base(layers, d, n);
        let rows = {
            let mut ov = WaveOverlay::new(&kv, n, layers, d);
            for layer in 0..layers {
                ov.append_row(layer, n, &vec![7.0; d], &vec![7.5; d]);
            }
            ov.advance(1);
            ov.into_rows()
        };
        assert_eq!(rows.appended(), 1);
        rows.commit(&mut kv).unwrap();
        assert_eq!(kv.pos, n + 1);
        let (k, v) = kv.rows(1, n);
        assert!(k.iter().all(|&x| x == 7.0));
        assert!(v.iter().all(|&x| x == 7.5));
    }

    #[test]
    fn truncate_drops_buffered_suffix_only() {
        let (layers, d, n) = (2usize, 3usize, 4usize);
        let base = filled_base(layers, d, n);
        let mut ov = WaveOverlay::new(&base, n, layers, d);
        for step in 0..3 {
            for layer in 0..layers {
                let val = 50.0 + step as f32;
                ov.append_row(layer, n + step, &vec![val; d], &vec![val + 0.5; d]);
            }
            ov.advance(1);
        }
        assert_eq!(ov.pos(), n + 3);
        ov.truncate(n + 1);
        assert_eq!(ov.pos(), n + 1);
        // the surviving buffered row and the base both still read back
        let (k, _) = ov.rows(0, n);
        assert!(k.iter().all(|&x| x == 50.0));
        let (k, _) = ov.rows(1, 1);
        assert!(k.iter().all(|&x| x == 11.0));
        // truncate to the base boundary empties the buffer; commit is a no-op
        ov.truncate(n);
        let rows = ov.into_rows();
        assert_eq!(rows.appended(), 0);
        let mut kv = filled_base(layers, d, n);
        rows.commit(&mut kv).unwrap();
        assert_eq!(kv.pos, n);
    }
}
