//! The contiguous per-slot cache: one growable `Vec<f32>` per layer per
//! side. This is the original `SlotKv` layout — kept as the default for
//! single-request tools (eval, generate, bench) and as the bit-exact
//! reference the paged view is property-tested against.

use super::{KvCache, KvError, KvRows};

/// Per-slot KV cache: post-RoPE K/V rows per layer, appended as
/// positions fill. Grows lazily to at most `max_seq · d_model` floats
/// per side per layer; `reset` keeps the allocation for the slot's next
/// request.
pub struct SlotKv {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Number of cached positions (== rows per layer).
    pub pos: usize,
    /// Row width (`d_model`).
    d: usize,
}

impl SlotKv {
    pub fn new(n_layers: usize, d_model: usize) -> SlotKv {
        SlotKv {
            k: (0..n_layers).map(|_| Vec::new()).collect(),
            v: (0..n_layers).map(|_| Vec::new()).collect(),
            pos: 0,
            d: d_model,
        }
    }

    /// Drop the cached sequence (retire/reuse); capacity is kept.
    pub fn reset(&mut self) {
        for side in self.k.iter_mut().chain(self.v.iter_mut()) {
            side.clear();
        }
        self.pos = 0;
    }

    /// Resident bytes currently held by this slot's cache.
    pub fn nbytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|s| s.len() * 4).sum::<usize>()
    }
}

impl KvRows for SlotKv {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (a, b) = (pos * self.d, (pos + 1) * self.d);
        (&self.k[layer][a..b], &self.v[layer][a..b])
    }
}

impl KvCache for SlotKv {
    fn pos(&self) -> usize {
        self.pos
    }

    fn reserve(&mut self, _extra: usize) -> Result<(), KvError> {
        Ok(()) // contiguous slots grow on demand; max_seq is checked upstream
    }

    fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(pos * self.d, self.k[layer].len(), "non-sequential append");
        debug_assert_eq!(k.len(), self.d);
        self.k[layer].extend_from_slice(k);
        self.v[layer].extend_from_slice(v);
    }

    fn advance(&mut self, n: usize) {
        self.pos += n;
    }

    fn truncate(&mut self, n: usize) {
        debug_assert!(n <= self.pos, "truncate beyond committed positions");
        for side in self.k.iter_mut().chain(self.v.iter_mut()) {
            side.truncate(n * self.d);
        }
        self.pos = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_read_reset_cycle() {
        let d = 4;
        let mut kv = SlotKv::new(2, d);
        kv.reserve(3).unwrap();
        for pos in 0..3 {
            for layer in 0..2 {
                kv.append_row(layer, pos, &vec![pos as f32; d], &vec![-(pos as f32); d]);
            }
        }
        kv.advance(3);
        assert_eq!(kv.pos, 3);
        let (k, v) = kv.rows(1, 2);
        assert!(k.iter().all(|&x| x == 2.0));
        assert!(v.iter().all(|&x| x == -2.0));
        assert_eq!(kv.nbytes(), 2 * 2 * 3 * d * 4);
        kv.reset();
        assert_eq!(kv.pos, 0);
        assert_eq!(kv.nbytes(), 0);
    }

    #[test]
    fn truncate_drops_suffix_and_appends_resume() {
        let d = 4;
        let mut kv = SlotKv::new(2, d);
        for pos in 0..5 {
            for layer in 0..2 {
                kv.append_row(layer, pos, &vec![pos as f32; d], &vec![-(pos as f32); d]);
            }
        }
        kv.advance(5);
        kv.truncate(2);
        assert_eq!(kv.pos, 2);
        assert_eq!(kv.nbytes(), 2 * 2 * 2 * d * 4, "suffix storage freed");
        let (k, _) = kv.rows(0, 1);
        assert!(k.iter().all(|&x| x == 1.0), "prefix survives truncate");
        // appends resume at the truncation point with different data
        for layer in 0..2 {
            kv.append_row(layer, 2, &vec![9.0; d], &vec![9.5; d]);
        }
        kv.advance(1);
        let (k, v) = kv.rows(1, 2);
        assert!(k.iter().all(|&x| x == 9.0));
        assert!(v.iter().all(|&x| x == 9.5));
        // truncate to the current position is a no-op
        kv.truncate(3);
        assert_eq!(kv.pos, 3);
    }
}
