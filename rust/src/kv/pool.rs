//! The shared page pool: one flat f32 arena split into fixed-size pages
//! plus a stack free list, so alloc and free are O(1) pushes/pops.
//!
//! One page holds `page_tokens` positions of *every* layer's K and V
//! rows — a request's whole transformer state for a token span lives in
//! one page, so a slot's page table is a single `Vec<u32>` indexed by
//! `pos / page_tokens` regardless of layer count. Within a page the
//! layout is `[layer][side][token][d_model]` (side 0 = K, 1 = V).

use super::KvError;

pub struct BlockPool {
    page_tokens: usize,
    n_layers: usize,
    d_model: usize,
    /// Floats per page: `2 · n_layers · page_tokens · d_model`.
    page_floats: usize,
    storage: Vec<f32>,
    /// Free page indices; top of the stack is handed out next.
    free: Vec<u32>,
    pages: usize,
    /// Pages currently handed out; `free.len() + outstanding == pages`
    /// is the conservation law `audit_conservation` re-checks after
    /// every wave, retire, truncate, and preemption.
    #[cfg(feature = "audit")]
    outstanding: usize,
}

impl BlockPool {
    /// A pool of `pages` pages sized for a model with `n_layers` layers
    /// of `d_model`-wide K/V rows, `page_tokens` positions per page.
    pub fn new(n_layers: usize, d_model: usize, page_tokens: usize, pages: usize) -> BlockPool {
        assert!(page_tokens > 0, "page_tokens must be positive");
        assert!(n_layers > 0 && d_model > 0, "degenerate model shape");
        let page_floats = 2 * n_layers * page_tokens * d_model;
        BlockPool {
            page_tokens,
            n_layers,
            d_model,
            page_floats,
            storage: vec![0.0; page_floats * pages],
            // reversed so page 0 is handed out first (cosmetic, but it
            // makes pool traces easy to read)
            free: (0..pages as u32).rev().collect(),
            pages,
            #[cfg(feature = "audit")]
            outstanding: 0,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn pages_total(&self) -> usize {
        self.pages
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_used(&self) -> usize {
        self.pages - self.free.len()
    }

    /// Bytes of one page (K+V across all layers).
    pub fn page_nbytes(&self) -> usize {
        self.page_floats * 4
    }

    /// Bytes of the whole arena (allocated up front).
    pub fn nbytes(&self) -> usize {
        self.storage.len() * 4
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Pop `n` pages off the free list, all-or-nothing: on exhaustion
    /// nothing is allocated, so callers can requeue/preempt and retry.
    pub(super) fn alloc(&mut self, n: usize, out: &mut Vec<u32>) -> Result<(), KvError> {
        if self.free.len() < n {
            return Err(KvError::PoolExhausted { needed: n, free: self.free.len() });
        }
        // Same hand-out order as n pops off the top of the stack, but
        // with no panicking path: drain the tail and reverse it.
        let start = self.free.len() - n;
        out.extend(self.free.drain(start..).rev());
        #[cfg(feature = "audit")]
        {
            self.outstanding += n;
        }
        Ok(())
    }

    /// Return a page to the free list.
    pub(super) fn release(&mut self, page: u32) {
        debug_assert!((page as usize) < self.pages, "release of foreign page");
        debug_assert!(!self.free.contains(&page), "double free of page {page}");
        self.free.push(page);
        #[cfg(feature = "audit")]
        {
            assert!(self.outstanding > 0, "audit: release with no outstanding pages");
            self.outstanding -= 1;
        }
    }

    /// Conservation auditor (audit builds only): every page is either
    /// free or outstanding, the free list holds no duplicates, and no
    /// entry points outside the arena. Called by the runtime after
    /// every decode/spec wave, retire, truncate, and preemption.
    #[cfg(feature = "audit")]
    pub fn audit_conservation(&self) {
        assert_eq!(
            self.free.len() + self.outstanding,
            self.pages,
            "audit: page conservation violated (free {} + outstanding {} != total {})",
            self.free.len(),
            self.outstanding,
            self.pages
        );
        let mut seen = vec![false; self.pages];
        for &p in &self.free {
            let p = p as usize;
            assert!(p < self.pages, "audit: free list holds foreign page {p}");
            assert!(!seen[p], "audit: free list holds page {p} twice");
            seen[p] = true;
        }
    }

    #[inline]
    fn offset(&self, page: u32, layer: usize, side: usize, idx: usize) -> usize {
        debug_assert!(layer < self.n_layers && side < 2 && idx < self.page_tokens);
        page as usize * self.page_floats
            + ((layer * 2 + side) * self.page_tokens + idx) * self.d_model
    }

    /// The `d_model`-float row at (`layer`, side, token-in-page).
    #[inline]
    pub(super) fn row(&self, page: u32, layer: usize, side: usize, idx: usize) -> &[f32] {
        let o = self.offset(page, layer, side, idx);
        &self.storage[o..o + self.d_model]
    }

    #[inline]
    pub(super) fn row_mut(
        &mut self,
        page: u32,
        layer: usize,
        side: usize,
        idx: usize,
    ) -> &mut [f32] {
        let o = self.offset(page, layer, side, idx);
        &mut self.storage[o..o + self.d_model]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip_is_exact() {
        let mut pool = BlockPool::new(2, 8, 4, 3);
        assert_eq!(pool.pages_total(), 3);
        assert_eq!(pool.pages_free(), 3);
        let mut pages = Vec::new();
        pool.alloc(2, &mut pages).unwrap();
        assert_eq!(pages.len(), 2);
        assert_eq!(pool.pages_used(), 2);
        // exhaustion is all-or-nothing: asking for 2 with 1 free
        // allocates nothing
        let mut more = Vec::new();
        let err = pool.alloc(2, &mut more).unwrap_err();
        assert_eq!(err, KvError::PoolExhausted { needed: 2, free: 1 });
        assert!(more.is_empty());
        assert_eq!(pool.pages_free(), 1);
        for p in pages {
            pool.release(p);
        }
        assert_eq!(pool.pages_free(), 3);
    }

    #[test]
    fn page_rows_are_disjoint_per_layer_side_and_token() {
        let (layers, d, pt) = (2, 4, 3);
        let mut pool = BlockPool::new(layers, d, pt, 2);
        let mut pages = Vec::new();
        pool.alloc(2, &mut pages).unwrap();
        // stamp every row with a unique value, then read all back
        let mut stamp = 1.0f32;
        for &pg in &pages {
            for layer in 0..layers {
                for side in 0..2 {
                    for idx in 0..pt {
                        pool.row_mut(pg, layer, side, idx).fill(stamp);
                        stamp += 1.0;
                    }
                }
            }
        }
        let mut expect = 1.0f32;
        for &pg in &pages {
            for layer in 0..layers {
                for side in 0..2 {
                    for idx in 0..pt {
                        assert!(pool.row(pg, layer, side, idx).iter().all(|&v| v == expect));
                        expect += 1.0;
                    }
                }
            }
        }
    }

    #[test]
    fn sizing_helpers() {
        let pool = BlockPool::new(3, 16, 8, 5);
        assert_eq!(pool.pages_for(0), 0);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(8), 1);
        assert_eq!(pool.pages_for(9), 2);
        assert_eq!(pool.page_nbytes(), 2 * 3 * 8 * 16 * 4);
        assert_eq!(pool.nbytes(), pool.page_nbytes() * 5);
    }
}
