//! Per-slot page tables and the paged cache view the model executes
//! against.
//!
//! A [`PageTable`] is just the slot's ordered list of pool pages plus
//! the committed position count: position `p` lives in
//! `pages[p / page_tokens]` at in-page index `p % page_tokens`. The
//! table owns no storage — pages go back to the pool on `release`
//! (retire/preempt), making eviction O(pages).
//!
//! [`PagedSlot`] borrows the pool and one table for the duration of a
//! prefill/decode call and implements [`KvCache`] over them; the model
//! never sees pages, only `rows(layer, pos)`.

use super::pool::BlockPool;
use super::{KvCache, KvError, KvRows};

/// One slot's page list + committed length. Default state holds no
/// pages and zero positions.
#[derive(Default)]
pub struct PageTable {
    pages: Vec<u32>,
    pos: usize,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Positions the held pages can store.
    pub fn capacity(&self, pool: &BlockPool) -> usize {
        self.pages.len() * pool.page_tokens()
    }

    /// Grow the page list (all-or-nothing) so `pos + extra` positions
    /// fit. Idempotent: already-held pages are never re-allocated.
    pub fn reserve(&mut self, pool: &mut BlockPool, extra: usize) -> Result<(), KvError> {
        let needed = pool.pages_for(self.pos + extra);
        if needed > self.pages.len() {
            pool.alloc(needed - self.pages.len(), &mut self.pages)?;
        }
        Ok(())
    }

    /// Return every page to the pool and forget the sequence.
    pub fn release(&mut self, pool: &mut BlockPool) {
        for page in self.pages.drain(..) {
            pool.release(page);
        }
        self.pos = 0;
    }

    /// Roll back to `n` committed positions (`n <= pos`): pages past the
    /// one holding position `n - 1` go straight back to the pool — the
    /// speculative-decode rollback is a free-list push, no copying. Rows
    /// inside the kept pages are not cleared; they are overwritten when
    /// the positions are appended again.
    pub fn truncate(&mut self, pool: &mut BlockPool, n: usize) {
        debug_assert!(n <= self.pos, "truncate beyond committed positions");
        let keep = pool.pages_for(n);
        for page in self.pages.drain(keep..) {
            pool.release(page);
        }
        self.pos = n;
    }

    /// (page, in-page index) holding position `pos`.
    #[inline]
    fn locate(&self, page_tokens: usize, pos: usize) -> (u32, usize) {
        let page = *self
            .pages
            .get(pos / page_tokens)
            // Internal invariant, never request-shaped input: callers
            // reserve before touching a position, so a miss is a code
            // bug in this module and aborting is correct.
            // sqlint: allow(hotpath) — invariant violation is a code bug
            .expect("kv position outside reserved pages");
        (page, pos % page_tokens)
    }
}

/// Borrowed (pool, table) pair implementing the cache interface for one
/// model call.
pub struct PagedSlot<'a> {
    pub pool: &'a mut BlockPool,
    pub table: &'a mut PageTable,
}

impl<'a> PagedSlot<'a> {
    #[inline]
    fn locate(&self, pos: usize) -> (u32, usize) {
        self.table.locate(self.pool.page_tokens(), pos)
    }
}

/// Read-only view of one slot's paged cache: shared borrows only, so a
/// decode wave can hold one per active slot simultaneously while the
/// pool stays untouched.
pub struct PagedReader<'a> {
    pub pool: &'a BlockPool,
    pub table: &'a PageTable,
}

impl KvRows for PagedReader<'_> {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (page, idx) = self.table.locate(self.pool.page_tokens(), pos);
        (self.pool.row(page, layer, 0, idx), self.pool.row(page, layer, 1, idx))
    }
}

impl KvRows for PagedSlot<'_> {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (page, idx) = self.locate(pos);
        (self.pool.row(page, layer, 0, idx), self.pool.row(page, layer, 1, idx))
    }
}

impl KvCache for PagedSlot<'_> {
    fn pos(&self) -> usize {
        self.table.pos
    }

    fn reserve(&mut self, extra: usize) -> Result<(), KvError> {
        self.table.reserve(self.pool, extra)
    }

    fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let (page, idx) = self.locate(pos);
        self.pool.row_mut(page, layer, 0, idx).copy_from_slice(k);
        self.pool.row_mut(page, layer, 1, idx).copy_from_slice(v);
    }

    fn advance(&mut self, n: usize) {
        self.table.pos += n;
        debug_assert!(
            self.table.pos <= self.table.capacity(self.pool),
            "advance past reserved capacity"
        );
    }

    fn truncate(&mut self, n: usize) {
        self.table.truncate(self.pool, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_is_idempotent_and_all_or_nothing() {
        let mut pool = BlockPool::new(1, 4, 4, 3);
        let mut table = PageTable::new();
        table.reserve(&mut pool, 5).unwrap(); // 2 pages
        assert_eq!(table.n_pages(), 2);
        table.reserve(&mut pool, 5).unwrap(); // no growth needed
        assert_eq!(table.n_pages(), 2);
        assert_eq!(pool.pages_free(), 1);
        // 13 positions would need 4 pages; only 1 more exists
        let err = table.reserve(&mut pool, 13).unwrap_err();
        assert_eq!(err, KvError::PoolExhausted { needed: 2, free: 1 });
        assert_eq!(table.n_pages(), 2, "failed reserve must not grow the table");
        table.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(table.pos(), 0);
    }

    #[test]
    fn rows_round_trip_across_page_boundaries() {
        let (layers, d, pt) = (2, 4, 3);
        let mut pool = BlockPool::new(layers, d, pt, 4);
        let mut table = PageTable::new();
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        let n = 8; // spans 3 pages of 3 tokens
        slot.reserve(n).unwrap();
        for pos in 0..n {
            for layer in 0..layers {
                let k = vec![(pos * 10 + layer) as f32; d];
                let v = vec![(pos * 10 + layer) as f32 + 0.5; d];
                slot.append_row(layer, pos, &k, &v);
            }
        }
        slot.advance(n);
        assert_eq!(slot.pos(), n);
        for pos in 0..n {
            for layer in 0..layers {
                let (k, v) = slot.rows(layer, pos);
                assert!(k.iter().all(|&x| x == (pos * 10 + layer) as f32));
                assert!(v.iter().all(|&x| x == (pos * 10 + layer) as f32 + 0.5));
            }
        }
        assert_eq!(table.n_pages(), 3);
    }

    /// Fill `n` positions with position-stamped rows through a fresh slot.
    fn fill(slot: &mut PagedSlot<'_>, layers: usize, d: usize, n: usize) {
        slot.reserve(n).unwrap();
        for pos in 0..n {
            for layer in 0..layers {
                let k = vec![(pos * 10 + layer) as f32; d];
                let v = vec![(pos * 10 + layer) as f32 + 0.5; d];
                slot.append_row(layer, pos, &k, &v);
            }
        }
        slot.advance(n);
    }

    /// Truncate across page sizes that split mid-page (1, 7) and on the
    /// boundary (16): the kept prefix reads back exactly, the freed pages
    /// are back in the pool, and no page leaks across rollback cycles —
    /// the speculative-rollback contract.
    #[test]
    fn truncate_frees_pages_and_keeps_prefix_across_page_sizes() {
        let (layers, d, total) = (2usize, 4usize, 20usize);
        for pt in [1usize, 7, 16] {
            let mut pool = BlockPool::new(layers, d, pt, total.div_ceil(pt));
            for keep in [13usize, 7, 0] {
                let mut table = PageTable::new();
                let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
                fill(&mut slot, layers, d, total);
                assert_eq!(pool.pages_free(), 0, "pt={pt}: pool sized exactly");
                let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
                slot.truncate(keep);
                assert_eq!(slot.pos(), keep, "pt={pt} keep={keep}");
                let want_pages = keep.div_ceil(pt);
                assert_eq!(table.n_pages(), want_pages, "pt={pt} keep={keep}");
                assert_eq!(
                    pool.pages_used(), want_pages,
                    "pt={pt} keep={keep}: freed pages must be back in the pool"
                );
                // the kept prefix is untouched
                let slot = PagedSlot { pool: &mut pool, table: &mut table };
                for pos in 0..keep {
                    for layer in 0..layers {
                        let (k, v) = slot.rows(layer, pos);
                        assert!(k.iter().all(|&x| x == (pos * 10 + layer) as f32),
                                "pt={pt} keep={keep} pos={pos}");
                        assert!(v.iter().all(|&x| x == (pos * 10 + layer) as f32 + 0.5));
                    }
                }
                table.release(&mut pool);
                assert_eq!(pool.pages_free(), pool.pages_total(),
                           "pt={pt} keep={keep}: leak");
            }
        }
    }

    /// Truncate exactly onto a page boundary: the boundary page itself is
    /// kept (it holds position `n - 1`) and only pages past it return.
    #[test]
    fn truncate_to_page_boundary_keeps_the_full_page() {
        let (layers, d, pt) = (1usize, 4usize, 4usize);
        let mut pool = BlockPool::new(layers, d, pt, 3);
        let mut table = PageTable::new();
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        fill(&mut slot, layers, d, 10); // 3 pages: 4 + 4 + 2
        slot.truncate(8); // exactly two full pages
        assert_eq!(slot.pos(), 8);
        assert_eq!(table.n_pages(), 2);
        assert_eq!(pool.pages_free(), 1);
        // truncate(pos) is a no-op
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        slot.truncate(8);
        assert_eq!(table.n_pages(), 2);
        assert_eq!(pool.pages_free(), 1);
    }

    /// Truncate-then-reserve must hand the freed pages straight back:
    /// pool accounting is exact through a rollback/regrow cycle and the
    /// regrown rows read back correctly.
    #[test]
    fn truncate_then_reserve_reuses_freed_pages_exactly() {
        let (layers, d, pt) = (2usize, 4usize, 7usize);
        let mut pool = BlockPool::new(layers, d, pt, 3); // 21 positions max
        let mut table = PageTable::new();
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        fill(&mut slot, layers, d, 20); // all 3 pages in use
        slot.truncate(5); // back to 1 page, 2 freed
        assert_eq!(pool.pages_free(), 2);
        // a burst of 9 beyond pos=5 needs pages for 14 positions = 2 pages
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        slot.reserve(9).unwrap();
        assert_eq!(table.n_pages(), 2);
        assert_eq!(pool.pages_free(), 1, "exactly one page of headroom left");
        for pos in 5..14 {
            for layer in 0..layers {
                slot.append_row(layer, pos, &vec![100.0 + pos as f32; d],
                                &vec![200.0 + pos as f32; d]);
            }
        }
        slot.advance(9);
        assert_eq!(slot.pos(), 14);
        for pos in 0..14 {
            let (k, _) = slot.rows(0, pos);
            let want = if pos < 5 { (pos * 10) as f32 } else { 100.0 + pos as f32 };
            assert!(k.iter().all(|&x| x == want), "pos {pos} after regrow");
        }
        table.release(&mut pool);
        assert_eq!(pool.pages_free(), 3, "no leak after the full cycle");
    }

    #[test]
    fn no_leak_after_churn() {
        let mut pool = BlockPool::new(2, 4, 2, 6);
        let mut tables: Vec<PageTable> = (0..3).map(|_| PageTable::new()).collect();
        for round in 0..10 {
            for (i, table) in tables.iter_mut().enumerate() {
                let want = 1 + (round + i) % 4;
                table.reserve(&mut pool, want).unwrap();
                table.pos += want.min(table.capacity(&pool) - table.pos);
            }
            for table in tables.iter_mut() {
                table.release(&mut pool);
            }
            assert_eq!(pool.pages_free(), pool.pages_total(), "round {round} leaked");
        }
    }
}
