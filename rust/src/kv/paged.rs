//! Per-slot page tables and the paged cache view the model executes
//! against.
//!
//! A [`PageTable`] is just the slot's ordered list of pool pages plus
//! the committed position count: position `p` lives in
//! `pages[p / page_tokens]` at in-page index `p % page_tokens`. The
//! table owns no storage — pages go back to the pool on `release`
//! (retire/preempt), making eviction O(pages).
//!
//! [`PagedSlot`] borrows the pool and one table for the duration of a
//! prefill/decode call and implements [`KvCache`] over them; the model
//! never sees pages, only `rows(layer, pos)`.

use super::pool::BlockPool;
use super::{KvCache, KvError, KvRows};

/// One slot's page list + committed length. Default state holds no
/// pages and zero positions.
#[derive(Default)]
pub struct PageTable {
    pages: Vec<u32>,
    pos: usize,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Positions the held pages can store.
    pub fn capacity(&self, pool: &BlockPool) -> usize {
        self.pages.len() * pool.page_tokens()
    }

    /// Grow the page list (all-or-nothing) so `pos + extra` positions
    /// fit. Idempotent: already-held pages are never re-allocated.
    pub fn reserve(&mut self, pool: &mut BlockPool, extra: usize) -> Result<(), KvError> {
        let needed = pool.pages_for(self.pos + extra);
        if needed > self.pages.len() {
            pool.alloc(needed - self.pages.len(), &mut self.pages)?;
        }
        Ok(())
    }

    /// Return every page to the pool and forget the sequence.
    pub fn release(&mut self, pool: &mut BlockPool) {
        for page in self.pages.drain(..) {
            pool.release(page);
        }
        self.pos = 0;
    }

    /// (page, in-page index) holding position `pos`.
    #[inline]
    fn locate(&self, page_tokens: usize, pos: usize) -> (u32, usize) {
        let page = *self
            .pages
            .get(pos / page_tokens)
            .expect("kv position outside reserved pages");
        (page, pos % page_tokens)
    }
}

/// Borrowed (pool, table) pair implementing the cache interface for one
/// model call.
pub struct PagedSlot<'a> {
    pub pool: &'a mut BlockPool,
    pub table: &'a mut PageTable,
}

impl<'a> PagedSlot<'a> {
    #[inline]
    fn locate(&self, pos: usize) -> (u32, usize) {
        self.table.locate(self.pool.page_tokens(), pos)
    }
}

/// Read-only view of one slot's paged cache: shared borrows only, so a
/// decode wave can hold one per active slot simultaneously while the
/// pool stays untouched.
pub struct PagedReader<'a> {
    pub pool: &'a BlockPool,
    pub table: &'a PageTable,
}

impl KvRows for PagedReader<'_> {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (page, idx) = self.table.locate(self.pool.page_tokens(), pos);
        (self.pool.row(page, layer, 0, idx), self.pool.row(page, layer, 1, idx))
    }
}

impl KvRows for PagedSlot<'_> {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let (page, idx) = self.locate(pos);
        (self.pool.row(page, layer, 0, idx), self.pool.row(page, layer, 1, idx))
    }
}

impl KvCache for PagedSlot<'_> {
    fn pos(&self) -> usize {
        self.table.pos
    }

    fn reserve(&mut self, extra: usize) -> Result<(), KvError> {
        self.table.reserve(self.pool, extra)
    }

    fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let (page, idx) = self.locate(pos);
        self.pool.row_mut(page, layer, 0, idx).copy_from_slice(k);
        self.pool.row_mut(page, layer, 1, idx).copy_from_slice(v);
    }

    fn advance(&mut self, n: usize) {
        self.table.pos += n;
        debug_assert!(
            self.table.pos <= self.table.capacity(self.pool),
            "advance past reserved capacity"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_is_idempotent_and_all_or_nothing() {
        let mut pool = BlockPool::new(1, 4, 4, 3);
        let mut table = PageTable::new();
        table.reserve(&mut pool, 5).unwrap(); // 2 pages
        assert_eq!(table.n_pages(), 2);
        table.reserve(&mut pool, 5).unwrap(); // no growth needed
        assert_eq!(table.n_pages(), 2);
        assert_eq!(pool.pages_free(), 1);
        // 13 positions would need 4 pages; only 1 more exists
        let err = table.reserve(&mut pool, 13).unwrap_err();
        assert_eq!(err, KvError::PoolExhausted { needed: 2, free: 1 });
        assert_eq!(table.n_pages(), 2, "failed reserve must not grow the table");
        table.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(table.pos(), 0);
    }

    #[test]
    fn rows_round_trip_across_page_boundaries() {
        let (layers, d, pt) = (2, 4, 3);
        let mut pool = BlockPool::new(layers, d, pt, 4);
        let mut table = PageTable::new();
        let mut slot = PagedSlot { pool: &mut pool, table: &mut table };
        let n = 8; // spans 3 pages of 3 tokens
        slot.reserve(n).unwrap();
        for pos in 0..n {
            for layer in 0..layers {
                let k = vec![(pos * 10 + layer) as f32; d];
                let v = vec![(pos * 10 + layer) as f32 + 0.5; d];
                slot.append_row(layer, pos, &k, &v);
            }
        }
        slot.advance(n);
        assert_eq!(slot.pos(), n);
        for pos in 0..n {
            for layer in 0..layers {
                let (k, v) = slot.rows(layer, pos);
                assert!(k.iter().all(|&x| x == (pos * 10 + layer) as f32));
                assert!(v.iter().all(|&x| x == (pos * 10 + layer) as f32 + 0.5));
            }
        }
        assert_eq!(table.n_pages(), 3);
    }

    #[test]
    fn no_leak_after_churn() {
        let mut pool = BlockPool::new(2, 4, 2, 6);
        let mut tables: Vec<PageTable> = (0..3).map(|_| PageTable::new()).collect();
        for round in 0..10 {
            for (i, table) in tables.iter_mut().enumerate() {
                let want = 1 + (round + i) % 4;
                table.reserve(&mut pool, want).unwrap();
                table.pos += want.min(table.capacity(&pool) - table.pos);
            }
            for table in tables.iter_mut() {
                table.release(&mut pool);
            }
            assert_eq!(pool.pages_free(), pool.pages_total(), "round {round} leaked");
        }
    }
}
