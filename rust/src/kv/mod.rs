//! Paged KV-cache subsystem: a fixed-size page pool, per-slot page
//! tables, and the cache traits the native execution engine reads and
//! writes through.
//!
//! The contiguous per-slot cache ([`SlotKv`]) sizes every slot for the
//! worst case (`max_seq` rows), so a batcher running `B` slots must
//! budget `B × max_seq` rows even though most requests finish far
//! shorter. The paged layout ([`BlockPool`] + [`PageTable`]) instead
//! hands out fixed-size pages — each holding `page_tokens` positions of
//! every layer's K and V rows — from one shared free list, so memory
//! follows the *actual* live token count and the batcher can safely
//! overcommit, falling back to preemption when the pool runs dry.
//!
//! Both implementations expose the same [`KvCache`] interface and
//! produce bit-identical reads: a cached row is the same `d_model` f32
//! slice whether it lives in a slot-owned `Vec` or inside a pool page,
//! and `model::layers::attention_step_kv` consumes rows position by
//! position in the same order either way. The property tests in
//! `model::native` pin this down across page sizes.

mod contig;
mod overlay;
mod paged;
mod pool;

pub use contig::SlotKv;
pub use overlay::{WaveOverlay, WaveRows};
pub use paged::{PageTable, PagedReader, PagedSlot};
pub use pool::BlockPool;

use std::error::Error;
use std::fmt;

/// KV allocation failure. Carried through `anyhow` so callers up the
/// stack (the serving backend, the batcher) can downcast and translate
/// pool pressure into admission control instead of an engine abort.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The free list cannot cover an allocation of `needed` more pages.
    PoolExhausted { needed: usize, free: usize },
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::PoolExhausted { needed, free } => write!(
                f,
                "kv pool exhausted: need {needed} page(s), {free} free"
            ),
        }
    }
}

impl Error for KvError {}

/// Read access to cached K/V rows. `rows(layer, pos)` returns the
/// post-RoPE K and V rows (each `d_model` floats) cached at `pos` —
/// the only lookup the attention read path needs.
pub trait KvRows {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]);
}

/// Shared references read straight through — a decode wave hands each
/// slot a `&SlotKv` (or a [`PagedReader`]) base view while the slots
/// compute in parallel.
impl<T: KvRows + ?Sized> KvRows for &T {
    fn rows(&self, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        (**self).rows(layer, pos)
    }
}

/// A per-request KV cache the step functions write into. `reserve`
/// must be called (and succeed) before `append_row` touches positions
/// beyond the current capacity; contiguous caches always succeed while
/// paged caches may report [`KvError::PoolExhausted`] — *before* any
/// state changes, so a failed reservation leaves the cache replayable.
pub trait KvCache: KvRows {
    /// Number of cached positions.
    fn pos(&self) -> usize;

    /// Ensure capacity for `extra` positions beyond `pos()`.
    fn reserve(&mut self, extra: usize) -> Result<(), KvError>;

    /// Write the K and V rows for `(layer, pos)`; `pos` must be inside
    /// the reserved capacity and `>= self.pos()` (rows are appended
    /// layer by layer before `advance` commits them).
    fn append_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Commit `n` appended positions: `pos()` grows by `n`.
    fn advance(&mut self, n: usize);

    /// Roll the cache back to exactly `n` committed positions
    /// (`n <= pos()`): rows at `n..` are dropped and paged caches return
    /// every page past the one holding position `n - 1` to the pool.
    /// This is the speculative-decode rollback primitive — a rejected
    /// draft suffix disappears without copying, and the slot is left in
    /// the same state as if only the accepted prefix had ever been
    /// decoded (pinned by the property tests in `model::native` and
    /// `spec`).
    fn truncate(&mut self, n: usize);
}
