//! `cargo bench --bench bench_quant_time` — Table 7/B.2: quantization
//! wall-clock per method per model, on the real trained checkpoints.
//! (criterion is unavailable offline; util::bench provides the harness.)

use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::Engine;
use singlequant::util::bench::{bench, header};
use singlequant::util::sqt::SqtFile;

fn main() {
    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("bench_quant_time: run `make artifacts` first");
        return;
    }
    let engine = Engine::new(&dir).expect("engine");
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
        .unwrap()
        .get("tokens")
        .unwrap()
        .as_u16()
        .unwrap()
        .to_vec();

    println!("{}", header());
    for model in ["sq-s", "sq-m", "sq-l", "sq-xl", "sq-moe"] {
        let cfg = engine.config(model).unwrap();
        let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt")).unwrap();
        for (label, method, iters) in [
            ("singlequant", Method::singlequant(), 5usize),
            ("duquant", Method::DuQuant { steps: 16 }, 3),
            ("spinquant-100", Method::SpinQuant { steps: 100 }, 1),
            ("flatquant-60", Method::FlatQuant { steps: 60 }, 1),
        ] {
            let opts = PipelineOptions { method: method.clone(), ..Default::default() };
            let stats = bench(&format!("{model}/{label}"), 0, iters, || {
                let qm = quantize(&cfg, &weights, &calib, &opts).unwrap();
                std::hint::black_box(qm.rots.len());
            });
            println!("{}", stats.row());
        }
    }
}
