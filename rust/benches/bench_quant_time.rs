//! `cargo bench --bench bench_quant_time` — Table 7/B.2: quantization
//! wall-clock per method, plus the serial-vs-parallel pipeline sweep.
//! (criterion is unavailable offline; util::bench provides the harness.)
//!
//! Runs against the real trained checkpoints when `make artifacts` has
//! been done; otherwise falls back to the built-in demo model so the
//! bench (and its `--smoke` CI mode) works on a bare machine. Results
//! are written to `BENCH_quant.json`: per-method wall-clock entries and
//! a `serial_vs_parallel` section timing the same quantization at
//! 1/2/4/8 pipeline lanes (output is bit-identical across the sweep —
//! pinned by the test suites — so the speedup is free).

use singlequant::model::{ModelConfig, Weights};
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::util::bench::{bench, header, BenchStats};
use singlequant::util::json::Json;
use singlequant::util::rng::Rng;
use singlequant::util::sqt::SqtFile;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn entry(report: &mut Vec<Json>, s: &BenchStats, extra: Vec<(&str, Json)>) {
    let mut pairs = vec![
        ("name", Json::str(s.name.clone())),
        ("mean_s", Json::num(s.mean_s)),
        ("p50_s", Json::num(s.p50_s)),
        ("p95_s", Json::num(s.p95_s)),
        ("min_s", Json::num(s.min_s)),
        ("iters", Json::usize(s.iters)),
    ];
    pairs.extend(extra);
    report.push(Json::obj(pairs));
}

/// The artifact-free fallback: demo config, seeded random weights, a
/// synthetic byte-level calibration corpus (mirrors serve-http's
/// no-artifacts path).
fn demo_inputs() -> (ModelConfig, Weights, Vec<u16>) {
    let cfg = ModelConfig::demo();
    let weights = Weights::random_init(&cfg, 1);
    let mut rng = Rng::new(7);
    let calib: Vec<u16> = (0..4096).map(|_| rng.below(256) as u16).collect();
    (cfg, weights, calib)
}

/// One (model, method) wall-clock row.
fn method_row(
    model: &str,
    label: &str,
    method: Method,
    iters: usize,
    cfg: &ModelConfig,
    weights: &Weights,
    calib: &[u16],
    base: &PipelineOptions,
    report: &mut Vec<Json>,
) {
    let opts = PipelineOptions { method, ..base.clone() };
    let stats = bench(&format!("{model}/{label}"), 0, iters, || {
        let qm = quantize(cfg, weights, calib, &opts).expect("quantize");
        std::hint::black_box(qm.rots.len());
    });
    println!("{}", stats.row());
    entry(report, &stats, vec![
        ("kind", Json::str("method")),
        ("model", Json::str(model.to_string())),
        ("method", Json::str(label.to_string())),
    ]);
}

/// Per-method wall-clock on one checkpoint.
fn method_section(
    model: &str,
    cfg: &ModelConfig,
    weights: &Weights,
    calib: &[u16],
    base: &PipelineOptions,
    smoke: bool,
    report: &mut Vec<Json>,
) {
    let scale = |iters: usize| if smoke { 1 } else { iters };
    for (label, method, iters) in [
        ("singlequant", Method::singlequant(), scale(5)),
        ("duquant", Method::DuQuant { steps: 16 }, scale(3)),
        ("spinquant-100", Method::SpinQuant { steps: 100 }, 1),
        ("flatquant-60", Method::FlatQuant { steps: 60 }, 1),
    ] {
        method_row(model, label, method, iters, cfg, weights, calib, base, report);
    }
}

/// The tentpole measurement: the same singlequant run at 1/2/4/8
/// pipeline lanes. threads=1 is the serial baseline (single-lane pools
/// inline their chunks on the caller), so `speedup_vs_serial` is the
/// direct win of the parallel fan-out.
fn thread_sweep_section(
    model: &str,
    cfg: &ModelConfig,
    weights: &Weights,
    calib: &[u16],
    base: &PipelineOptions,
    smoke: bool,
    sweep: &mut Vec<Json>,
) {
    let iters = if smoke { 1 } else { 3 };
    let mut serial_mean = f64::NAN;
    for t in THREAD_SWEEP {
        let opts = PipelineOptions {
            method: Method::singlequant(),
            threads: t,
            ..base.clone()
        };
        let stats = bench(&format!("{model}/singlequant threads={t}"), 0, iters, || {
            let qm = quantize(cfg, weights, calib, &opts).expect("quantize");
            std::hint::black_box(qm.packed_bytes);
        });
        if t == 1 {
            serial_mean = stats.mean_s;
        }
        let speedup = serial_mean / stats.mean_s;
        println!("{}  ({speedup:.2}x vs serial)", stats.row());
        sweep.push(Json::obj(vec![
            ("name", Json::str(stats.name.clone())),
            ("model", Json::str(model.to_string())),
            ("threads", Json::usize(t)),
            ("mean_s", Json::num(stats.mean_s)),
            ("min_s", Json::num(stats.min_s)),
            ("iters", Json::usize(stats.iters)),
            ("speedup_vs_serial", Json::num(speedup)),
        ]));
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke" || a == "--test");

    let mut report: Vec<Json> = Vec::new();
    let mut sweep: Vec<Json> = Vec::new();
    println!("{}", header());

    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let have_artifacts = std::path::Path::new(&format!("{dir}/manifest.json")).exists();
    if have_artifacts && !smoke {
        let manifest = Json::parse_file(&format!("{dir}/manifest.json")).expect("manifest");
        let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
            .expect("calibration corpus")
            .get("tokens")
            .expect("tokens key")
            .as_u16()
            .expect("u16 tokens")
            .to_vec();
        let base = PipelineOptions::default();
        for model in ["sq-s", "sq-m", "sq-l", "sq-xl", "sq-moe"] {
            let cfg = ModelConfig::from_manifest(&manifest, model).expect("config");
            let weights =
                Weights::load(&format!("{dir}/ckpt/{model}.sqt")).expect("checkpoint");
            method_section(model, &cfg, &weights, &calib, &base, smoke, &mut report);
        }
        // the lane sweep runs on one mid-size checkpoint
        let cfg = ModelConfig::from_manifest(&manifest, "sq-m").expect("config");
        let weights = Weights::load(&format!("{dir}/ckpt/sq-m.sqt")).expect("checkpoint");
        thread_sweep_section("sq-m", &cfg, &weights, &calib, &base, smoke, &mut sweep);
    } else {
        if !have_artifacts {
            eprintln!(
                "bench_quant_time: no artifacts at {dir}; using the built-in \
                 demo model (run `make artifacts` for checkpoint timings)"
            );
        }
        let (cfg, weights, calib) = demo_inputs();
        let base = PipelineOptions {
            calib_seqs: if smoke { 2 } else { 4 },
            calib_len: if smoke { 24 } else { 64 },
            ..Default::default()
        };
        method_section("demo", &cfg, &weights, &calib, &base, smoke, &mut report);
        thread_sweep_section("demo", &cfg, &weights, &calib, &base, smoke, &mut sweep);
    }

    let json = Json::obj(vec![
        ("bench", Json::str("quant_time")),
        ("smoke", Json::bool(smoke)),
        ("entries", Json::arr(report)),
        ("serial_vs_parallel", Json::arr(sweep)),
    ]);
    match std::fs::write("BENCH_quant.json", json.to_string()) {
        Ok(()) => println!("wrote BENCH_quant.json"),
        Err(e) => eprintln!("bench_quant_time: could not write json: {e}"),
    }
}
