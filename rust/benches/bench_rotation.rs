//! `cargo bench --bench bench_rotation` — Layer-1 kernel and rotation-
//! construction micro-benchmarks:
//!
//! * the AOT Pallas kernels through PJRT (Kronecker rotation vs dense
//!   rotation vs plain/quantized matmul vs Hadamard) — the O(n^{3/2})
//!   claim measured end to end;
//! * Rust-side construction cost of ART / URT / composed rotations and the
//!   GivensChain-vs-dense application crossover.

use singlequant::rotation::art::art_rotation;
use singlequant::rotation::givens::map_to_e1;
use singlequant::rotation::kronecker::{kron_factor, kron_flops, dense_flops, kron_rotate_rows};
use singlequant::rotation::singlequant::{build_site_rotation, SingleQuantConfig, SiteProfile};
use singlequant::rotation::urt::urt_rotation;
use singlequant::runtime::engine::{lit_f32, lit_i32};
use singlequant::runtime::Engine;
use singlequant::tensor::Tensor;
use singlequant::util::bench::{bench_for, header};
use singlequant::util::rng::Rng;

fn main() {
    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("{}", header());
    let mut rng = Rng::new(1);

    // ---- construction costs (pure Rust) ------------------------------------
    for n in [64usize, 96, 160, 416] {
        let profile: Vec<f32> = rng.normal_vec(n, 1.0);
        let s = bench_for(&format!("construct/urt n={n}"), 0.3, || {
            std::hint::black_box(urt_rotation(&profile));
        });
        println!("{}", s.row());
        let (n1, _) = kron_factor(n);
        let prof1: Vec<f32> = rng.normal_vec(n1, 1.0);
        let s = bench_for(&format!("construct/art n1={n1}"), 0.3, || {
            let mut r = Rng::new(7);
            std::hint::black_box(art_rotation(&prof1, 20, &mut r));
        });
        println!("{}", s.row());
        let sp = SiteProfile {
            n,
            signed_absmax: rng.normal_vec(n, 2.0),
            median: rng.normal_vec(n, 0.5),
        };
        let s = bench_for(&format!("construct/composed n={n}"), 0.3, || {
            std::hint::black_box(build_site_rotation(&sp, &SingleQuantConfig::default()));
        });
        println!("{}", s.row());
    }

    // ---- GivensChain O(n) vs dense O(n^2) application ----------------------
    for n in [64usize, 256, 1024] {
        let v = rng.normal_vec(n, 1.0);
        let chain = map_to_e1(&v);
        let dense = chain.to_matrix(n);
        let x = rng.normal_vec(n, 1.0);
        let s = bench_for(&format!("apply/chain n={n}"), 0.2, || {
            let mut w = x.clone();
            chain.apply_row(&mut w);
            std::hint::black_box(w[0]);
        });
        println!("{}", s.row());
        let s = bench_for(&format!("apply/dense n={n}"), 0.2, || {
            let row = Tensor::from_raw(vec![1, n], x.clone());
            std::hint::black_box(row.matmul(&dense).data()[0]);
        });
        println!("{}", s.row());
    }

    // ---- Kronecker vs dense rotation: Rust path + analytic flops -----------
    for n in [256usize, 1024, 4096] {
        let (n1, n2) = kron_factor(n);
        println!(
            "flops/kron n={n}: {} vs dense {} ({}x fewer)",
            kron_flops(n1, n2),
            dense_flops(n),
            dense_flops(n) / kron_flops(n1, n2).max(1)
        );
    }
    {
        let n = 1024;
        let (n1, n2) = kron_factor(n);
        let x = Tensor::randn(&[64, n], 1.0, &mut rng);
        let r1 = singlequant::tensor::decomp::random_orthogonal(n1, &mut rng);
        let r2 = singlequant::tensor::decomp::random_orthogonal(n2, &mut rng);
        let rd = singlequant::tensor::decomp::random_orthogonal(n, &mut rng);
        let s = bench_for("rust/kron_rotate n=1024", 0.4, || {
            std::hint::black_box(kron_rotate_rows(&x, &r1, &r2).len());
        });
        println!("{}", s.row());
        let s = bench_for("rust/dense_rotate n=1024", 0.4, || {
            std::hint::black_box(x.matmul(&rd).len());
        });
        println!("{}", s.row());
    }

    // ---- AOT Pallas kernels through PJRT ------------------------------------
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        let engine = Engine::new(&dir).expect("engine");
        let t = engine.manifest.get("kbench").unwrap().usize_at("t").unwrap();
        let n = engine.manifest.get("kbench").unwrap().usize_at("n").unwrap();
        let (n1, n2) = kron_factor(n);
        let mut rng = Rng::new(3);
        let x = lit_f32(&Tensor::randn(&[t, n], 1.0, &mut rng)).unwrap();
        let w = lit_f32(&Tensor::randn(&[n, n], 0.5, &mut rng)).unwrap();
        let r1 = lit_f32(&Tensor::eye(n1)).unwrap();
        let r2 = lit_f32(&Tensor::eye(n2)).unwrap();
        let rfull = lit_f32(&Tensor::eye(n)).unwrap();
        let _ = lit_i32(&[0], &[1]); // keep helper linked
        let cases: Vec<(&str, Vec<&xla::Literal>)> = vec![
            ("kernel_kron", vec![&x, &r1, &r2]),
            ("kernel_dense_rotate", vec![&x, &rfull]),
            ("kernel_qmm", vec![&x, &w]),
            ("kernel_mm", vec![&x, &w]),
            ("kernel_hadamard", vec![&x]),
        ];
        for (name, inputs) in cases {
            let art = engine.load(name).unwrap();
            let lits: Vec<xla::Literal> = inputs.iter().map(|l| (*l).clone()).collect();
            let s = bench_for(&format!("pjrt/{name} t={t} n={n}"), 0.5, || {
                std::hint::black_box(art.run(&lits).unwrap().len());
            });
            println!("{}", s.row());
        }
    } else {
        eprintln!("(skipping PJRT kernel benches: no artifacts)");
    }
}
