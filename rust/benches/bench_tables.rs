//! `cargo bench --bench bench_tables` — regenerate the paper's tables and
//! figures end to end. By default runs the fast evaluation budget so
//! `cargo bench` completes in minutes; set `SQ_FULL=1` for the full
//! budget, or `SQ_TABLES=table1,fig3` to select specific artifacts
//! (default: a representative subset; `all` runs everything).

use singlequant::experiments::{run_experiment, EvalBudget, ExpContext};

fn main() {
    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("bench_tables: run `make artifacts` first");
        return;
    }
    let budget = if std::env::var("SQ_FULL").is_ok() {
        EvalBudget::full()
    } else {
        EvalBudget::fast()
    };
    let ctx = ExpContext::new(&dir, budget).expect("context");
    let ids = std::env::var("SQ_TABLES")
        .unwrap_or_else(|_| "table6,table7,table8,fig1b,fig2".into());
    for id in ids.split(',') {
        println!("=== {id} ===");
        if let Err(e) = run_experiment(&ctx, id.trim()) {
            eprintln!("{id}: {e:#}");
        }
    }
}
