//! `cargo bench --bench bench_inference` — the serving-performance
//! measurement surface.
//!
//! Section 1 (always runs, no artifacts needed): the native CPU kernels —
//! f32 vs fused-dequant packed matmul across thread counts, and the
//! native model's prefill vs KV-cached decode tokens/sec — plus an
//! end-to-end coordinator run over `NativeBackend`. Results are also
//! written to `BENCH_inference.json` so the perf trajectory is machine-
//! readable across commits.
//!
//! Section 2 (requires `make artifacts`): the Fig. 3 PJRT measurements —
//! prefill/decode latency vs batch size for fp32 and W4A4 graphs.
//!
//! `--smoke` (used by CI) shrinks the timing budget and skips the
//! artifact-gated section; it exists to catch kernel rot, not to measure.

use std::sync::Arc;

use singlequant::coordinator::tokenizer::PAD;
use singlequant::coordinator::{Request, ServeBackend, ServeConfig, ServeEngine};
use singlequant::model::{ModelConfig, NativeModel, Weights};
use singlequant::pipeline::{quantize, Method, PipelineOptions, QuantizedModel};
use singlequant::quant::repack::RepackedWeight;
use singlequant::runtime::{Engine, ModelRunner, NativeBackend, RunnerBackend};
use singlequant::spec::NgramDraft;
use singlequant::tensor::kernels::{
    matmul_packed, matmul_packed_with, matmul_threaded, matmul_threaded_with,
};
use singlequant::tensor::{pool, simd, Tensor};
use singlequant::util::bench::{bench_for, header, BenchStats};
use singlequant::util::json::Json;
use singlequant::util::rng::Rng;
use singlequant::util::sqt::SqtFile;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn entry(report: &mut Vec<Json>, s: &BenchStats, extra: Vec<(&str, Json)>) {
    let mut pairs = vec![
        ("name", Json::str(s.name.clone())),
        ("mean_s", Json::num(s.mean_s)),
        ("p50_s", Json::num(s.p50_s)),
        ("p95_s", Json::num(s.p95_s)),
        ("min_s", Json::num(s.min_s)),
        ("iters", Json::usize(s.iters)),
    ];
    pairs.extend(extra);
    report.push(Json::obj(pairs));
}

/// f32 vs packed matmul across thread counts on a serving-shaped GEMM.
fn kernel_section(budget: f64, smoke: bool, report: &mut Vec<Json>) {
    let (m, k, n) = if smoke { (16, 256, 256) } else { (32, 1024, 1024) };
    let mut rng = Rng::new(11);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 0.5, &mut rng);
    let packed = RepackedWeight::pack(&b, 4, 64).unwrap();

    let f32_serial = bench_for(&format!("f32/serial {m}x{k}x{n}"), budget, || {
        std::hint::black_box(a.matmul(&b).len());
    });
    println!("{}", f32_serial.row());
    entry(report, &f32_serial, vec![("kind", Json::str("f32")), ("threads", Json::usize(1))]);

    let mut packed4_mean = f64::INFINITY;
    for &t in &THREAD_SWEEP {
        let s = bench_for(&format!("f32/threads={t} {m}x{k}x{n}"), budget, || {
            std::hint::black_box(matmul_threaded(&a, &b, t).len());
        });
        println!("{}", s.row());
        entry(report, &s, vec![("kind", Json::str("f32_threaded")), ("threads", Json::usize(t))]);

        let s = bench_for(&format!("packed4/threads={t} {m}x{k}x{n}"), budget, || {
            std::hint::black_box(matmul_packed(&a, &packed, t).len());
        });
        println!("{}", s.row());
        if t == 4 {
            packed4_mean = s.mean_s;
        }
        entry(report, &s, vec![("kind", Json::str("packed4")), ("threads", Json::usize(t))]);
    }
    let speedup = f32_serial.mean_s / packed4_mean;
    println!("packed4@4threads vs f32@1thread: {speedup:.2}x");
    report.push(Json::obj(vec![
        ("name", Json::str("speedup/packed4t4_vs_f32t1")),
        ("kind", Json::str("derived")),
        ("speedup", Json::num(speedup)),
    ]));
}

/// Scalar vs best-SIMD microkernel on the same serving-shaped GEMMs,
/// forced in-process through the `_with` dispatchers (the process-wide
/// kernel latch is untouched). Packed rows report effective GB/s over
/// the bytes a fused-dequant matmul actually streams.
fn simd_section(budget: f64, smoke: bool, report: &mut Vec<Json>) {
    let (m, k, n) = if smoke { (16, 256, 256) } else { (32, 1024, 1024) };
    let mut rng = Rng::new(19);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 0.5, &mut rng);
    let packed = RepackedWeight::pack(&b, 4, 64).unwrap();
    // fused-dequant traffic: A fp32 + int4 codes + fp32 output
    let packed_bytes = (m * k * 4 + k * n / 2 + m * n * 4) as f64;

    let mut kernels = vec![simd::Kernel::Scalar];
    if simd::best() != simd::Kernel::Scalar {
        kernels.push(simd::best());
    }
    for kernel in kernels {
        let label = kernel.label();
        for &t in &THREAD_SWEEP {
            let s = bench_for(
                &format!("kernel/{label}/packed4 t={t} {m}x{k}x{n}"),
                budget,
                || {
                    std::hint::black_box(matmul_packed_with(kernel, &a, &packed, t).len());
                },
            );
            let gbs = packed_bytes / s.mean_s / 1e9;
            println!("{}  ({gbs:.2} GB/s)", s.row());
            entry(report, &s, vec![
                ("kind", Json::str("packed_kernel")),
                ("kernel", Json::str(label)),
                ("threads", Json::usize(t)),
                ("gb_per_s", Json::num(gbs)),
            ]);
        }
        let s = bench_for(
            &format!("kernel/{label}/f32 t=4 {m}x{k}x{n}"),
            budget,
            || {
                std::hint::black_box(matmul_threaded_with(kernel, &a, &b, 4).len());
            },
        );
        println!("{}", s.row());
        entry(report, &s, vec![
            ("kind", Json::str("dense_kernel")),
            ("kernel", Json::str(label)),
            ("threads", Json::usize(4)),
        ]);
    }
}

/// Per-call dispatch overhead: spawn-per-matmul (the pre-pool scheme,
/// replicated with `std::thread::scope`) vs posting the same chunks to
/// the persistent worker pool. The chunk body is matmul-threshold sized,
/// so the gap is pure thread start/stop cost.
fn dispatch_section(budget: f64, report: &mut Vec<Json>) {
    const CHUNKS: usize = 4;
    let work: Vec<f32> = (0..4096).map(|i| (i as f32).sin()).collect();
    let chunk_sum = |ci: usize| {
        let lo = ci * work.len() / CHUNKS;
        let hi = (ci + 1) * work.len() / CHUNKS;
        std::hint::black_box(work[lo..hi].iter().sum::<f32>());
    };

    let s = bench_for("dispatch/spawn-per-call x4", budget, || {
        std::thread::scope(|scope| {
            for ci in 1..CHUNKS {
                scope.spawn(move || chunk_sum(ci));
            }
            chunk_sum(0);
        });
    });
    println!("{}", s.row());
    entry(report, &s, vec![("kind", Json::str("dispatch")), ("scheme", Json::str("spawn"))]);
    let spawn_mean = s.mean_s;

    let s = bench_for("dispatch/worker-pool x4", budget, || {
        pool::global().run(CHUNKS, chunk_sum);
    });
    println!("{}  ({:.1}x vs spawn)", s.row(), spawn_mean / s.mean_s);
    entry(report, &s, vec![
        ("kind", Json::str("dispatch")),
        ("scheme", Json::str("pool")),
        ("speedup_vs_spawn", Json::num(spawn_mean / s.mean_s)),
    ]);
}

/// Slot-parallel decode-wave scaling: tokens/sec of one backend decode
/// step as the number of active slots grows. Cache refills (retire +
/// re-prefill) happen outside the timed region.
fn wave_section(qm: &QuantizedModel, budget: f64, report: &mut Vec<Json>) {
    let mut rng = Rng::new(23);
    let plen = 8usize;
    for batch in [1usize, 4, 8] {
        let model = NativeModel::from_quantized(qm, 4, 0).expect("native model");
        let cfg = model.cfg.clone();
        let mut be = NativeBackend::new(model, batch);
        let score_seq = be.limits().score_seq;
        let admitted: Vec<usize> = (0..batch).collect();
        let prefill_tokens = |rng: &mut Rng| -> Vec<i32> {
            let mut toks = vec![PAD as i32; batch * score_seq];
            for slot in 0..batch {
                for p in 0..plen {
                    toks[slot * score_seq + p] = rng.below(256) as i32;
                }
            }
            toks
        };
        be.prefill(&prefill_tokens(&mut rng), &admitted).unwrap();
        let mut pos = plen;

        let step: Vec<i32> = vec![7; batch];
        let mut times = Vec::new();
        let start = std::time::Instant::now();
        while start.elapsed().as_secs_f64() < budget || times.len() < 3 {
            if pos + 1 >= cfg.max_seq {
                for slot in 0..batch {
                    be.retire(slot);
                }
                be.prefill(&prefill_tokens(&mut rng), &admitted).unwrap();
                pos = plen;
            }
            let positions: Vec<i32> = vec![pos as i32; batch];
            let t0 = std::time::Instant::now();
            std::hint::black_box(be.decode(&step, &positions).unwrap().len());
            times.push(t0.elapsed().as_secs_f64());
            pos += 1;
            if times.len() > 10_000 {
                break;
            }
        }
        let s = BenchStats::from_times(&format!("wave/decode batch={batch}"), times);
        let tps = batch as f64 / s.mean_s;
        println!("{}  ({tps:.0} tok/s across {batch} slots)", s.row());
        entry(report, &s, vec![
            ("kind", Json::str("decode_wave")),
            ("batch", Json::usize(batch)),
            ("tokens_per_s", Json::num(tps)),
        ]);
    }
}

/// Prefill vs KV-cached decode tokens/sec on the quantized demo model.
/// Returns the quantized package so later sections reuse it.
fn serving_section(budget: f64, report: &mut Vec<Json>) -> QuantizedModel {
    let cfg = ModelConfig::demo();
    let weights = Weights::random_init(&cfg, 1);
    let mut rng = Rng::new(3);
    let calib: Vec<u16> = (0..2048).map(|_| rng.below(256) as u16).collect();
    let opts = PipelineOptions {
        method: Method::singlequant(),
        calib_seqs: 2,
        calib_len: 32,
        ..Default::default()
    };
    let qm = quantize(&cfg, &weights, &calib, &opts).expect("quantize demo model");
    let prompt: Vec<u16> = (0..16).map(|_| rng.below(256) as u16).collect();
    let prefill_prompt: Vec<u16> = (0..48).map(|_| rng.below(256) as u16).collect();

    for &t in &[1usize, 2, 4] {
        let model = NativeModel::from_quantized(&qm, 4, t).expect("native model");

        let s = bench_for(&format!("native/prefill48 threads={t}"), budget, || {
            let mut kv = model.new_kv();
            std::hint::black_box(model.prefill(&mut kv, &prefill_prompt).unwrap().len());
        });
        println!("{}  ({:.0} tok/s)", s.row(), 48.0 / s.mean_s);
        entry(report, &s, vec![
            ("kind", Json::str("prefill")),
            ("threads", Json::usize(t)),
            ("tokens_per_s", Json::num(48.0 / s.mean_s)),
        ]);

        // cache refills happen outside the timed region so the stats
        // measure pure decode steps
        let mut kv = model.new_kv();
        model.prefill(&mut kv, &prompt).unwrap();
        let mut times = Vec::new();
        let start = std::time::Instant::now();
        while start.elapsed().as_secs_f64() < budget || times.len() < 3 {
            if kv.pos + 1 >= cfg.max_seq {
                kv.reset();
                model.prefill(&mut kv, &prompt).unwrap();
            }
            let t0 = std::time::Instant::now();
            std::hint::black_box(model.decode(&mut kv, 7).unwrap().len());
            times.push(t0.elapsed().as_secs_f64());
            if times.len() > 10_000 {
                break;
            }
        }
        let s = BenchStats::from_times(&format!("native/decode threads={t}"), times);
        println!("{}  ({:.0} tok/s)", s.row(), 1.0 / s.mean_s);
        entry(report, &s, vec![
            ("kind", Json::str("decode")),
            ("threads", Json::usize(t)),
            ("tokens_per_s", Json::num(1.0 / s.mean_s)),
        ]);
    }

    // end-to-end: continuous batcher over the native backend
    let model = NativeModel::from_quantized(&qm, 4, 0).expect("native model");
    let mut serve = ServeEngine::new(
        Box::new(NativeBackend::new(model, 4)),
        ServeConfig { max_new_cap: 8, seed: 3, ..Default::default() },
    );
    for id in 0..8u64 {
        let start = (id as usize * 97) % (calib.len() - 32);
        serve.submit(
            Request::new(id, calib[start..start + 8 + (id as usize % 16)].to_vec())
                .with_max_new(8),
        );
    }
    let t0 = std::time::Instant::now();
    let responses = serve.run_to_completion().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let tput = serve.metrics.generated_tokens as f64 / wall;
    println!(
        "native/serve-e2e b=4: {} reqs in {:.2}s -> {:.1} gen tok/s \
         (prefill/decode split {:.0}%/{:.0}%)",
        responses.len(),
        wall,
        tput,
        serve.metrics.prefill_time_fraction() * 100.0,
        (1.0 - serve.metrics.prefill_time_fraction()) * 100.0,
    );
    report.push(Json::obj(vec![
        ("name", Json::str("native/serve-e2e b=4")),
        ("kind", Json::str("serve_e2e")),
        ("requests", Json::usize(responses.len())),
        ("wall_s", Json::num(wall)),
        ("tokens_per_s", Json::num(tput)),
        ("decode_tokens_per_s", Json::num(serve.metrics.decode_only_tokens_per_s())),
        ("prefill_fraction", Json::num(serve.metrics.prefill_time_fraction())),
    ]));
    qm
}

/// Drive a fixed request trace through one backend configuration and
/// record concurrency + throughput.
fn kv_budget_run(
    label: &str,
    backend: Box<dyn ServeBackend>,
    n_requests: usize,
    prompt_len: usize,
    max_new: usize,
    report: &mut Vec<Json>,
) {
    let mut serve = ServeEngine::new(
        backend,
        ServeConfig { max_new_cap: max_new, seed: 5, queue_cap: 64 },
    );
    let mut rng = Rng::new(17);
    for id in 0..n_requests as u64 {
        let prompt: Vec<u16> = (0..prompt_len).map(|_| rng.below(256) as u16).collect();
        serve.submit(Request::new(id, prompt).with_max_new(max_new));
    }
    let t0 = std::time::Instant::now();
    let mut max_active = 0;
    while serve.has_work() {
        serve.step().expect("kv-budget bench step");
        max_active = max_active.max(serve.active());
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = &serve.metrics;
    println!(
        "kv-budget/{label}: {} reqs in {:.2}s, max {} concurrent, \
         {:.0} decode tok/s, {} preemptions",
        m.completed, wall, max_active, m.decode_only_tokens_per_s(), m.preemptions,
    );
    report.push(Json::obj(vec![
        ("name", Json::str(format!("kv-budget/{label}"))),
        ("kind", Json::str("kv_budget")),
        ("requests", Json::usize(m.completed)),
        ("wall_s", Json::num(wall)),
        ("max_concurrent", Json::usize(max_active)),
        ("decode_tokens_per_s", Json::num(m.decode_only_tokens_per_s())),
        ("preemptions", Json::usize(m.preemptions)),
        ("kv_pages_total", Json::usize(m.kv_pages_total)),
    ]));
}

/// Serving concurrency at a fixed KV byte budget: contiguous slots each
/// pin `max_seq` rows up front, so the budget caps the batch at the
/// worst case; a paged pool spends the same bytes on demand and admits
/// by actual page need (preempting if it overcommits).
fn paged_kv_section(qm: &QuantizedModel, smoke: bool, report: &mut Vec<Json>) {
    let (n_requests, max_new) = if smoke { (6, 4) } else { (16, 12) };
    let prompt_len = 12;
    let model = NativeModel::from_quantized(qm, 4, 0).expect("native model");
    let cfg = model.cfg.clone();
    // fp32 K+V rows across all layers
    let bytes_per_token = 2 * cfg.n_layers * cfg.d_model * 4;
    let budget = 2 * cfg.max_seq * bytes_per_token; // two worst-case slots
    println!(
        "kv-budget: {} KiB for KV ({} B/token, max_seq {})",
        budget / 1024, bytes_per_token, cfg.max_seq
    );

    // naive sizing: batch limited to the slots that can reach max_seq
    let contig_batch = budget / (cfg.max_seq * bytes_per_token);
    kv_budget_run(
        "contig",
        Box::new(NativeBackend::new(model, contig_batch)),
        n_requests, prompt_len, max_new, report,
    );

    for pt in [8usize, 32] {
        let pages = budget / (pt * bytes_per_token);
        let model = NativeModel::from_quantized(qm, 4, 0).expect("native model");
        kv_budget_run(
            &format!("paged-pt{pt}"),
            Box::new(NativeBackend::with_paged_kv(model, 8, pt, pages)),
            n_requests, prompt_len, max_new, report,
        );
    }
}

/// Speculative decoding: decode tokens/sec and acceptance rate vs the
/// proposal depth k, on the quantized demo model with the zero-weight
/// n-gram draft. Prompts are periodic — the draft's best case — and
/// k = 0 is the plain-decode baseline. Output equality across k is
/// pinned by the unit suites; this section quantifies the throughput
/// side of the accept/reject trade.
fn spec_decode_section(qm: &QuantizedModel, smoke: bool, report: &mut Vec<Json>) {
    let (n_requests, max_new) = if smoke { (6, 6) } else { (16, 24) };
    for k in [0usize, 1, 2, 4, 8] {
        let model = NativeModel::from_quantized(qm, 4, 0).expect("native model");
        let mut serve = ServeEngine::new(
            Box::new(NativeBackend::new(model, 4)),
            ServeConfig { max_new_cap: max_new, seed: 5, queue_cap: 64 },
        );
        if k > 0 {
            serve.enable_speculation(k, Box::new(NgramDraft::new(3)));
        }
        for id in 0..n_requests as u64 {
            let base = 10 + (id as u16 % 7) * 5;
            let prompt: Vec<u16> = (0..12).map(|j| base + j % 4).collect();
            serve.submit(Request::new(id, prompt).with_max_new(max_new));
        }
        let t0 = std::time::Instant::now();
        serve.run_to_completion().expect("spec bench run");
        let wall = t0.elapsed().as_secs_f64();
        let m = &serve.metrics;
        println!(
            "spec-decode/k={k}: {:.0} decode tok/s, acceptance {:.0}% \
             ({} proposed), {:.2} tok/wave, {:.2}s wall",
            m.decode_only_tokens_per_s(),
            m.spec_acceptance_rate() * 100.0,
            m.spec_proposed,
            m.spec_wave_len.mean(),
            wall,
        );
        report.push(Json::obj(vec![
            ("name", Json::str(format!("spec-decode/k={k}"))),
            ("kind", Json::str("spec_decode")),
            ("k", Json::usize(k)),
            ("draft", Json::str(if k == 0 { "none" } else { "ngram" })),
            ("decode_tokens_per_s", Json::num(m.decode_only_tokens_per_s())),
            ("acceptance_rate", Json::num(m.spec_acceptance_rate())),
            ("proposed", Json::usize(m.spec_proposed as usize)),
            ("accepted", Json::usize(m.spec_accepted as usize)),
            ("mean_wave_len", Json::num(m.spec_wave_len.mean())),
            ("wall_s", Json::num(wall)),
        ]));
    }
}

/// The artifact-gated PJRT section (Fig. 3 shapes).
fn pjrt_section(dir: &str) {
    let engine = Arc::new(Engine::new(dir).expect("engine"));
    let model = "sq-m";
    let cfg = engine.config(model).unwrap();
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt")).unwrap();
    let corpus = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
        .unwrap()
        .get("tokens")
        .unwrap()
        .as_u16()
        .unwrap()
        .to_vec();

    let batches: Vec<usize> = engine
        .manifest
        .get("serve_batches")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|b| b.as_usize().unwrap())
        .collect();

    for (label, method) in [("fp32", Method::Fp16), ("w4a4", Method::singlequant())] {
        let qm = quantize(&cfg, &weights, &corpus, &PipelineOptions {
            method,
            ..Default::default()
        })
        .unwrap();
        let runner = Arc::new(ModelRunner::new(engine.clone(), &qm).unwrap());
        let t = cfg.score_seq;
        let mut rng = Rng::new(5);
        for &b in &batches {
            let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(256) as i32).collect();
            let s = bench_for(&format!("{label}/prefill b={b}"), 0.6, || {
                std::hint::black_box(runner.prefill(b, &tokens).unwrap().0.len());
            });
            println!("{}", s.row());
            let (_, mut kv) = runner.prefill(b, &tokens).unwrap();
            let step: Vec<i32> = vec![7; b];
            let pos: Vec<i32> = vec![t as i32; b];
            let s = bench_for(&format!("{label}/decode b={b}"), 0.6, || {
                std::hint::black_box(runner.decode(&mut kv, &step, &pos).unwrap().len());
            });
            println!("{}", s.row());
        }

        // end-to-end coordinator throughput at batch 4
        let mut serve = ServeEngine::new(
            Box::new(RunnerBackend::new(runner.clone(), 4)),
            ServeConfig { max_new_cap: 16, seed: 3, ..Default::default() },
        );
        for id in 0..12u64 {
            let start = (id as usize * 311) % (corpus.len() - 64);
            serve.submit(
                Request::new(id, corpus[start..start + 24 + (id as usize % 32)].to_vec())
                    .with_max_new(12),
            );
        }
        let t0 = std::time::Instant::now();
        let responses = serve.run_to_completion().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label}/serve-e2e b=4: {} reqs in {:.2}s -> {:.1} gen tok/s",
            responses.len(),
            wall,
            serve.metrics.generated_tokens as f64 / wall
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke" || a == "--test");
    let budget = if smoke { 0.02 } else { 0.5 };

    println!("{}", header());
    let mut report: Vec<Json> = Vec::new();
    kernel_section(budget, smoke, &mut report);
    simd_section(budget, smoke, &mut report);
    dispatch_section(budget, &mut report);
    let qm = serving_section(budget, &mut report);
    wave_section(&qm, budget, &mut report);
    paged_kv_section(&qm, smoke, &mut report);
    spec_decode_section(&qm, smoke, &mut report);

    let json = Json::obj(vec![
        ("bench", Json::str("inference")),
        ("smoke", Json::bool(smoke)),
        ("entries", Json::arr(report)),
    ]);
    match std::fs::write("BENCH_inference.json", json.to_string()) {
        Ok(()) => println!("wrote BENCH_inference.json"),
        Err(e) => eprintln!("bench_inference: could not write json: {e}"),
    }

    if smoke {
        return;
    }
    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        pjrt_section(&dir);
    } else {
        eprintln!("bench_inference: no artifacts at {dir}; skipped PJRT section \
                   (run `make artifacts` for Fig. 3 shapes)");
    }
}
