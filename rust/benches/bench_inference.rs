//! `cargo bench --bench bench_inference` — Fig. 3's measurement core:
//! prefill / decode-step latency vs batch size for the fp32 and W4A4
//! (SingleQuant) runtime graphs, plus the serving coordinator's
//! end-to-end throughput at each batch width.

use std::sync::Arc;

use singlequant::coordinator::{Request, ServeConfig, ServeEngine};
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner, RunnerBackend};
use singlequant::util::bench::{bench_for, header};
use singlequant::util::rng::Rng;
use singlequant::util::sqt::SqtFile;

fn main() {
    let dir = std::env::var("SQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("bench_inference: run `make artifacts` first");
        return;
    }
    let engine = Arc::new(Engine::new(&dir).expect("engine"));
    let model = "sq-m";
    let cfg = engine.config(model).unwrap();
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt")).unwrap();
    let corpus = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))
        .unwrap()
        .get("tokens")
        .unwrap()
        .as_u16()
        .unwrap()
        .to_vec();

    println!("{}", header());
    let batches: Vec<usize> = engine
        .manifest
        .get("serve_batches")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|b| b.as_usize().unwrap())
        .collect();

    for (label, method) in [("fp32", Method::Fp16), ("w4a4", Method::singlequant())] {
        let qm = quantize(&cfg, &weights, &corpus, &PipelineOptions {
            method,
            ..Default::default()
        })
        .unwrap();
        let runner = Arc::new(ModelRunner::new(engine.clone(), &qm).unwrap());
        let t = cfg.score_seq;
        let mut rng = Rng::new(5);
        for &b in &batches {
            let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(256) as i32).collect();
            let s = bench_for(&format!("{label}/prefill b={b}"), 0.6, || {
                std::hint::black_box(runner.prefill(b, &tokens).unwrap().0.len());
            });
            println!("{}", s.row());
            let (_, mut kv) = runner.prefill(b, &tokens).unwrap();
            let step: Vec<i32> = vec![7; b];
            let pos: Vec<i32> = vec![t as i32; b];
            let s = bench_for(&format!("{label}/decode b={b}"), 0.6, || {
                std::hint::black_box(runner.decode(&mut kv, &step, &pos).unwrap().len());
            });
            println!("{}", s.row());
        }

        // end-to-end coordinator throughput at batch 4
        let mut serve = ServeEngine::new(
            Box::new(RunnerBackend::new(runner.clone(), 4)),
            ServeConfig { max_new_cap: 16, seed: 3, ..Default::default() },
        );
        for id in 0..12u64 {
            let start = (id as usize * 311) % (corpus.len() - 64);
            serve.submit(
                Request::new(id, corpus[start..start + 24 + (id as usize % 32)].to_vec())
                    .with_max_new(12),
            );
        }
        let t0 = std::time::Instant::now();
        let responses = serve.run_to_completion().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label}/serve-e2e b=4: {} reqs in {:.2}s -> {:.1} gen tok/s",
            responses.len(),
            wall,
            serve.metrics.generated_tokens as f64 / wall
        );
    }
}
