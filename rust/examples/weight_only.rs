//! Weight-only quantization walk-through (Table B.3): sweep W4A16 and
//! W3A16 across weight quantizers, showing where plain RTN collapses and
//! how GPTQ's error compensation / SingleQuant's rotations recover it.
//!
//!     cargo run --release --example weight_only [artifacts_dir]

use std::sync::Arc;

use anyhow::Result;
use singlequant::eval::ppl::perplexity;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::quant::WeightQuantizer;
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::bench::Table;
use singlequant::util::sqt::SqtFile;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = "sq-m";
    let engine = Arc::new(Engine::new(&dir)?);
    let cfg = engine.config(model)?;
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt"))?;
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();
    let eval = SqtFile::load(&format!("{dir}/data/corpus_wiki_eval.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();

    let rows: Vec<(&str, Method, WeightQuantizer)> = vec![
        ("RTN", Method::Rtn, WeightQuantizer::Rtn),
        ("GPTQ", Method::Rtn, WeightQuantizer::Gptq),
        ("GPTQ-g32", Method::Rtn, WeightQuantizer::GptqGrouped(32)),
        ("AWQ", Method::Awq { grid: 10 }, WeightQuantizer::Rtn),
        ("SingleQuant", Method::singlequant(), WeightQuantizer::Rtn),
    ];
    let mut table = Table::new(
        "weight-only perplexity (wiki eval)",
        &["method", "W4A16↓", "W3A16↓"],
    );
    for (label, method, wq) in rows {
        let mut cells = vec![label.to_string()];
        for bits in [4u32, 3] {
            let opts = PipelineOptions {
                method: method.clone(),
                weight_quantizer: wq,
                weight_bits: bits,
                act_bits: 16,
                ..Default::default()
            };
            let qm = quantize(&cfg, &weights, &calib, &opts)?;
            let runner = ModelRunner::new(engine.clone(), &qm)?;
            let ppl = perplexity(&runner, &eval, cfg.score_seq, 8)?;
            println!("{label} W{bits}A16: ppl {ppl:.3}");
            cells.push(format!("{ppl:.3}"));
        }
        table.row(cells);
    }
    table.print();
    Ok(())
}
