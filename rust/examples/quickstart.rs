//! Quickstart: quantize a trained checkpoint with SingleQuant and compare
//! W4A4 perplexity against FP16 through the PJRT runtime.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (data generation + pretraining + AOT
//! lowering) to have been run once.

use std::sync::Arc;

use anyhow::Result;
use singlequant::eval::ppl::perplexity;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::sqt::SqtFile;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = "sq-m";

    // 1. Load the engine (PJRT CPU client + artifact manifest) and model.
    let engine = Arc::new(Engine::new(&dir)?);
    let cfg = engine.config(model)?;
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt"))?;
    println!("loaded {model}: {} parameters", weights.n_params());

    // 2. Calibration data: a slice of the training corpus.
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
        .get("tokens")?
        .as_u16()?
        .to_vec();

    // 3. Quantize: one calibration pass + closed-form rotations. No
    //    gradient optimization anywhere — watch the wall-clock.
    let t0 = std::time::Instant::now();
    let qm = quantize(&cfg, &weights, &calib, &PipelineOptions {
        method: Method::singlequant(),
        ..Default::default()
    })?;
    println!(
        "SingleQuant W4A4 quantization took {:.2}s \
         (calib {:.2}s, rotations {:.3}s, weights {:.2}s)",
        t0.elapsed().as_secs_f64(),
        qm.calib_seconds,
        qm.transform_seconds,
        qm.weight_quant_seconds,
    );
    println!(
        "packed weight storage: {:.2} MB (fp32 would be {:.2} MB)",
        qm.packed_bytes as f64 / 1e6,
        (weights.n_params() * 4) as f64 / 1e6,
    );

    // 4. Evaluate both the fp and quantized graphs end to end.
    let eval = SqtFile::load(&format!("{dir}/data/corpus_wiki_eval.sqt"))?
        .get("tokens")?
        .as_u16()?
        .to_vec();
    let fp = quantize(&cfg, &weights, &calib, &PipelineOptions {
        method: Method::Fp16,
        ..Default::default()
    })?;
    let fp_runner = ModelRunner::new(engine.clone(), &fp)?;
    let q_runner = ModelRunner::new(engine, &qm)?;
    let ppl_fp = perplexity(&fp_runner, &eval, cfg.score_seq, 8)?;
    let ppl_q = perplexity(&q_runner, &eval, cfg.score_seq, 8)?;
    println!("perplexity: fp32 {ppl_fp:.3}  |  W4A4+SingleQuant {ppl_q:.3}");
    Ok(())
}
