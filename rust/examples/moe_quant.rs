//! MoE quantization (the paper's Mixtral experiment, Table 4): quantize
//! the Mixtral-style sparse-expert model and verify the closed-form
//! rotations handle expert-routed activation distributions.
//!
//!     cargo run --release --example moe_quant [artifacts_dir]

use std::sync::Arc;

use anyhow::Result;
use singlequant::eval::ppl::perplexity;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::sqt::SqtFile;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = "sq-moe";
    let engine = Arc::new(Engine::new(&dir)?);
    let cfg = engine.config(model)?;
    println!(
        "MoE model: {} experts, top-{} routing, {} layers",
        cfg.n_experts, cfg.top_k, cfg.n_layers
    );
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt"))?;
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();
    let eval = SqtFile::load(&format!("{dir}/data/corpus_wiki_eval.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();

    for method in [Method::Fp16, Method::Rtn, Method::QuaRot, Method::singlequant()] {
        let label = method.label();
        let opts = PipelineOptions { method, ..Default::default() };
        let qm = quantize(&cfg, &weights, &calib, &opts)?;
        let runner = ModelRunner::new(engine.clone(), &qm)?;
        let ppl = perplexity(&runner, &eval, cfg.score_seq, 8)?;
        println!(
            "{label:<14} wiki ppl {ppl:>8.3}   quant time {:.2}s",
            qm.total_seconds()
        );
    }
    println!("\nnote: expert mlp/down sites share one rotation per layer — the");
    println!("calibration tap aggregates across experts (see calib::run_calibration).");
    Ok(())
}
