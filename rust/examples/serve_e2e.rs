//! End-to-end serving driver (the repo's headline validation): quantize a
//! real trained model, bring up the continuous-batching coordinator on the
//! W4A4 PJRT graphs, push a bursty synthetic request trace through it, and
//! report latency/throughput — then run the same trace against the fp32
//! graphs for comparison.
//!
//!     cargo run --release --example serve_e2e [artifacts_dir]
//!
//! Everything on the request path is Rust + PJRT; Python was only involved
//! at build time.

use std::sync::Arc;

use anyhow::Result;
use singlequant::coordinator::{Request, ServeConfig, ServeEngine};
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner, RunnerBackend};
use singlequant::util::rng::Rng;
use singlequant::util::sqt::SqtFile;

const MODEL: &str = "sq-m";
const BATCH: usize = 4;
const N_REQUESTS: usize = 24;

fn trace(corpus: &[u16], n: usize) -> Vec<Request> {
    let mut rng = Rng::new(99);
    (0..n)
        .map(|id| {
            let start = rng.below(corpus.len() - 96);
            let len = 12 + rng.below(60);
            let mut req = Request::new(id as u64, corpus[start..start + len].to_vec())
                .with_max_new(8 + rng.below(24));
            if id % 3 == 0 {
                req = req.with_temperature(0.8);
            }
            req
        })
        .collect()
}

fn serve_with(engine: Arc<Engine>, method: Method, corpus: &[u16],
              calib: &[u16]) -> Result<()> {
    let cfg = engine.config(MODEL)?;
    let weights = Weights::load(&format!("{}/ckpt/{MODEL}.sqt", engine.dir))?;
    let label = method.label();
    let qm = quantize(&cfg, &weights, calib, &PipelineOptions {
        method,
        ..Default::default()
    })?;
    let runner = Arc::new(ModelRunner::new(engine, &qm)?);
    let mut serve = ServeEngine::new(
        Box::new(RunnerBackend::new(runner, BATCH)),
        ServeConfig { max_new_cap: 32, seed: 7, ..Default::default() },
    );
    for req in trace(corpus, N_REQUESTS) {
        serve.submit(req);
    }
    let responses = serve.run_to_completion()?;
    println!("--- {label} ---");
    println!("{}", serve.metrics.summary());
    // show a few generations
    for r in responses.iter().take(3) {
        let preview: String = r.text.chars().take(60).collect();
        println!("  req {:>2} ({:>2} prompt tok, {:>2} gen): {preview:?}",
                 r.id, r.prompt_len, r.tokens.len());
    }
    println!();
    Ok(())
}

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Arc::new(Engine::new(&dir)?);
    let corpus = SqtFile::load(&format!("{dir}/data/corpus_wiki_eval.sqt"))?
        .get("tokens")?
        .as_u16()?
        .to_vec();
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
        .get("tokens")?
        .as_u16()?
        .to_vec();

    println!("serving {N_REQUESTS} requests, continuous batching, batch={BATCH}\n");
    serve_with(engine.clone(), Method::singlequant(), &corpus, &calib)?;
    serve_with(engine, Method::Fp16, &corpus, &calib)?;
    Ok(())
}
