//! Decode-latency micro-probe used by the §Perf L3 pass (EXPERIMENTS.md):
//! measures the per-step decode wall-clock across batch sizes on the fp
//! graphs. `SQ_KV_HOST_PATH=1` forces the pre-optimization KV host path
//! for A/B comparison.
use std::sync::Arc;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::sqt::SqtFile;
fn main() {
    let dir = "artifacts";
    let engine = Arc::new(Engine::new(dir).unwrap());
    let cfg = engine.config("sq-m").unwrap();
    let w = Weights::load(&format!("{dir}/ckpt/sq-m.sqt")).unwrap();
    let toks = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt")).unwrap()
        .get("tokens").unwrap().as_u16().unwrap().to_vec();
    let qm = quantize(&cfg, &w, &toks, &PipelineOptions{method: Method::Fp16, ..Default::default()}).unwrap();
    let runner = ModelRunner::new(engine, &qm).unwrap();
    for b in [1usize, 4, 16, 32] {
        let ptoks = vec![0i32; b*96];
        let (_l, mut kv) = runner.prefill(b, &ptoks).unwrap();
        let step = vec![0i32; b]; let pos = vec![5i32; b];
        runner.decode(&mut kv, &step, &pos).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..10 { runner.decode(&mut kv, &step, &pos).unwrap(); }
        println!("decode b{b}: {:.2}ms", t0.elapsed().as_secs_f64()/10.0*1e3);
    }
}
