//! Concurrent load generator for the HTTP serving front-end: replays a
//! bursty synthetic trace of mixed streaming / non-streaming completions
//! against a live server and reports client-side latency percentiles plus
//! the server's own /metrics.
//!
//!     # self-contained demo (in-process server on the synthetic backend):
//!     cargo run --release --example http_load -- --self-host
//!
//!     # against a running `singlequant serve-http`:
//!     cargo run --release --example http_load -- --addr 127.0.0.1:8071 \
//!         --requests 64 --burst 8 --burst-pause-ms 40
//!
//! Every third request streams (SSE); the rest take the single-JSON path.
//! 429 responses are counted as shed load, not errors — that is the
//! admission control doing its job under burst.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use singlequant::coordinator::metrics::Histogram;
use singlequant::coordinator::{ServeConfig, ServeEngine, SyntheticBackend};
use singlequant::server::{serve, ServerConfig, ServerHandle};
use singlequant::util::cli::Args;
use singlequant::util::json::Json;
use singlequant::util::rng::Rng;

struct Outcome {
    status: u16,
    latency: Duration,
    /// Time to the first SSE token frame (streaming requests only).
    first_token: Option<Duration>,
    tokens: usize,
}

fn one_request(addr: &str, id: usize, prompt: &str, max_tokens: usize,
               stream: bool) -> Result<Outcome> {
    let started = Instant::now();
    let mut sock = TcpStream::connect(addr).context("connect")?;
    sock.set_read_timeout(Some(Duration::from_secs(60)))?;
    let body = Json::obj(vec![
        ("prompt", Json::str(prompt)),
        ("max_tokens", Json::usize(max_tokens)),
        ("stream", Json::bool(stream)),
        ("temperature", if id % 4 == 0 { Json::num(0.8) } else { Json::Null }),
    ])
    .to_string();
    write!(
        sock,
        "POST /v1/completions HTTP/1.1\r\nHost: l\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;

    let mut raw = Vec::new();
    let mut buf = [0u8; 4096];
    let mut first_token = None;
    loop {
        match sock.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                raw.extend_from_slice(&buf[..n]);
                if stream && first_token.is_none()
                    && raw.windows(6).any(|w| w == b"data: ".as_slice())
                {
                    first_token = Some(started.elapsed());
                }
            }
            Err(e) => return Err(anyhow!("read: {e}")),
        }
    }
    let text = String::from_utf8_lossy(&raw);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("unparseable response"))?;
    let payload = text.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let tokens = if stream {
        payload.matches("data: ").count().saturating_sub(2) // finish chunk + [DONE]
    } else {
        Json::parse(payload)
            .ok()
            .and_then(|j| j.get("usage").ok().and_then(|u| u.usize_at("completion_tokens").ok()))
            .unwrap_or(0)
    };
    Ok(Outcome { status, latency: started.elapsed(), first_token, tokens })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["self-host"])?;

    let self_host = args.flag("self-host") || args.get("addr").is_none();
    let handle: Option<ServerHandle> = if self_host {
        let engine = ServeEngine::new(
            Box::new(
                SyntheticBackend::new(4)
                    .with_seq(64, 128)
                    .with_delay(Duration::from_millis(2)),
            ),
            ServeConfig { max_new_cap: 32, seed: 7, queue_cap: 16 },
        );
        let h = serve(engine, ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            default_max_tokens: 16,
            default_deadline_ms: Some(10_000),
            model: "synthetic".to_string(),
        })?;
        println!("self-hosted synthetic server on {}", h.addr());
        Some(h)
    } else {
        None
    };
    let addr = match &handle {
        Some(h) => h.addr().to_string(),
        None => args.get("addr").unwrap().to_string(),
    };

    let n_requests = args.usize_or("requests", 48)?;
    let burst = args.usize_or("burst", 8)?.max(1);
    let pause = Duration::from_millis(args.usize_or("burst-pause-ms", 30)? as u64);
    let max_tokens = args.usize_or("max-new", 12)?;

    println!(
        "replaying {n_requests} requests against {addr} in bursts of {burst} \
         ({}ms apart), every 3rd streamed\n",
        pause.as_millis()
    );

    let mut rng = Rng::new(0x10ad);
    let mut latency = Histogram::default();
    let mut ttft = Histogram::default();
    let (mut ok, mut shed, mut failed, mut tokens) = (0usize, 0usize, 0usize, 0usize);

    let t0 = Instant::now();
    let mut id = 0usize;
    while id < n_requests {
        let wave = burst.min(n_requests - id);
        let workers: Vec<_> = (0..wave)
            .map(|k| {
                let rid = id + k;
                let addr = addr.clone();
                let plen = 8 + rng.below(40);
                let prompt: String =
                    (0..plen).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                std::thread::spawn(move || {
                    one_request(&addr, rid, &prompt, max_tokens, rid % 3 == 0)
                })
            })
            .collect();
        for w in workers {
            match w.join().expect("worker") {
                Ok(o) => {
                    match o.status {
                        200 => {
                            ok += 1;
                            latency.record(o.latency.as_secs_f64());
                            if let Some(ft) = o.first_token {
                                ttft.record(ft.as_secs_f64());
                            }
                            tokens += o.tokens;
                        }
                        429 => shed += 1,
                        _ => failed += 1,
                    };
                }
                Err(e) => {
                    eprintln!("request error: {e:#}");
                    failed += 1;
                }
            }
        }
        id += wave;
        std::thread::sleep(pause);
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("── client side ────────────────────────────────────────");
    println!("  200 OK      : {ok}");
    println!("  429 shed    : {shed}");
    println!("  failed      : {failed}");
    println!("  tokens      : {tokens} ({:.1} tok/s end-to-end)", tokens as f64 / wall);
    println!(
        "  latency     : p50 {:.1}ms  p95 {:.1}ms  mean {:.1}ms",
        latency.percentile(50.0) * 1e3,
        latency.percentile(95.0) * 1e3,
        latency.mean() * 1e3
    );
    if ttft.count() > 0 {
        println!(
            "  stream ttfb : p50 {:.1}ms  p95 {:.1}ms",
            ttft.percentile(50.0) * 1e3,
            ttft.percentile(95.0) * 1e3
        );
    }

    // pull the server's own view
    if let Ok(mut sock) = TcpStream::connect(&addr) {
        let _ = write!(sock, "GET /metrics HTTP/1.1\r\nHost: l\r\nConnection: close\r\n\r\n");
        let mut raw = String::new();
        let _ = sock.set_read_timeout(Some(Duration::from_secs(5)));
        if sock.read_to_string(&mut raw).is_ok() {
            println!("── server /metrics (excerpt) ──────────────────────────");
            for line in raw.lines().filter(|l| {
                !l.starts_with('#')
                    && (l.contains("requests_") || l.contains("ttft")
                        || l.contains("per_token") || l.contains("throughput"))
            }) {
                println!("  {line}");
            }
        }
    }

    if let Some(h) = handle {
        h.shutdown();
        println!("\nself-hosted server drained cleanly");
    }
    Ok(())
}
